PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test test-all bench-sched-ops bench-colocation \
	bench-multiprocess bench-multiprocess-smoke bench-faults \
	bench-faults-smoke

## check: the fast CI gate — clean-collecting tier-1 tests (slow ones are
## deselected via pyproject addopts; the chaos smoke seeds ride along) +
## the sched-ops/arbiter microbench in smoke mode, perf-gated:
## SCHED_COOP/SCHED_FAIR pick-cycle throughput must stay within 30% of the
## committed BENCH_sched_ops.json baseline — plus the cross-process broker
## benchmark in smoke mode (machinery end-to-end; the >=1.5x ratio is
## asserted only in the full nightly run) and the fault-recovery benchmark
## in smoke mode (broker-kill MTTR + grant-convergence machinery)
check: test bench-sched-ops bench-multiprocess-smoke bench-faults-smoke

test:
	$(PY) -m pytest -q

test-all:
	$(PY) -m pytest -q -m ""

bench-sched-ops:
	$(PY) -m benchmarks.sched_ops --smoke --out BENCH_sched_ops.smoke.json \
		--gate BENCH_sched_ops.json

bench-colocation:
	$(PY) -m benchmarks.colocation

bench-multiprocess:
	$(PY) -m benchmarks.multiprocess

bench-multiprocess-smoke:
	$(PY) -m benchmarks.multiprocess --smoke \
		--out BENCH_multiprocess.smoke.json

bench-faults:
	$(PY) -m benchmarks.faults

bench-faults-smoke:
	$(PY) -m benchmarks.faults --smoke --out BENCH_faults.smoke.json
