PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test test-all bench-sched-ops bench-colocation

## check: the fast CI gate — clean-collecting tier-1 tests (slow ones are
## deselected via pyproject addopts) + the sched-ops/arbiter microbench in
## smoke mode, perf-gated: SCHED_COOP/SCHED_FAIR pick-cycle throughput must
## stay within 30% of the committed BENCH_sched_ops.json baseline
check: test bench-sched-ops

test:
	$(PY) -m pytest -q

test-all:
	$(PY) -m pytest -q -m ""

bench-sched-ops:
	$(PY) -m benchmarks.sched_ops --smoke --out BENCH_sched_ops.smoke.json \
		--gate BENCH_sched_ops.json

bench-colocation:
	$(PY) -m benchmarks.colocation
