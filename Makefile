PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test test-all bench-all bench-all-smoke bench-sched-ops \
	bench-colocation bench-multiprocess bench-multiprocess-smoke \
	bench-faults bench-faults-smoke bench-microservices bench-slo-smoke \
	bench-trace bench-trace-smoke

## check: the fast CI gate — clean-collecting tier-1 tests (slow ones are
## deselected via pyproject addopts; the chaos smoke seeds ride along) +
## the sched-ops/arbiter microbench in smoke mode, perf-gated:
## SCHED_COOP/SCHED_FAIR pick-cycle throughput within 30% and the
## real-thread preempt cycle within 60% of the committed
## BENCH_sched_ops.json baseline, the auto-checkpoint wrapper overhead
## under an absolute 5% per-step ceiling, and the urgent-preempt p50
## under a 10x-baseline/2ms ceiling — plus the cross-process broker
## benchmark in smoke mode (machinery end-to-end, including the
## real_model auto-checkpoint scenario; the ratio/latency targets are
## asserted only in the full nightly run), the fault-recovery benchmark
## in smoke mode
## (broker-kill MTTR + grant-convergence machinery), the open-arrival
## SLO load-generator in smoke mode (deadline-aware vs share-only A/B
## machinery; the win criteria are asserted on the full nightly sweep)
## and the trace-replay bench in smoke mode, perf-gated: replay events/s
## within 30% of the committed BENCH_trace_replay.json baseline (the
## gated replay runs the full-size trace even under --smoke)
check: test bench-sched-ops bench-multiprocess-smoke bench-faults-smoke \
	bench-slo-smoke bench-trace-smoke

test:
	$(PY) -m pytest -q

test-all:
	$(PY) -m pytest -q -m ""

bench-sched-ops:
	$(PY) -m benchmarks.sched_ops --smoke --out BENCH_sched_ops.smoke.json \
		--gate BENCH_sched_ops.json

bench-colocation:
	$(PY) -m benchmarks.colocation

bench-multiprocess:
	$(PY) -m benchmarks.multiprocess

bench-multiprocess-smoke:
	$(PY) -m benchmarks.multiprocess --smoke \
		--out BENCH_multiprocess.smoke.json

bench-faults:
	$(PY) -m benchmarks.faults

bench-faults-smoke:
	$(PY) -m benchmarks.faults --smoke --out BENCH_faults.smoke.json

## the full Fig. 4 grid + the open-arrival SLO sweep (nightly artifact)
bench-microservices:
	$(PY) -m benchmarks.microservices

bench-slo-smoke:
	$(PY) -m benchmarks.microservices --slo-only --smoke \
		--out BENCH_microservices.smoke.json

## trace record/replay: gated replay throughput + recorder overhead +
## determinism + the replayer-backed SLO A/B (full sweep is nightly)
bench-trace:
	$(PY) -m benchmarks.trace_replay --gate BENCH_trace_replay.json

bench-trace-smoke:
	$(PY) -m benchmarks.trace_replay --smoke --gate BENCH_trace_replay.json

## every benchmark module through the unified runner (benchmarks/run.py)
bench-all:
	$(PY) -m benchmarks.run --all

bench-all-smoke:
	$(PY) -m benchmarks.run --all --smoke
