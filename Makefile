PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test test-all bench-sched-ops

## check: the fast CI gate — clean-collecting tier-1 tests (slow ones are
## deselected via pyproject addopts) + the sched-ops microbench in smoke mode
check: test bench-sched-ops

test:
	$(PY) -m pytest -q

test-all:
	$(PY) -m pytest -q -m ""

bench-sched-ops:
	$(PY) -m benchmarks.sched_ops --smoke --out BENCH_sched_ops.smoke.json
