"""Trace record/replay: determinism, reconstruction, schema, synthesis.

The centerpiece is the record→replay→re-record fuzz: a seeded random
live run (mixed op programs reusing the test_sched_model generator shape,
driver-delivered semaphore wakes, attach/demote/resize control churn,
half the seeds under a ``DeadlineArbiter`` with mixed deadline traffic)
is recorded with op recording armed, reconstructed into a ``Workload``,
and replayed. Asserted bit-identical on the DECISION_CODES stream:

* replay vs replay under the same config (determinism);
* replay vs a replay of the *re-recorded* replay (reconstruction is a
  fixed point — nothing is lost or invented by the round trip).

Live-vs-replay equality is NOT asserted: sync blocks are re-encoded as
absolute-time ``sleep_until`` ops (a documented approximation), so the
replay reproduces the observed blocking behaviour, not the sync objects.

Also covered: the sleep-then-sync-block attribution corner in
``reconstruct``, exact ``events_processed`` accounting under batched
same-timestamp wakeups, schema round-trip/rejection, recorder
arm/disarm hygiene, synthesized workloads (arrival generators,
stragglers, node churn), the task-event CSV adapter, the A/B runner,
and the unified benchmark runner's discovery.
"""

import json
import random

import pytest

from repro.core import simtask as st
from repro.core.deadline import DeadlineArbiter
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair, SchedRR
from repro.core.task import Job
from repro.core.topology import Topology
from repro.trace import (
    ReplayConfig,
    Replayer,
    TraceRecorder,
    TraceSchemaError,
    Workload,
    diff_streams,
    load_trace,
    reconstruct,
)
from repro.trace import schema as trace_schema
from repro.trace import synth
from repro.trace.ab import run_ab, slo_ab_configs
from repro.trace.adapter import ALIBABA_COLUMNS, load_task_events

N_SEEDS = 10


# --------------------------------------------------------------------- #
# the recorded live fuzz driver
# --------------------------------------------------------------------- #
class _TaskModel:
    __slots__ = ("task", "sem", "blocks_total", "wakes_sent")

    def __init__(self, task, sem, blocks_total):
        self.task = task
        self.sem = sem
        self.blocks_total = blocks_total
        self.wakes_sent = 0

    @property
    def wakes_owed(self):
        return self.blocks_total - self.wakes_sent


def _spawn_random_task(sim, rng, job, *, deadline=None) -> _TaskModel:
    """The test_sched_model op-generator shape: a random program over
    compute/sleep/yield/checkpoint plus semaphore blocks the driver must
    wake (the sync ops the reconstruction re-encodes as sleep_until)."""
    sem = st.SimSemaphore(0)
    ops = []
    n_blocks = 0
    for _ in range(rng.randint(2, 6)):
        k = rng.random()
        if k < 0.35:
            ops.append(("compute", rng.uniform(3e-4, 4e-3)))
        elif k < 0.50:
            ops.append(("sleep", rng.uniform(3e-4, 4e-3)))
        elif k < 0.62:
            ops.append(("yield",))
        elif k < 0.76:
            ops.append(("checkpoint",))
        else:
            ops.append(("block",))
            n_blocks += 1

    def gen():
        for op in ops:
            if op[0] == "compute":
                yield st.compute(op[1])
            elif op[0] == "sleep":
                yield st.sleep(op[1])
            elif op[0] == "yield":
                yield st.yield_()
            elif op[0] == "checkpoint":
                yield st.checkpoint()
            else:
                yield st.sem_acquire(sem)

    return _TaskModel(sim.spawn(job, gen, deadline=deadline), sem, n_blocks)


def _deliver_wake(sim, tm: _TaskModel) -> None:
    tm.wakes_sent += 1
    if tm.sem.queue:
        sim.sched.unblock(tm.sem.queue.popleft())
    else:
        tm.sem.value += 1


def _record_fuzz(seed: int):
    """One seeded random live run, recorded; returns (records, the
    ReplayConfig matching the live executor)."""
    rng = random.Random(seed)
    use_deadline = seed % 2 == 0
    n_slots = rng.choice((2, 4, 8))
    arb = DeadlineArbiter(SchedCoop(quantum=0.01)) if use_deadline else None
    sim = SimExecutor(Topology(n_slots, 1), SchedCoop(quantum=0.01),
                      max_time=1e9, arbiter=arb)
    rec = TraceRecorder().attach_sim(sim, ops=True)

    jobs = [Job(f"trfz{seed}-{i}") for i in range(rng.randint(2, 3))]
    models = []

    def spawn(job):
        dl = None
        if use_deadline and rng.random() < 0.5:
            dl = sim.now() + rng.uniform(-0.005, 0.05)  # sometimes overdue
        models.append(_spawn_random_task(sim, rng, job, deadline=dl))

    for job in jobs:
        for _ in range(rng.randint(1, 3)):
            spawn(job)

    def advance(dt):
        sim.run(until=sim.now() + dt)

    for _ in range(rng.randint(20, 40)):
        op = rng.random()
        job = rng.choice(jobs)
        if op < 0.22:
            spawn(job)
        elif op < 0.45:
            owed = [m for m in models if m.wakes_owed > 0]
            if owed:
                _deliver_wake(sim, rng.choice(owed))
        elif op < 0.60:  # attach: promote or live policy swap
            pol = rng.choice((
                lambda: SchedCoop(quantum=0.005),
                lambda: SchedFair(slice_s=0.002),
                lambda: SchedRR(quantum=0.003),
            ))()
            sim.attach(job, policy=pol, share=rng.choice((1.0, 2.0)))
        elif op < 0.70:
            if job.lease is not None and job.lease.group.dedicated:
                sim.demote(job, share=rng.choice((None, 1.0)))
        elif op < 0.80:
            if job.lease is not None:
                job.lease.resize(rng.choice((0.5, 1.0, 3.0)))
        else:
            advance(rng.uniform(0.001, 0.01))
        advance(rng.uniform(0.0005, 0.004))

    for tm in models:
        while tm.wakes_owed > 0:
            _deliver_wake(sim, tm)
    sim.run()
    rec.detach_all()
    assert all(m.task.done for m in models)
    cfg = ReplayConfig(slots=n_slots, domains=1,
                       default_policy=("SCHED_COOP", 0.01),
                       arbiter="deadline" if use_deadline else "none")
    return rec.records(), cfg


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_record_replay_rerecord_bit_identical(seed):
    records, cfg = _record_fuzz(seed)
    wl = reconstruct(records)
    assert wl.tasks and wl.n_ops() > 0

    r1 = Replayer(wl, cfg).run(record=True)
    r2 = Replayer(wl, cfg).run(record=True)
    s1 = r1.normalized_records()
    d = diff_streams(s1, r2.normalized_records())
    assert d is None, f"seed {seed}: replay not deterministic: {d}"
    assert all(t.done for t in r1.tasks), f"seed {seed}: replay lost tasks"

    # fixed point: re-record the replay, reconstruct THAT, replay again —
    # the round trip must not lose or invent a single decision
    wl2 = reconstruct(s1)
    r3 = Replayer(wl2, cfg).run(record=True)
    d = diff_streams(s1, r3.normalized_records())
    assert d is None, f"seed {seed}: reconstruction not a fixed point: {d}"


def test_sync_block_after_sleep_not_misattributed():
    """A sem block landing right after a completed sleep must survive
    reconstruction as its own sleep_until (a sleep op explains at most
    one block)."""
    sim = SimExecutor(Topology(2, 1), SchedCoop(quantum=0.01), max_time=1e9)
    rec = TraceRecorder().attach_sim(sim, ops=True)
    sem = st.SimSemaphore(0)

    def gen():
        yield st.compute(0.001)
        yield st.sleep(0.002)
        yield st.sem_acquire(sem)     # blocks immediately after the sleep
        yield st.compute(0.001)

    task = sim.spawn(Job("corner"), gen)
    sim.run(until=0.01)               # sleep expired; now parked on sem
    assert sem.queue
    sim.sched.unblock(sem.queue.popleft())
    sim.run()
    rec.detach_all()
    assert task.done

    wl = reconstruct(rec.records())
    kinds = [op[0] for op in wl.tasks[0].ops]
    assert kinds == ["compute", "sleep", "sleep_until", "compute"]


# --------------------------------------------------------------------- #
# satellite: exact events_processed accounting under batched wakeups
# --------------------------------------------------------------------- #
def test_events_processed_exact_under_batched_wakeups():
    """Same-timestamp sleep expiries drain as one batch; the count must
    still equal the number of heap pops — identical to the staggered run
    where every wakeup is its own pop."""
    def run_one(stagger):
        sim = SimExecutor(Topology(8, 1), SchedCoop(quantum=0.01),
                          max_time=1e9)
        job = Job("wk")
        for i in range(8):
            dt = 0.01 + (i * 1e-6 if stagger else 0.0)

            def gen(dt=dt):
                yield st.compute(0.001)
                yield st.sleep(dt)
                yield st.compute(0.001)

            sim.spawn(job, gen)
        sim.run()
        return sim.events_processed

    batched, staggered = run_one(False), run_one(True)
    assert batched == staggered == 40  # 5 structural events per task


# --------------------------------------------------------------------- #
# recorder: arm/disarm hygiene, file streaming
# --------------------------------------------------------------------- #
def _tiny_run(recorder=None):
    sim = SimExecutor(Topology(2, 1), SchedCoop(quantum=0.01), max_time=1e9)
    if recorder is not None:
        recorder.attach_sim(sim, ops=True)
    job = Job("tiny")
    for _ in range(3):
        sim.spawn(job, lambda: iter((("compute", 0.001, 0.0),
                                     ("sleep", 0.002),
                                     ("yield",),
                                     ("compute", 0.001, 0.0))))
    sim.run()
    return sim


def test_recorder_arm_disarm_restores_clean_state():
    sim = SimExecutor(Topology(2, 1), SchedCoop(quantum=0.01), max_time=1e9)
    assert sim.sched._rec is None
    assert "_advance" not in sim.__dict__   # disarmed: class method, no shim
    rec = TraceRecorder().attach_sim(sim, ops=True)
    assert sim.sched._rec is rec.emit
    assert "_advance" in sim.__dict__       # armed: recording twin shadowed
    rec.detach_all()
    assert sim.sched._rec is None
    assert "_advance" not in sim.__dict__


def test_recorder_memory_vs_file_streams_identical(tmp_path):
    mem = TraceRecorder()
    _tiny_run(mem)
    mem.close()

    path = str(tmp_path / "run.jsonl")
    with TraceRecorder(path, meta={"who": "test"}) as filed:
        _tiny_run(filed)

    header, records = load_trace(path)
    assert header["kind"] == "decisions"
    assert header["meta"] == {"who": "test"}
    # the sim is virtual-time deterministic, but tids/jids are process-
    # global — normalize both runs into a common (per-run-relative) space
    wl_mem, wl_file = reconstruct(mem.records()), reconstruct(records)
    assert len(wl_mem.tasks) == len(wl_file.tasks) == 3
    assert ([ts.ops for ts in wl_mem.tasks]
            == [ts.ops for ts in wl_file.tasks])


def test_disarmed_run_records_nothing():
    rec = TraceRecorder()
    _tiny_run(recorder=None)
    assert rec.records() == []


# --------------------------------------------------------------------- #
# schema: round-trip + rejection
# --------------------------------------------------------------------- #
def test_workload_save_load_roundtrip(tmp_path):
    wl = synth.slo_workload(0.8, n_requests=40, seed=3)
    path = str(tmp_path / "wl.jsonl")
    wl.save(path)
    wl2 = Workload.load(path)
    assert wl2.jobs == wl.jobs
    assert wl2.tasks == wl.tasks
    assert wl2.control == wl.control


def test_decision_records_roundtrip_bit_exact():
    rec = TraceRecorder()
    _tiny_run(rec)
    rec.close()
    records = rec.records()
    assert records
    decoded = [trace_schema.decode_record(trace_schema.encode_record(r))
               for r in records]
    assert decoded == records  # floats round-trip exactly through JSON


def test_fast_json_encoder_matches_dumps():
    """The writer's direct formatter (``encode_record_json``) must decode
    to exactly what the ``encode_record`` + ``json.dumps`` path decodes
    to, across every payload shape — including the non-finite floats and
    structured payloads that take the fallback."""
    from repro.core.scheduler import (REC_DISPATCH, REC_DL_POST, REC_OP,
                                      REC_RESIZE, REC_SPAWN, REC_WAKE)
    rng = random.Random(7)
    recs = []
    for i in range(500):
        t = rng.random() * 100
        recs.append(rng.choice([
            (t, REC_DISPATCH, i, rng.randrange(8)),
            (t, REC_WAKE, i, None),
            (t, REC_RESIZE, i, rng.random()),
            (t, REC_SPAWN, i, (3, None, 1.5)),
            (t, REC_OP, i, ("compute", 0.25, None)),
        ]))
    recs.append((float("inf"), REC_DL_POST, 1, float("inf")))
    for r in recs:
        line = trace_schema.encode_record_json(r)
        via_dumps = json.dumps(trace_schema.encode_record(r),
                               separators=(",", ":"))
        assert json.loads(line) == json.loads(via_dumps), r
        assert trace_schema.decode_record(json.loads(line)) == r


def test_schema_rejections(tmp_path):
    def write(header):
        p = tmp_path / "bad.jsonl"
        p.write_text(json.dumps(header) + "\n")
        return str(p)

    good = trace_schema.make_header(trace_schema.KIND_DECISIONS)

    future = dict(good, version=trace_schema.SCHEMA_VERSION + 1)
    with pytest.raises(TraceSchemaError, match="version"):
        load_trace(write(future))

    alien = dict(good, schema="not-a-trace")
    with pytest.raises(TraceSchemaError, match="schema"):
        load_trace(write(alien))

    with pytest.raises(TraceSchemaError, match="kind"):
        load_trace(write(dict(good, kind="mystery")))

    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    with pytest.raises(TraceSchemaError, match="empty"):
        load_trace(str(empty))

    with pytest.raises(TraceSchemaError, match="tag"):
        trace_schema.decode_record(["??", 0.0, 1, None])
    with pytest.raises(TraceSchemaError, match="op"):
        trace_schema.decode_op(["zz", 1.0])
    with pytest.raises(TraceSchemaError):
        Workload.from_lines([["X", 1, 2, 3]])


# --------------------------------------------------------------------- #
# synthesis: arrival generators, perturbations
# --------------------------------------------------------------------- #
def test_arrival_generators_deterministic_and_ordered():
    for gen in (synth.poisson_arrivals, synth.burst_arrivals,
                synth.diurnal_arrivals):
        a = gen(100.0, 300, seed=1)
        assert len(a) == 300
        assert all(y >= x for x, y in zip(a, a[1:]))
        assert a == gen(100.0, 300, seed=1)
        assert a != gen(100.0, 300, seed=2)


def test_stragglers_and_node_churn_replay():
    wl = synth.colocation_workload(n_requests=150, batch_tasks=2,
                                   batch_segments=60, seed=1)
    base_ops = wl.n_ops()
    straggled = synth.with_stragglers(wl, frac=0.2, factor=4.0, seed=2)
    assert straggled.n_ops() == base_ops  # stretched, not re-shaped

    def total_compute(w):
        return sum(op[1] for ts in w.tasks for op in ts.ops
                   if op[0] == "compute")

    assert total_compute(straggled) > total_compute(wl)

    churned = synth.with_node_churn(straggled, [(0.05, 4), (0.2, 8)])
    assert [c for c in churned.control if c[1] == "target"]
    r = Replayer(churned, ReplayConfig(
        slots=8, domains=2, default_policy=("SCHED_FAIR", 0.003))).run()
    assert all(t.done for t in r.tasks)
    assert r.events == r.sim.events_processed > 0


# --------------------------------------------------------------------- #
# adapter: task-event CSV -> workload
# --------------------------------------------------------------------- #
def test_adapter_google_style_rows():
    rows = [
        # [time, _, jid, tid, _, event] — GOOGLE_COLUMNS order
        ["0",       "-", "j1", "t1", "-", "0"],   # submit
        ["100000",  "-", "j1", "t1", "-", "1"],   # schedule
        ["600000",  "-", "j1", "t1", "-", "4"],   # finish: 0.5 s
        ["200000",  "-", "j1", "t2", "-", "0"],   # submit, never finishes
        ["300000",  "-", "j2", "t1", "-", "0"],
        ["300000",  "-", "j2", "t1", "-", "5"],   # killed before running
        ["garbage", "-", "j9", "t9", "-", "0"],   # malformed: skipped
    ]
    wl = load_task_events(rows, time_scale=1e-6, chunk_s=0.01,
                          default_duration=0.02)
    assert len(wl.tasks) == 2            # the killed task is dropped
    assert len(wl.jobs) == 1             # ...and with it its only job
    by_arrival = {round(ts.t, 6): ts for ts in wl.tasks}
    full = by_arrival[0.0]
    assert full.cost_hint == pytest.approx(0.5)
    assert len(full.ops) == 50           # 0.5 s chunked at 10 ms
    assert sum(op[1] for op in full.ops) == pytest.approx(0.5)
    defaulted = by_arrival[0.2]
    assert defaulted.cost_hint == pytest.approx(0.02)
    assert wl.meta["defaulted_durations"] == 1

    r = Replayer(wl, ReplayConfig(slots=2, domains=1)).run()
    assert all(t.done for t in r.tasks)


def test_adapter_alibaba_style_rows():
    rows = [
        # [tid, _, jid, _, event, time, end_time] — ALIBABA_COLUMNS order
        ["1", "-", "j1", "-", "ready",      "10", "12"],
        ["2", "-", "j1", "-", "ready",      "11", "14"],
        ["3", "-", "j2", "-", "terminated", "12", "13"],
    ]
    wl = load_task_events(rows, columns=ALIBABA_COLUMNS, chunk_s=0.5)
    assert len(wl.tasks) == 3
    assert [ts.t for ts in wl.tasks] == [0.0, 1.0, 2.0]  # shifted to t0
    assert wl.tasks[0].cost_hint == pytest.approx(2.0)
    assert len(wl.tasks[0].ops) == 4                     # 2 s / 0.5 s
    # the lone "terminated" row still yields a start (its `time` column)
    assert wl.tasks[2].cost_hint == pytest.approx(1.0)


def test_adapter_rejects_empty_and_unmapped():
    with pytest.raises(ValueError, match="empty"):
        load_task_events([])
    with pytest.raises(ValueError, match="columns"):
        load_task_events([["0", "1"]], columns={"time": 0})


# --------------------------------------------------------------------- #
# A/B runner
# --------------------------------------------------------------------- #
def test_slo_ab_smoke():
    wl = synth.slo_workload(0.8, n_requests=150, seed=0)
    cfg_deadline, cfg_share = slo_ab_configs()
    res = run_ab(wl, cfg_deadline, cfg_share,
                 name_a="deadline", name_b="share")
    a, b = res["a"], res["b"]
    # both sides finish every task (serve requests + batch segments)
    assert a.completed == b.completed == len(wl.tasks)
    assert a.deadline_tasks == b.deadline_tasks == 150
    assert len(a.latencies) == 150
    cmp = res["comparison"]
    assert set(cmp["miss_rate"]) == {"deadline", "share"}
    assert cmp["events"]["deadline"] > 0 and cmp["events"]["share"] > 0


# --------------------------------------------------------------------- #
# unified benchmark runner
# --------------------------------------------------------------------- #
def test_bench_runner_discovery():
    from benchmarks.run import _takes_argv, discover, run_csv

    names = discover()
    for expected in ("sched_ops", "trace_replay", "colocation",
                     "microservices", "faults", "multiprocess"):
        assert expected in names
    assert "common" not in names and "run" not in names

    import benchmarks.sched_ops
    import benchmarks.matmul_heatmap
    assert _takes_argv(benchmarks.sched_ops.main)        # forwards --smoke
    assert not _takes_argv(benchmarks.matmul_heatmap.main)
    assert callable(run_csv)                             # legacy path kept


def test_bench_runner_rejects_unknown_module(capsys):
    from benchmarks.run import run_all

    assert run_all(smoke=True, only=["does_not_exist"]) == 2
    assert "unknown benchmarks" in capsys.readouterr().err
