"""Per-kernel validation: interpret=True Pallas vs pure-jnp oracle,
with hypothesis sweeps over shapes and dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as hst

from repro.kernels import ops, ref

jax.config.update("jax_default_matmul_precision", "highest")


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@settings(max_examples=12, deadline=None)
@given(
    hst.sampled_from([(1, 4, 128, 32), (2, 6, 256, 64), (1, 8, 64, 16)]),
    hst.sampled_from([1, 2]),       # GQA group size
    hst.booleans(),                  # causal
    hst.sampled_from([None, 32]),    # window
    hst.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_matches_ref(dims, g, causal, window, dtype):
    B, H, S, D = dims
    if H % g:
        g = 1
    KV = H // g
    if window is not None and not causal:
        window = None  # windowed-bidir unused by any arch
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(k1, (B, S, H, D)).astype(dtype)
    k = jax.random.normal(k2, (B, S, KV, D)).astype(dtype)
    v = jax.random.normal(k3, (B, S, KV, D)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    # ref uses kernel layout
    expect = ref.flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal, window=window,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.swapaxes(out, 1, 2), np.float32),
        np.asarray(expect, np.float32), **_tol(dtype)
    )


def test_flash_attention_nondivisible_seq_padding():
    B, H, S, D = 1, 2, 100, 32  # S not a multiple of the block
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    expect = ref.flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.swapaxes(out, 1, 2), np.float32),
        np.asarray(expect, np.float32), rtol=2e-5, atol=2e-5,
    )


# --------------------------------------------------------------------------- #
# flash decode
# --------------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(
    hst.sampled_from([(1, 4, 64, 32), (2, 8, 128, 16)]),
    hst.sampled_from([1, 2]),
    hst.sampled_from([None, 48]),
    hst.integers(5, 60),
)
def test_flash_decode_matches_ref(dims, g, window, pos):
    B, H, W, D = dims
    if H % g:
        g = 1
    KV = H // g
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k_cache = jax.random.normal(ks[1], (B, W, KV, D))
    v_cache = jax.random.normal(ks[2], (B, W, KV, D))
    # linear cache filled up to pos
    cache_pos = jnp.broadcast_to(jnp.arange(W), (B, W))
    cache_pos = jnp.where(cache_pos <= pos, cache_pos, -1).astype(jnp.int32)
    q_pos = jnp.full((B,), pos, jnp.int32)
    out = ops.flash_decode(q, k_cache, v_cache, cache_pos, q_pos,
                           window=window, interpret=True)
    expect = ref.flash_decode_ref(
        q, jnp.swapaxes(k_cache, 1, 2), jnp.swapaxes(v_cache, 1, 2),
        cache_pos, q_pos, window=window,
    )
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# SSD scan
# --------------------------------------------------------------------------- #
@settings(max_examples=8, deadline=None)
@given(
    hst.sampled_from([(1, 64, 2, 16, 8), (2, 128, 4, 32, 16)]),
    hst.sampled_from([16, 32]),
)
def test_ssd_scan_matches_recurrence(dims, chunk):
    B, S, H, P, N = dims
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y, h = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(h_ref, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_ssd_model_chunked_matches_kernel():
    """The model's pure-JAX chunked SSD and the Pallas kernel agree."""
    from repro.models.mamba2 import ssd_chunked

    B, S, H, P, N = 2, 64, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y2, h2 = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- #
# RG-LRU scan
# --------------------------------------------------------------------------- #
@settings(max_examples=8, deadline=None)
@given(
    hst.sampled_from([(1, 64, 128), (2, 128, 256), (1, 32, 128)]),
    hst.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_rglru_matches_recurrence(dims, dtype):
    B, S, W = dims
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, W)) * 0.1).astype(dtype)
    h0 = jax.random.normal(ks[2], (B, W)).astype(jnp.float32)
    y, hN = ops.rglru(a, b, h0, interpret=True)
    y_ref, h_ref = ref.rglru_ref(a, b, h0)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(hN), np.asarray(h_ref), **tol)


# --------------------------------------------------------------------------- #
# grouped matmul
# --------------------------------------------------------------------------- #
@settings(max_examples=8, deadline=None)
@given(
    hst.sampled_from([(2, 64, 32, 48), (4, 100, 64, 96), (1, 128, 128, 128)]),
    hst.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_moe_gmm_matches_einsum(dims, dtype):
    E, C, D, F = dims
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    x = (jax.random.normal(ks[0], (E, C, D)) * 0.5).astype(dtype)
    w = (jax.random.normal(ks[1], (E, D, F)) * 0.5).astype(dtype)
    out = ops.moe_gmm(x, w, interpret=True)
    expect = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))
