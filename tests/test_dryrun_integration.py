"""Integration: the dry-run harness end-to-end in a subprocess (8 fake
devices, debug mesh) — exercises mesh construction, shardings, lowering,
compile, memory/cost analysis, collective parsing and the probe
decomposition exactly as the production 512-device sweep does."""

import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run_dryrun(tmp_path, *args):
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--debug-mesh",
         "--out", str(tmp_path), *args],
        capture_output=True, text=True, timeout=900, env=env, cwd=str(REPO),
    )


@pytest.mark.slow
def test_dryrun_train_cell_debug_mesh(tmp_path):
    p = _run_dryrun(tmp_path, "--arch", "smollm_360m", "--shape", "train_4k")
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    out = json.loads(
        (tmp_path / "smollm_360m.train_4k.debug.json").read_text()
    )
    assert out["status"] == "ok"
    r = out["roofline"]
    assert r["flops_global"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert 0 < r["useful_flops_ratio"] <= 1.5
    assert out["probes"]["derived"]["per_layer_flops"] > 0


@pytest.mark.slow
def test_dryrun_decode_cell_debug_mesh(tmp_path):
    p = _run_dryrun(tmp_path, "--arch", "mamba2_2_7b", "--shape",
                    "decode_32k")
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    out = json.loads(
        (tmp_path / "mamba2_2_7b.decode_32k.debug.json").read_text()
    )
    assert out["status"] == "ok"
    assert out["full"]["memory"]["peak_bytes_est"] > 0


@pytest.mark.slow
def test_sharding_rules_under_fake_devices():
    """Re-runs the mesh-dependent sharding-rule tests with 8 fake devices
    (they self-skip in the default 1-device environment)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    p = subprocess.run(
        [sys.executable, "-m", "pytest", "tests/test_sharding_rules.py",
         "-q", "--no-header"],
        capture_output=True, text=True, timeout=600, env=env, cwd=str(REPO),
    )
    assert p.returncode == 0, p.stdout[-2000:]
    assert "skipped" not in p.stdout.splitlines()[-1]


@pytest.mark.slow
def test_dryrun_skip_cell(tmp_path):
    """Encoder-only arch x decode shape must be recorded as a skip."""
    p = _run_dryrun(tmp_path, "--arch", "hubert_xlarge", "--shape",
                    "decode_32k")
    assert p.returncode == 0
    out = json.loads(
        (tmp_path / "hubert_xlarge.decode_32k.debug.json").read_text()
    )
    assert out["status"] == "skip"
    assert "encoder-only" in out["reason"]
