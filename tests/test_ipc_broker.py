"""Cross-process coordination (repro.ipc): the node-level lease broker.

Covers the lease lifecycle (register / grant / resize / rescale /
deregister), the work-conserving node apportionment, and — critically —
the fault paths the paper's pure-user-space stance demands:

* a worker process killed mid-lease is reclaimed (socket EOF immediately,
  heartbeat timeout for wedged-but-connected workers) and its slots flow
  to the survivors;
* a broker killed mid-run degrades every worker to free-running — full
  local width, no hang, no deadlock.
"""

import multiprocessing as mp
import os
import socket
import tempfile
import threading
import time

import pytest

from repro.core.policies import SchedCoop
from repro.core.task import Job
from repro.core.threads import UsfRuntime
from repro.core.topology import Topology
from repro.ipc import BrokerClient, NodeBroker
from repro.ipc.protocol import recv_msg, send_msg

_CTX = mp.get_context("spawn")


def _path() -> str:
    return os.path.join(tempfile.mkdtemp(prefix="usf-ipc-"), "broker.sock")


def _wait_until(cond, timeout=5.0, step=0.01):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(step)
    return cond()


@pytest.fixture
def broker():
    b = NodeBroker(_path(), capacity=4, heartbeat_timeout=0.6)
    b.start()
    yield b
    b.stop()


# --------------------------------------------------------------------- #
# lease lifecycle & apportionment
# --------------------------------------------------------------------- #
def test_single_worker_gets_whole_node(broker):
    c = BrokerClient(broker.path, name="w0", share=1.0, slots=4,
                     heartbeat_interval=0.1).start()
    try:
        assert c.wait_grant(5.0) == 4  # work-conserving: nobody else wants
    finally:
        c.stop()


def test_two_workers_split_by_share(broker):
    c1 = BrokerClient(broker.path, name="w1", share=1.0, slots=4,
                      heartbeat_interval=0.1).start()
    c2 = BrokerClient(broker.path, name="w2", share=3.0, slots=4,
                      heartbeat_interval=0.1).start()
    try:
        assert c1.wait_grant(5.0) is not None
        assert _wait_until(lambda: c1.granted == 1 and c2.granted == 3)
        snap = broker.snapshot()
        assert snap["workers"]["w1"]["quota"] == 1
        assert snap["workers"]["w2"]["quota"] == 3
    finally:
        c1.stop()
        c2.stop()


def test_grant_capped_at_demand_and_redistributed(broker):
    # w1 can only use 1 slot: its spare quota flows to w2 (I5 borrow
    # order at node scope — work-conserving, no slot idles)
    c1 = BrokerClient(broker.path, name="w1", share=1.0, slots=1,
                      heartbeat_interval=0.1).start()
    c2 = BrokerClient(broker.path, name="w2", share=1.0, slots=4,
                      heartbeat_interval=0.1).start()
    try:
        assert _wait_until(lambda: c1.granted == 1 and c2.granted == 3)
    finally:
        c1.stop()
        c2.stop()


def test_resize_and_rescale_reapportion(broker):
    c1 = BrokerClient(broker.path, name="w1", share=1.0, slots=4,
                      heartbeat_interval=0.1).start()
    c2 = BrokerClient(broker.path, name="w2", share=1.0, slots=4,
                      heartbeat_interval=0.1).start()
    try:
        assert _wait_until(lambda: c1.granted == 2 and c2.granted == 2)
        c1.resize(3.0)  # the cross-process lease.resize
        assert _wait_until(lambda: c1.granted == 3 and c2.granted == 1)
        c1.rescale(1 / 3)  # the MeshRescaleEvent routing: back to 1.0
        assert _wait_until(lambda: c1.granted == 2 and c2.granted == 2)
        assert c1.share == pytest.approx(1.0)
    finally:
        c1.stop()
        c2.stop()


def test_deregister_returns_capacity_to_survivors(broker):
    c1 = BrokerClient(broker.path, name="w1", share=1.0, slots=4,
                      heartbeat_interval=0.1).start()
    c2 = BrokerClient(broker.path, name="w2", share=1.0, slots=4,
                      heartbeat_interval=0.1).start()
    assert _wait_until(lambda: c1.granted == 2 and c2.granted == 2)
    c2.stop()  # clean deregister
    try:
        assert _wait_until(lambda: c1.granted == 4)
        assert _wait_until(lambda: len(broker.snapshot()["workers"]) == 1)
    finally:
        c1.stop()


def test_grants_drive_bound_runtime_width(broker):
    """End-to-end: a pushed grant lands on elastic slot parking."""
    rt1 = UsfRuntime(Topology(4, 1), SchedCoop())
    rt2 = UsfRuntime(Topology(4, 1), SchedCoop())
    c1 = BrokerClient(broker.path, name="w1",
                      heartbeat_interval=0.1).bind(rt1).start()
    c2 = None
    try:
        assert c1.wait_grant(5.0) == 4
        assert rt1.sched.slot_target() == 4
        c2 = BrokerClient(broker.path, name="w2",
                          heartbeat_interval=0.1).bind(rt2).start()
        assert _wait_until(lambda: rt1.sched.slot_target() == 2
                           and rt2.sched.slot_target() == 2)
        # gated work respects the brokered width
        lock = threading.Lock()
        state = {"cur": 0, "max": 0}
        job = Job("j")

        def body():
            for _ in range(4):
                with lock:
                    state["cur"] += 1
                    state["max"] = max(state["max"], state["cur"])
                time.sleep(0.002)
                with lock:
                    state["cur"] -= 1
                rt1.yield_now()

        tasks = [rt1.create(body, job=job) for _ in range(6)]
        for t in tasks:
            assert rt1.join(t, timeout=30.0)
        assert state["max"] <= 2
    finally:
        c1.stop()
        if c2 is not None:
            c2.stop()
        rt1.shutdown(timeout=5.0)
        rt2.shutdown(timeout=5.0)


def test_zero_grant_floors_at_one_slot(broker):
    """A starved apportionment (capacity < workers) still leaves every
    bound runtime one slot — throttled, never deadlocked."""
    rts = [UsfRuntime(Topology(2, 1), SchedCoop()) for _ in range(6)]
    clients = []
    try:
        for i, rt in enumerate(rts):
            clients.append(BrokerClient(
                broker.path, name=f"w{i}",
                heartbeat_interval=0.1).bind(rt).start())
        assert _wait_until(
            lambda: all(c.granted is not None for c in clients))
        # 4 slots over 6 workers: someone holds a zero grant...
        assert _wait_until(
            lambda: sum(c.granted for c in clients) == 4)
        # ...but every runtime keeps at least one active slot
        for rt in rts:
            assert rt.sched.slot_target() >= 1
        job = Job("alive")
        done = []
        for rt in rts:
            t = rt.create(lambda: done.append(1), job=job)
            assert rt.join(t, timeout=30.0)
        assert len(done) == len(rts)
    finally:
        for c in clients:
            c.stop()
        for rt in rts:
            rt.shutdown(timeout=5.0)


# --------------------------------------------------------------------- #
# fault path 1: worker dies mid-lease
# --------------------------------------------------------------------- #
def _victim_main(path: str, ready) -> None:
    """A worker process that registers and then parks forever (until
    killed): the broker must reclaim it."""
    client = BrokerClient(path, name="victim", share=1.0, slots=4,
                          heartbeat_interval=0.1).start()
    client.wait_grant(5.0)
    ready.set()
    time.sleep(600.0)


def test_worker_killed_mid_lease_is_reclaimed(broker):
    survivor = BrokerClient(broker.path, name="survivor", share=1.0,
                            slots=4, heartbeat_interval=0.1).start()
    try:
        assert survivor.wait_grant(5.0) == 4
        ready = _CTX.Event()
        victim = _CTX.Process(target=_victim_main,
                              args=(broker.path, ready), daemon=True)
        victim.start()
        assert ready.wait(30.0)
        assert _wait_until(lambda: survivor.granted == 2)
        assert len(broker.snapshot()["workers"]) == 2

        victim.kill()  # SIGKILL: no deregister, no goodbye
        victim.join(10.0)
        # reclaim is EOF-driven (faster than the heartbeat timeout): the
        # victim's lease is gone and its slots flow back to the survivor
        assert _wait_until(lambda: survivor.granted == 4, timeout=3.0)
        snap = broker.snapshot()
        assert list(snap["workers"]) == ["survivor"]
        assert snap["reclaims"] >= 1
    finally:
        survivor.stop()


def test_wedged_worker_reclaimed_by_heartbeat_timeout(broker):
    """A worker whose socket stays open but goes silent (wedged process)
    is reclaimed within one heartbeat-timeout window."""
    survivor = BrokerClient(broker.path, name="survivor", share=1.0,
                            slots=4, heartbeat_interval=0.1).start()
    try:
        # a raw, never-heartbeating registration
        silent = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        silent.connect(broker.path)
        send_msg(silent, {"op": "register", "name": "wedged",
                          "share": 1.0, "slots": 4, "pid": 0})
        assert recv_msg(silent)["op"] == "welcome"
        assert recv_msg(silent)["op"] == "grant"
        assert _wait_until(lambda: survivor.granted == 2)

        t0 = time.monotonic()
        # silence: no heartbeats. Reclaim must land within the timeout
        # (0.6 s) plus one reaping pass — bounded, asserted generously.
        assert _wait_until(lambda: survivor.granted == 4, timeout=5.0)
        assert time.monotonic() - t0 < 4.0
        assert list(broker.snapshot()["workers"]) == ["survivor"]
        silent.close()
    finally:
        survivor.stop()


# --------------------------------------------------------------------- #
# fault path 2: broker dies mid-run
# --------------------------------------------------------------------- #
def _broker_main(path: str, capacity: int) -> None:
    NodeBroker(path, capacity=capacity,
               heartbeat_timeout=0.6).serve_forever()


def test_broker_killed_workers_degrade_to_free_running():
    """Killing the broker mid-run must leave workers free-running at full
    local width — never hung, never throttled by a dead coordinator."""
    path = _path()
    proc = _CTX.Process(target=_broker_main, args=(path, 4), daemon=True)
    proc.start()
    assert _wait_until(lambda: os.path.exists(path), timeout=10.0)

    rt1 = UsfRuntime(Topology(4, 1), SchedCoop())
    rt2 = UsfRuntime(Topology(4, 1), SchedCoop())
    c1 = BrokerClient(path, name="w1", heartbeat_interval=0.1)\
        .bind(rt1).start()
    c2 = BrokerClient(path, name="w2", heartbeat_interval=0.1)\
        .bind(rt2).start()
    try:
        assert _wait_until(lambda: rt1.sched.slot_target() == 2
                           and rt2.sched.slot_target() == 2)

        proc.kill()  # the coordinator vanishes without a goodbye
        proc.join(10.0)
        assert _wait_until(lambda: c1.degraded and c2.degraded,
                           timeout=5.0)
        # degraded = free-running: full local width restored
        assert rt1.sched.slot_target() == 4
        assert rt2.sched.slot_target() == 4
        # and the runtimes still run work (no hang, no poisoned state)
        job = Job("after")
        t = rt1.create(lambda: time.sleep(0.01), job=job)
        assert rt1.join(t, timeout=30.0)
        # lease ops now fail loudly instead of hanging
        with pytest.raises(OSError):
            c1.resize(2.0)
    finally:
        c1.stop()
        c2.stop()
        rt1.shutdown(timeout=5.0)
        rt2.shutdown(timeout=5.0)
        if proc.is_alive():
            proc.kill()


def test_malformed_message_drops_sender_not_broker(broker):
    """A buggy client (well-framed message, garbage fields) costs ITSELF
    the connection; the broker loop and sibling coordination survive."""
    survivor = BrokerClient(broker.path, name="survivor", share=1.0,
                            slots=4, heartbeat_interval=0.1).start()
    try:
        bad = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        bad.connect(broker.path)
        send_msg(bad, {"op": "register", "name": "bad", "share": 1.0,
                       "slots": 4, "pid": 0})
        assert recv_msg(bad)["op"] == "welcome"
        assert recv_msg(bad)["op"] == "grant"
        assert _wait_until(lambda: survivor.granted == 2)

        send_msg(bad, {"op": "rescale"})  # missing "scale": KeyError-bait
        # the offender is dropped and its lease reclaimed...
        assert _wait_until(lambda: survivor.granted == 4, timeout=3.0)
        assert list(broker.snapshot()["workers"]) == ["survivor"]
        # ...and the broker still serves new registrations (loop alive)
        late = BrokerClient(broker.path, name="late", share=1.0, slots=4,
                            heartbeat_interval=0.1).start()
        assert late.wait_grant(5.0) == 2
        late.stop()
        bad.close()
    finally:
        survivor.stop()


def test_second_broker_refuses_to_hijack_live_path(broker):
    """A broker never steals a rendezvous path a LIVE broker serves (two
    runs sharing the per-user default path must fail fast, not silently
    split the lease table); a stale socket file IS reclaimed."""
    from repro.ipc.broker import BrokerError

    with pytest.raises(BrokerError, match="already serving"):
        NodeBroker(broker.path, capacity=4).start()
    # the live broker kept working through the probe
    c = BrokerClient(broker.path, name="w0", slots=4,
                     heartbeat_interval=0.1).start()
    assert c.wait_grant(5.0) == 4
    c.stop()

    # stale socket (dead broker left the file): reclaimed cleanly
    path = _path()
    b1 = NodeBroker(path, capacity=2, heartbeat_timeout=0.6)
    b1.start()
    b1.stop()
    open(path, "a").close() if not os.path.exists(path) else None
    # recreate a dead socket file the unlink-on-stop may have removed
    import socket as _s

    s = _s.socket(_s.AF_UNIX, _s.SOCK_STREAM)
    try:
        s.bind(path)
    except OSError:
        pass
    s.close()  # bound then closed: file exists, nobody listens
    b2 = NodeBroker(path, capacity=2, heartbeat_timeout=0.6)
    b2.start()
    c = BrokerClient(path, name="w0", slots=2,
                     heartbeat_interval=0.1).start()
    assert c.wait_grant(5.0) == 2
    c.stop()
    b2.stop()


def test_send_failure_during_stop_is_not_a_degrade(broker):
    """A deregister/lease-op send failing while stop() is underway is an
    intentional shutdown, not a broker loss: no degraded flag, no
    on_disconnect callback, no width restore. (White-box: the stop event
    is raised first, exactly as stop() does, because a killed broker's
    EOF otherwise reaches the recv thread instantly and wins any timing
    race.)"""
    events = []
    c = BrokerClient(broker.path, name="w0", slots=4,
                     heartbeat_interval=10.0,
                     on_disconnect=lambda: events.append("lost"))
    c.start()
    assert c.wait_grant(5.0) == 4
    c._stop_evt.set()        # stop() has begun...
    c._sock.close()          # ...and the broker-side socket is gone
    with pytest.raises(OSError):
        c._send({"op": "deregister"})
    assert c.degraded is False
    assert events == []
    c.stop()                 # idempotent clean finish
    assert c.degraded is False


def test_snapshot_disambiguates_duplicate_worker_names(broker):
    c1 = BrokerClient(broker.path, name="worker", slots=4,
                      heartbeat_interval=0.1).start()
    c2 = BrokerClient(broker.path, name="worker", slots=4,
                      heartbeat_interval=0.1).start()
    try:
        assert _wait_until(lambda: c1.granted == 2 and c2.granted == 2)
        workers = broker.snapshot()["workers"]
        assert len(workers) == 2  # no lease silently collapsed
        assert sum(w["granted"] for w in workers.values()) == 4
    finally:
        c1.stop()
        c2.stop()


def test_explicit_zero_share_is_best_effort_not_default(broker):
    """share=0.0 must reach the broker as zero (best-effort worker), not
    be coerced to the 1.0 default: it yields to weighted siblings and
    only borrows what they cannot use."""
    best_effort = BrokerClient(broker.path, name="be", share=0.0, slots=4,
                               heartbeat_interval=0.1).start()
    weighted = BrokerClient(broker.path, name="wt", share=1.0, slots=3,
                            heartbeat_interval=0.1).start()
    try:
        # weighted takes its full demand (3); the zero-share worker only
        # borrows the slot nobody with a lease wants
        assert _wait_until(lambda: weighted.granted == 3
                           and best_effort.granted == 1)
        snap = broker.snapshot()
        assert snap["workers"]["be"]["share"] == 0.0
        assert snap["workers"]["be"]["quota"] == 0
    finally:
        best_effort.stop()
        weighted.stop()


def test_client_start_against_missing_broker_raises():
    """No broker at the path: connect fails fast (the caller decides to
    run free), it does not hang."""
    with pytest.raises(OSError):
        BrokerClient(_path(), name="w0").start(connect_timeout=1.0)


# --------------------------------------------------------------------- #
# self-healing: reconnect, broker restart, epoch fencing (PR 6)
# --------------------------------------------------------------------- #
def test_start_retries_until_broker_appears():
    """start() no longer races broker startup: the initial connect
    retries with the backoff helper inside the connect_timeout deadline,
    so a worker launched before its broker settles instead of raising."""
    path = _path()
    res = {}

    def connect():
        c = BrokerClient(path, name="early", slots=4,
                         heartbeat_interval=0.1,
                         reconnect_backoff=(0.05, 0.2))
        try:
            c.start(connect_timeout=15.0)
            res["grant"] = c.wait_grant(5.0)
        finally:
            c.stop()

    t = threading.Thread(target=connect)
    t.start()
    time.sleep(0.4)  # the client is already in its retry loop
    b = NodeBroker(path, capacity=4, heartbeat_timeout=0.6)
    b.start()
    try:
        t.join(30.0)
        assert not t.is_alive()
        assert res.get("grant") == 4
    finally:
        b.stop()


def test_broker_restart_workers_rejoin_shares_preserved():
    """End-to-end heal: kill the broker -> workers degrade to full local
    width immediately -> restart a broker on the same rendezvous path ->
    workers re-register on their own and re-coordinate, shares (including
    a lease op queued during the outage) preserved, under a fresh
    incarnation — the lease table is rebuilt purely from
    re-registrations."""
    from repro.ipc import BrokerLostError

    path = _path()
    b1 = NodeBroker(path, capacity=4, heartbeat_timeout=0.6)
    b1.start()
    rt = UsfRuntime(Topology(4, 1), SchedCoop())
    c1 = BrokerClient(path, name="w1", share=1.0, slots=4,
                      heartbeat_interval=0.1,
                      reconnect_backoff=(0.02, 0.2)).bind(rt).start()
    c2 = BrokerClient(path, name="w2", share=3.0, slots=4,
                      heartbeat_interval=0.1,
                      reconnect_backoff=(0.02, 0.2)).start()
    b2 = None
    try:
        assert _wait_until(lambda: c1.granted == 1 and c2.granted == 3)
        assert rt.sched.slot_target() == 1
        inc1 = c1.incarnation
        assert inc1 == b1.incarnation

        b1.stop()  # the coordinator vanishes (EOF to every worker)
        assert _wait_until(lambda: c1.degraded and c2.degraded, timeout=5.0)
        assert rt.sched.slot_target() == 4  # free-running immediately
        assert c1.state in (BrokerClient.DEGRADED, BrokerClient.RECONNECTING)
        # lease ops fail TYPED during the outage — and the share change
        # is queued: the re-registration below carries share=2.0
        with pytest.raises(BrokerLostError) as ei:
            c1.resize(2.0)
        assert ei.value.client_name == "w1"
        assert ei.value.degraded is True
        assert c1.share == 2.0

        b2 = NodeBroker(path, capacity=4, heartbeat_timeout=0.6)
        b2.start()
        # workers rejoin on their own: apportion(4, [2.0, 3.0]) = [2, 2]
        assert _wait_until(lambda: c1.state == BrokerClient.COORDINATED
                           and c2.state == BrokerClient.COORDINATED,
                           timeout=10.0)
        assert _wait_until(lambda: c1.granted == 2 and c2.granted == 2,
                           timeout=10.0)
        assert _wait_until(lambda: rt.sched.slot_target() == 2, timeout=5.0)
        assert not c1.degraded and not c2.degraded
        # >= 1: the immediate first retry can land in the dying broker's
        # accept backlog and count a spurious bounce before the real rejoin
        assert c1.reconnects >= 1 and c2.reconnects >= 1
        assert c1.incarnation == b2.incarnation != inc1
        snap = b2.snapshot()
        assert sorted(snap["workers"]) == ["w1", "w2"]
        assert snap["workers"]["w1"]["share"] == 2.0
    finally:
        c1.stop()
        c2.stop()
        rt.shutdown(timeout=5.0)
        if b2 is not None:
            b2.stop()


def test_reordered_grant_pair_is_fenced(broker):
    """Satellite regression: a grant delivered out of order (via the
    fault layer's reorder) is DROPPED by the monotonic (incarnation,
    epoch) guard instead of rolling the worker back to a stale width."""
    from repro.ipc import FaultPlan

    # near-silent heartbeats: the only traffic is regrant-driven, so the
    # reordered pair below is exactly the two membership regrants
    c = BrokerClient(broker.path, name="w0", slots=4,
                     heartbeat_interval=60.0).start()
    sib = None
    try:
        assert c.wait_grant(5.0) == 4
        plan = FaultPlan(seed=7, reorder_recv=1.0, horizon=1)
        c._faults = plan
        # grant A (sibling registers: c -> 2 slots) is held by the plan;
        # grant B (sibling resize: c -> 1 slot) releases it -> [B, A]
        sib = BrokerClient(broker.path, name="w1", slots=4,
                           heartbeat_interval=60.0).start()
        assert sib.wait_grant(5.0) is not None
        sib.resize(3.0)
        assert _wait_until(lambda: c.stale_grants_dropped >= 1, timeout=5.0)
        assert plan.injected["reorder_recv"] == 1
        # the newest grant (1 slot) won; the stale one could not shrink
        # nor regrow the worker after the fact
        assert c.granted == 1
        assert c.grant_epoch == broker.snapshot()["epoch"]
    finally:
        c.stop()
        if sib is not None:
            sib.stop()


# --------------------------------------------------------------------- #
# demand-aware apportionment: live backlog feedback (PR 9)
# --------------------------------------------------------------------- #
@pytest.fixture
def demand_broker():
    """Fast demand knobs so tests see regrants within a few heartbeats."""
    b = NodeBroker(_path(), capacity=4, heartbeat_timeout=0.6,
                   demand_beats=2, min_regrant_interval=0.0)
    b.start()
    yield b
    b.stop()


def test_idle_worker_slots_flow_to_saturated_sibling(demand_broker):
    """THE idle-worker lease bug, fixed end to end: a registered-but-idle
    worker (backlog 0) no longer pins half the node. Its lease drains to
    the saturated sibling, while the idle worker itself keeps making
    progress on the client-side 1-slot floor."""
    rt = UsfRuntime(Topology(4, 1), SchedCoop())
    idle = BrokerClient(demand_broker.path, name="idle", share=1.0,
                        heartbeat_interval=0.05,
                        backlog_probe=lambda: 0).bind(rt).start()
    sat = BrokerClient(demand_broker.path, name="sat", share=1.0, slots=4,
                       heartbeat_interval=0.05,
                       backlog_probe=lambda: 8).start()
    try:
        assert idle.wait_grant(5.0) is not None
        # pre-fix this converged to 2/2 forever (want floored at 1 at
        # registration, demand static): now the idle half flows over
        assert _wait_until(lambda: sat.granted == 4 and idle.granted == 0)
        snap = demand_broker.snapshot()
        assert snap["workers"]["idle"]["eff_want"] == 0
        assert snap["workers"]["idle"]["backlog"] == 0
        assert snap["workers"]["sat"]["eff_want"] == 4
        # the zero grant lands as a 1-slot floor, not a stall: the idle
        # worker still runs (throttled, never deadlocked)
        assert rt.sched.slot_target() == 1
        done = []
        t = rt.create(lambda: done.append(1), job=Job("floor"))
        assert rt.join(t, timeout=30.0)
        assert done == [1]
    finally:
        idle.stop()
        sat.stop()
        rt.shutdown(timeout=5.0)


def test_backlog_rise_reclaims_width_from_idle_state(demand_broker):
    """The other half of the phase shift: when the idle worker's backlog
    rises, the broker regrants width back (symmetric, no ratchet)."""
    backlog = {"idle": 0}
    idle = BrokerClient(demand_broker.path, name="idle", share=1.0, slots=4,
                        heartbeat_interval=0.05,
                        backlog_probe=lambda: backlog["idle"]).start()
    sat = BrokerClient(demand_broker.path, name="sat", share=1.0, slots=4,
                       heartbeat_interval=0.05,
                       backlog_probe=lambda: 8).start()
    try:
        assert _wait_until(lambda: sat.granted == 4 and idle.granted == 0)
        backlog["idle"] = 8  # the phase shift: idle worker saturates
        assert _wait_until(lambda: idle.granted == 2 and sat.granted == 2)
    finally:
        idle.stop()
        sat.stop()


def test_want_zero_registration_is_legal(demand_broker):
    """slots=0 must reach the broker as zero demand (was floored to 1 at
    register/re-register/resize — the bug's third head): the zero-want
    worker holds a lease but no slots, and the sibling takes the node."""
    zero = BrokerClient(demand_broker.path, name="zero", share=1.0, slots=0,
                        heartbeat_interval=0.05).start()
    busy = BrokerClient(demand_broker.path, name="busy", share=1.0, slots=4,
                        heartbeat_interval=0.05).start()
    try:
        assert _wait_until(lambda: busy.granted == 4 and zero.granted == 0)
        snap = demand_broker.snapshot()
        assert snap["workers"]["zero"]["want"] == 0
        assert snap["workers"]["zero"]["eff_want"] == 0
    finally:
        zero.stop()
        busy.stop()


def test_v1_client_without_backlog_keeps_static_demand(demand_broker):
    """Backward compatibility: a client that never reports backlog
    (report_backlog=False — the v1 wire contract) keeps its static
    registration width as effective want, even sitting fully idle next
    to a saturated demand-reporting sibling."""
    rt = UsfRuntime(Topology(4, 1), SchedCoop())  # idle: backlog would be 0
    v1 = BrokerClient(demand_broker.path, name="v1", share=1.0,
                      heartbeat_interval=0.05,
                      report_backlog=False).bind(rt).start()
    sat = BrokerClient(demand_broker.path, name="sat", share=1.0, slots=4,
                       heartbeat_interval=0.05,
                       backlog_probe=lambda: 8).start()
    try:
        assert _wait_until(lambda: v1.granted == 2 and sat.granted == 2)
        time.sleep(0.5)  # many damping windows: a v1 lease must not decay
        assert v1.granted == 2 and sat.granted == 2
        assert demand_broker.snapshot()["workers"]["v1"]["backlog"] is None
    finally:
        v1.stop()
        sat.stop()
        rt.shutdown(timeout=5.0)


def test_steady_backlog_quiesces_regrant_pushes(demand_broker):
    """Acceptance: a steady workload with constant backlog causes ZERO
    regrant pushes after convergence, and a content-neutral recompute
    (same-share resize) is suppressed by the grant dedupe instead of
    re-pushed."""
    c1 = BrokerClient(demand_broker.path, name="w1", share=1.0, slots=4,
                      heartbeat_interval=0.05,
                      backlog_probe=lambda: 4).start()
    c2 = BrokerClient(demand_broker.path, name="w2", share=1.0, slots=4,
                      heartbeat_interval=0.05,
                      backlog_probe=lambda: 4).start()
    try:
        assert _wait_until(lambda: c1.granted == 2 and c2.granted == 2)
        before = demand_broker.snapshot()
        time.sleep(0.5)  # ~10 heartbeats per client at constant backlog
        after = demand_broker.snapshot()
        assert after["grants_pushed"] == before["grants_pushed"]
        assert after["demand_regrants"] == before["demand_regrants"]
        assert after["epoch"] == before["epoch"]

        # a regrant pass whose outcome is unchanged pushes nothing: the
        # dedupe counts both suppressions, the epoch does not burn
        c1.resize(1.0)
        assert _wait_until(
            lambda: demand_broker.snapshot()["grants_suppressed"]
            >= after["grants_suppressed"] + 2)
        final = demand_broker.snapshot()
        assert final["grants_pushed"] == after["grants_pushed"]
        assert final["epoch"] == after["epoch"]
        assert c1.granted == 2 and c2.granted == 2
    finally:
        c1.stop()
        c2.stop()


def test_failing_backlog_probe_degrades_to_static(demand_broker):
    """A probe that raises must not kill the heartbeat thread or the
    lease: the client beats without the field (v1 semantics) and stays
    coordinated."""
    def bad_probe():
        raise RuntimeError("probe exploded")

    c = BrokerClient(demand_broker.path, name="w0", share=1.0, slots=4,
                     heartbeat_interval=0.05, backlog_probe=bad_probe)
    c.start()
    try:
        assert c.wait_grant(5.0) == 4
        time.sleep(0.3)  # several beats, every probe call raising
        assert c.granted == 4
        assert c.state == BrokerClient.COORDINATED
        assert c.last_backlog is None
        snap = demand_broker.snapshot()
        assert snap["workers"]["w0"]["backlog"] is None
        assert snap["workers"]["w0"]["eff_want"] == 4
    finally:
        c.stop()


def test_legacy_terminal_degrade_still_available():
    """reconnect=False restores the PR 5 contract: a broker loss is a
    terminal free-running degrade — no reconnect attempts ever."""
    path = _path()
    b = NodeBroker(path, capacity=4, heartbeat_timeout=0.6)
    b.start()
    c = BrokerClient(path, name="w0", slots=4, heartbeat_interval=0.1,
                     reconnect=False).start()
    b2 = None
    try:
        assert c.wait_grant(5.0) == 4
        b.stop()
        assert _wait_until(lambda: c.degraded, timeout=5.0)
        b2 = NodeBroker(path, capacity=4, heartbeat_timeout=0.6)
        b2.start()
        time.sleep(1.0)  # ample time a reconnecting client would need
        assert c.degraded and c.reconnects == 0
        assert c.state == BrokerClient.DEGRADED
        assert len(b2.snapshot()["workers"]) == 0
    finally:
        c.stop()
        if b2 is not None:
            b2.stop()
