"""Real-thread preemption engine + live task migration tests.

Covers the four layers of the tick-driver/migration refactor:

* **Policy**: ``remove()`` keeps the incremental EEVDF sums consistent
  (locksteped against ``RefFair``, the executable spec); job-filtered
  picks restrict grants to allowed jobs.
* **Arbiter**: ``attach`` with READY/RUNNING tasks re-homes them live with
  no lost or duplicated dispatches (seeded property sweep, dispatch-count
  instrumented); per-job leases are enforced inside the default group.
* **Scheduler**: ``request_preempt`` marks need-resched; the next
  scheduling point / explicit checkpoint consumes it exactly once.
* **Executor**: the watchdog tick driver preempts real threads running
  preemptive-policy tasks, lands ``lease.resize()`` reclaim within a tick
  period, never ticks SCHED_COOP tasks, and absorbs timed wakeups
  (``sleep``/timeouts) without spawning per-call Timer threads.
"""

import random
import threading
import time
from collections import Counter

import pytest

from repro.core import simtask as st
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair, SchedRR
from repro.core.policies.base import StopReason
from repro.core.scheduler import Scheduler
from repro.core.task import Job, Task, TaskState
from repro.core.threads import UsfRuntime
from repro.core.topology import Topology

from tests.test_sched_fastpath import RefFair

# a watchdog tick period generous enough for noisy CI thread wakeups, and
# a latency bound of a few periods — far below the no-preemption
# alternative (spinners never yield, so reclaim latency would be infinite)
TICK = 0.05
RECLAIM_BOUND = 8 * TICK


# --------------------------------------------------------------------- #
# policy layer: remove() + filtered picks
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(10))
def test_sched_fair_remove_lockstep_vs_reffair(seed):
    """Random on_ready/pick/remove/on_stop traces: the incremental
    SchedFair and the brute-force RefFair must stay bit-identical in pick
    order, pool size, min_vruntime AND pool virtual time after removes."""
    rng = random.Random(seed)
    n_slots = rng.randint(1, 6)
    jobs = [Job(f"rm{seed}-{i}", nice=rng.choice([0, 0, 5, -5]))
            for i in range(3)]
    tasks = [Task(jobs[i % 3]) for i in range(rng.randint(4, 32))]
    ref, new = RefFair(slice_s=0.002), SchedFair(slice_s=0.002)
    ref.remove = lambda t: ref._ready.remove(t)  # list spec of remove()
    now = 0.0
    queued: list[Task] = []
    running: dict[int, tuple[Task, int]] = {}
    for step in range(400):
        act = rng.random()
        if act < 0.35 and len(queued) + len(running) < len(tasks):
            cand = [t for t in tasks
                    if t not in queued and t.tid not in running]
            t = rng.choice(cand)
            t.last_slot = rng.choice([None] + list(range(n_slots)))
            ref.on_ready(t)
            new.on_ready(t)
            queued.append(t)
        elif act < 0.5 and queued:  # the migration path under test
            t = rng.choice(queued)
            queued.remove(t)
            ref.remove(t)
            new.remove(t)
            with pytest.raises(KeyError):
                new.remove(t)  # double-remove must be refused
        elif act < 0.8 and queued:
            slot = rng.randrange(n_slots)
            a, b = ref.pick(slot), new.pick(slot)
            assert a is b, f"step {step}: ref {a} vs new {b}"
            queued.remove(a)
            running[a.tid] = (a, slot)
            ref.on_run(a, slot, now)
            new.on_run(a, slot, now)
        elif running:
            tid = rng.choice(sorted(running))
            t, slot = running.pop(tid)
            elapsed = rng.uniform(1e-4, 1e-2)
            now += elapsed
            t.last_slot = slot
            ref.on_stop(t, slot, now, elapsed, StopReason.BLOCK)
            new.on_stop(t, slot, now, elapsed, StopReason.BLOCK)
        assert ref.ready_count() == new.ready_count()
        assert ref._min_vruntime == new._min_vruntime
        if new.ready_count():
            # incremental pool sums survive removes (the I5 grant inputs)
            assert ref._pool_virtual_time() == pytest.approx(
                new._wvsum / new._wsum, abs=1e-9)
    for job in jobs:
        got = new.ready_count_of(job)
        want = sum(1 for t in queued if t.job is job)
        assert got == want


@pytest.mark.parametrize("polname", ["coop", "fair", "rr"])
def test_pick_filtered_only_returns_allowed_jobs(polname):
    from types import SimpleNamespace

    pol = {"coop": lambda: SchedCoop(quantum=1.0),
           "fair": lambda: SchedFair(slice_s=0.002),
           "rr": lambda: SchedRR(quantum=0.01)}[polname]()
    pol.attach(SimpleNamespace(topology=Topology(4, 1)))
    job_a, job_b = Job("allowed"), Job("denied")
    tasks = [Task(job_a if i % 2 == 0 else job_b) for i in range(12)]
    for i, t in enumerate(tasks):
        t.last_slot = None if i % 3 == 0 else i % 4
        pol.on_ready(t)
    allowed = {job_a.jid}
    got = []
    while True:
        t = pol.pick_filtered(0, allowed)
        if t is None:
            break
        got.append(t)
    assert sorted(t.tid for t in got) == sorted(
        t.tid for t in tasks if t.job is job_a)
    assert pol.ready_count_of(job_a) == 0
    assert pol.ready_count_of(job_b) == 6
    # the denied job's tasks are all still pickable afterwards
    rest = [pol.pick(0) for _ in range(6)]
    assert all(t is not None and t.job is job_b for t in rest)
    assert pol.ready_count() == 0


def test_remove_unknown_task_raises():
    for pol in (SchedCoop(), SchedFair(), SchedRR()):
        with pytest.raises(KeyError):
            pol.remove(Task(Job("ghost")))


# --------------------------------------------------------------------- #
# scheduler layer: request_preempt / consume_preempt
# --------------------------------------------------------------------- #
def _manual_sched(n_slots=1, policy=None):
    clock = {"now": 0.0}
    dispatched = []
    sched = Scheduler(
        Topology(n_slots, 1), policy or SchedFair(slice_s=0.003),
        clock=lambda: clock["now"],
        dispatch=lambda t, s: dispatched.append((t, s)),
    )
    return sched, clock, dispatched


def test_request_preempt_consumed_at_checkpoint_exactly_once():
    sched, clock, dispatched = _manual_sched()
    job = Job("p")
    t1, t2 = Task(job), Task(job)
    sched.submit(t1)
    sched.submit(t2)
    assert dispatched == [(t1, 0)]
    assert not sched.preempt_requested(t1)
    assert not sched.consume_preempt(t1)  # no pending request: no-op
    assert sched.request_preempt(0)
    assert sched.preempt_requested(t1)
    clock["now"] += 0.01
    assert sched.consume_preempt(t1)  # converts into a preempt + swap
    assert t1.stats.preemptions == 1
    assert t1.state is TaskState.READY
    assert dispatched[-1] == (t2, 0)
    assert not sched.consume_preempt(t2)  # flag cleared by the swap
    assert sched.request_preempt(0)
    clock["now"] += 0.01
    sched.block(t2)  # a natural scheduling point also satisfies it
    assert t2.stats.preemptions == 0
    assert dispatched[-1] == (t1, 0)
    assert not sched.preempt_requested(t1)


def test_request_preempt_idle_slot_is_refused():
    sched, _, _ = _manual_sched()
    assert not sched.request_preempt(0)


def test_consume_preempt_cooperative_task_yields_not_preempts():
    """A user checkpoint in a SCHED_COOP task converts a (spurious)
    request into a voluntary yield — I2: no preemption is recorded."""
    sched, clock, dispatched = _manual_sched(policy=SchedCoop())
    job = Job("c")
    t1, t2 = Task(job), Task(job)
    sched.submit(t1)
    sched.submit(t2)
    assert sched.request_preempt(0)
    clock["now"] += 0.01
    assert sched.consume_preempt(t1)
    assert t1.stats.preemptions == 0
    assert t1.stats.yields == 1
    assert dispatched[-1] == (t2, 0)


# --------------------------------------------------------------------- #
# arbiter layer: live re-homing, exactly-once dispatches
# --------------------------------------------------------------------- #
def _instrument_dispatches(sim) -> Counter:
    counts: Counter = Counter()
    orig = sim.sched._dispatch_cb

    def wrapped(task, slot_id):
        counts[task.tid] += 1
        orig(task, slot_id)

    sim.sched._dispatch_cb = wrapped
    return counts


def _prog_body(rng):
    prog = [(rng.choice(("compute", "sleep", "yield")),
             rng.uniform(5e-4, 6e-3))
            for _ in range(rng.randint(2, 6))]

    def gen():
        for kind, v in prog:
            if kind == "compute":
                yield st.compute(v)
            elif kind == "sleep":
                yield st.sleep(v)
            else:
                yield st.yield_()

    return gen


@pytest.mark.parametrize("seed", range(10))
def test_live_rehoming_exactly_once_property(seed):
    """Seeded mixed-policy workloads with a mid-run attach of a busy job:
    every task completes, and the executor saw exactly
    ``task.stats.dispatches`` dispatch callbacks per task — no dispatch is
    lost (a lost one deadlocks the sim) and none is duplicated (I1 would
    trip, and the instrumented counts would diverge)."""
    rng = random.Random(seed)
    n_slots = rng.choice((2, 4, 8))
    sim = SimExecutor(Topology(n_slots, 1), SchedCoop(quantum=0.01),
                      max_time=600.0)
    counts = _instrument_dispatches(sim)
    mover = Job(f"mover{seed}")
    others = [Job(f"bg{seed}-{i}") for i in range(rng.randint(1, 2))]
    tasks = []
    for _ in range(rng.randint(3, 3 * n_slots)):
        tasks.append(sim.spawn(mover, _prog_body(rng)))
    for job in others:
        for _ in range(rng.randint(1, n_slots)):
            tasks.append(sim.spawn(job, _prog_body(rng)))
    policy = rng.choice((
        lambda: SchedCoop(quantum=0.01),
        lambda: SchedFair(slice_s=0.002),
        lambda: SchedRR(quantum=0.002),
    ))()
    at = rng.uniform(0.0, 0.01)

    sim.run(until=at)  # mover now has a mix of READY/RUNNING/BLOCKED tasks
    ready_before = sum(1 for t in mover.tasks if t.state is TaskState.READY)
    lease = sim.attach(mover, policy=policy, share=rng.choice((1.0, 3.0)))
    assert lease.group.dedicated
    # the withdrawn READY tasks moved wholesale into the new policy
    assert policy.ready_count_of(mover) == ready_before
    assert sim.sched.policy_of(mover) is policy
    sim.run()

    assert all(t.done for t in tasks), f"seed {seed}: lost dispatches"
    for t in tasks:
        assert counts[t.tid] == t.stats.dispatches, (
            f"seed {seed}: task {t.tid} saw {counts[t.tid]} executor "
            f"dispatches vs {t.stats.dispatches} accounted")
    if not policy.preemptive:
        assert sum(t.stats.preemptions for t in mover.tasks) == 0  # I2


def test_live_rehoming_deterministic():
    def run_once():
        sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01),
                          max_time=600.0)
        rng = random.Random(77)
        mover, bg = Job("mover"), Job("bg")
        tasks = [sim.spawn(mover, _prog_body(rng)) for _ in range(6)]
        tasks += [sim.spawn(bg, _prog_body(rng)) for _ in range(4)]
        sim.run(until=0.004)
        sim.attach(mover, policy=SchedFair(slice_s=0.002), share=2.0)
        s = sim.run()
        return (s.makespan, s.dispatches, s.preemptions, s.migrations,
                round(mover.service_time, 9))

    assert run_once() == run_once()


def test_rehomed_running_task_gets_ticks_in_sim():
    """A RUNNING task migrated under a preemptive policy must become
    preemptible immediately (ticks armed at attach, not next dispatch)."""
    sim = SimExecutor(Topology(1, 1), SchedCoop(quantum=0.01), max_time=600.0)
    mover, other = Job("mover"), Job("other")

    def long_compute():
        yield st.compute(0.5)

    t1 = sim.spawn(mover, long_compute)
    sim.run(until=0.001)  # t1 is mid-compute on the only slot
    assert t1.state is TaskState.RUNNING
    # after these attaches the mover is an over-lease borrower (quota 0,
    # in_use 1) and `other` holds the slot's lease with ready work: the
    # lease-revocation tick must kick t1 off mid-compute
    sim.attach(mover, policy=SchedFair(slice_s=0.002), share=1.0)
    sim.attach(other, policy=SchedFair(slice_s=0.002), share=3.0)
    t2 = sim.spawn(other, long_compute)
    sim.run()
    assert t1.done and t2.done
    # without the attach-time arm, t1's 0.5s compute would finish untouched
    assert t1.stats.preemptions > 0
    # interleaving: t2 first ran long before t1's compute could have ended
    assert t2.stats.first_run_at < 0.1


def test_rehomed_running_task_is_slice_preempted():
    """Regression: migration must register RUNNING tasks with the new
    policy (on_run), or a preemptive policy can never slice-expire them —
    a same-job sibling would starve behind an unpreemptible migrant."""
    sim = SimExecutor(Topology(1, 1), SchedCoop(quantum=0.01), max_time=600.0)
    job = Job("mover")

    def long_compute():
        yield st.compute(0.5)

    t1 = sim.spawn(job, long_compute)
    t2 = sim.spawn(job, long_compute)  # queued behind t1 on the only slot
    sim.run(until=0.001)
    assert t1.state is TaskState.RUNNING
    sim.attach(job, policy=SchedFair(slice_s=0.002), share=1.0)
    sim.run()
    assert t1.done and t2.done
    # slice expiry (not lease revocation — the job is within quota) must
    # interleave the two: t1 gets preempted, t2 starts within a few slices
    assert t1.stats.preemptions > 0
    assert t2.stats.first_run_at < 0.1


@pytest.mark.parametrize("seed", range(8))
def test_any_to_any_migration_exactly_once_property(seed):
    """Seeded promote → live policy swap → demote chain on a busy job:
    every edge re-homes without losing or duplicating a dispatch, and the
    READY pool moves wholesale at each hop."""
    rng = random.Random(7000 + seed)
    n_slots = rng.choice((1, 2, 4))
    sim = SimExecutor(Topology(n_slots, 1), SchedCoop(quantum=0.01),
                      max_time=600.0)
    counts = _instrument_dispatches(sim)
    mover, bg = Job(f"anymover{seed}"), Job(f"anybg{seed}")
    tasks = [sim.spawn(mover, _prog_body(rng))
             for _ in range(rng.randint(3, 2 * n_slots + 2))]
    tasks += [sim.spawn(bg, _prog_body(rng))
              for _ in range(rng.randint(1, n_slots))]

    def hop(move):
        sim.run(until=sim.now() + rng.uniform(0.001, 0.004))
        ready_before = sum(1 for t in mover.tasks
                           if t.state is TaskState.READY)
        move()
        pol = sim.sched.policy_of(mover)
        assert pol.ready_count_of(mover) == ready_before, (
            f"seed {seed}: READY pool not moved wholesale")

    first = rng.choice((lambda: SchedFair(slice_s=0.002),
                        lambda: SchedCoop(quantum=0.01)))()
    second = rng.choice((lambda: SchedRR(quantum=0.002),
                         lambda: SchedFair(slice_s=0.002),
                         lambda: SchedCoop(quantum=0.01)))()
    hop(lambda: sim.attach(mover, policy=first, share=1.0))      # promote
    hop(lambda: sim.attach(mover, policy=second, share=2.0))     # swap
    assert sim.sched.policy_of(mover) is second
    hop(lambda: sim.demote(mover))                               # demote
    assert not mover.lease.group.dedicated
    sim.run()
    assert all(t.done for t in tasks), f"seed {seed}: lost dispatches"
    for t in tasks:
        assert counts[t.tid] == t.stats.dispatches, (
            f"seed {seed}: task {t.tid} saw {counts[t.tid]} executor "
            f"dispatches vs {t.stats.dispatches} accounted")


def test_resize_of_superseded_lease_raises():
    """A live swap/demote supersedes the job's SlotLease object: resizing
    the dead one must raise, not silently write a share nothing reads."""
    from repro.core.arbiter import ArbiterError

    sim = SimExecutor(Topology(2, 1), SchedCoop(quantum=0.01), max_time=600.0)
    job = Job("stale")
    old_lease = sim.attach(job, policy=SchedFair(slice_s=0.002), share=1.0)
    new_lease = sim.attach(job, policy=SchedRR(quantum=0.002), share=1.0)
    assert new_lease is not old_lease
    with pytest.raises(ArbiterError, match="superseded"):
        old_lease.resize(4.0)
    new_lease.resize(4.0)  # the live lease still resizes fine
    assert new_lease.share == 4.0


def test_sim_swap_to_shorter_slice_supersedes_pending_tick():
    """Sim twin of the watchdog class-migration semantics: a pending
    long-interval tick (old policy) must not delay slicing after a live
    swap to a short-slice policy — the earlier re-arm wins."""
    sim = SimExecutor(Topology(1, 1), SchedCoop(quantum=0.01), max_time=600.0)
    job = Job("tickswap")

    def long_compute():
        yield st.compute(0.5)

    t1 = sim.spawn(job, long_compute)
    t2 = sim.spawn(job, long_compute)
    sim.attach(job, policy=SchedRR(quantum=10.0), share=1.0)
    sim.run(until=0.001)  # t1 RUNNING with a tick pending at ~10s
    assert t1.state is TaskState.RUNNING
    sim.attach(job, policy=SchedFair(slice_s=0.002), share=1.0)  # live swap
    sim.run(until=0.1)
    # without supersede, the first tick under the new policy fires at 10s
    # and t2 starves behind the old quantum
    assert t1.stats.preemptions > 0
    assert t2.stats.first_run_at is not None and t2.stats.first_run_at < 0.1
    sim.run()
    assert t1.done and t2.done


def test_swap_to_preemptive_slices_rehomed_running_task():
    """dedicated-coop → dedicated-fair live swap: the RUNNING migrant
    becomes slice-preemptible under the NEW policy (ticks re-armed at the
    swap, fresh slice started)."""
    sim = SimExecutor(Topology(1, 1), SchedCoop(quantum=0.01), max_time=600.0)
    job = Job("swapmover")

    def long_compute():
        yield st.compute(0.5)

    t1 = sim.spawn(job, long_compute)
    t2 = sim.spawn(job, long_compute)
    sim.attach(job, policy=SchedCoop(quantum=0.01), share=1.0)
    sim.run(until=0.001)
    assert t1.state is TaskState.RUNNING
    sim.attach(job, policy=SchedFair(slice_s=0.002), share=1.0)  # live swap
    sim.run()
    assert t1.done and t2.done
    assert t1.stats.preemptions > 0  # sliced under the swapped-in policy
    assert t2.stats.first_run_at < 0.1


def test_rehomed_running_task_gets_fresh_slice_accounting():
    """Migration restarts the slot's slice clock: the pre-migration run
    time is charged to the task at the hop, so the new policy's first
    on_stop sees only post-migration elapsed time — and no run time is
    lost or double-counted end to end."""
    sim = SimExecutor(Topology(1, 1), SchedCoop(quantum=0.01), max_time=600.0)
    job = Job("slicemover")

    def body():
        yield st.compute(0.02)

    t = sim.spawn(job, body)
    sim.run(until=0.01)  # mid-compute
    assert t.state is TaskState.RUNNING
    run_before = t.stats.run_time
    sim.attach(job, policy=SchedFair(slice_s=0.05), share=1.0)
    # the hop charged the accrued segment and restarted the slice clock
    assert t.stats.run_time > run_before
    st_slot = sim.sched._slots[t.slot]
    assert st_slot.run_started == pytest.approx(sim.now())
    charged_at_hop = t.stats.run_time
    sim.run()
    assert t.done
    # conservation: total accounted run time is the requested compute
    # (plus nothing double-counted at the hop)
    assert t.stats.run_time == pytest.approx(
        0.02 + sim.costs.ctx_switch + sim.costs.dispatch_latency, abs=1e-9)
    assert t.stats.run_time >= charged_at_hop


def test_demote_rehomes_busy_job_and_default_multiplexes():
    """A busy dedicated job demotes live into the default group: queued
    work lands in the default policy exactly once and keeps completing
    alongside the incumbent default-group jobs."""
    sim = SimExecutor(Topology(2, 1), SchedCoop(quantum=0.01), max_time=600.0)
    rng = random.Random(11)
    mover, plain = Job("demover"), Job("deplain")
    lease = sim.attach(mover, policy=SchedFair(slice_s=0.002), share=1.0)
    tasks = [sim.spawn(mover, _prog_body(rng)) for _ in range(5)]
    tasks += [sim.spawn(plain, _prog_body(rng)) for _ in range(3)]
    sim.run(until=0.002)
    assert lease.group.dedicated
    default_pol = sim.sched.arbiter.default_policy
    ready_before = sum(1 for t in mover.tasks if t.state is TaskState.READY)
    new_lease = sim.demote(mover, share=2.0)
    assert mover.lease is new_lease and not new_lease.group.dedicated
    assert new_lease.share == 2.0
    assert sim.sched.policy_of(mover) is default_pol
    assert default_pol.ready_count_of(mover) == ready_before
    sim.run()
    assert all(t.done for t in tasks)
    # back to the flat single-group fast path once the last dedicated
    # group is gone
    assert not sim.sched.arbiter.multi


def test_detach_refusal_enumerates_busy_tasks():
    """The quiescence satellite: a refused teardown names the offending
    READY/RUNNING tasks (job + task ids) instead of just refusing."""
    from repro.core.arbiter import ArbiterError

    sim = SimExecutor(Topology(1, 1), SchedCoop(quantum=0.01), max_time=600.0)
    job = Job("busyjob")

    def busy_body():
        yield st.compute(0.05)

    tasks = [sim.spawn(job, busy_body, name=f"busy-{i}") for i in range(3)]
    sim.run(until=0.001)
    busy = [t for t in job.tasks
            if t.state in (TaskState.READY, TaskState.RUNNING)]
    assert busy
    with pytest.raises(ArbiterError) as exc:
        sim.detach(job)
    msg = str(exc.value)
    assert f"busyjob#{job.jid}" in msg
    for t in busy:
        assert f"{t.name}#{t.tid}={t.state.value}" in msg
    assert str(len(busy)) in msg
    del tasks


def test_failed_swap_leaves_dedicated_job_state_intact():
    """A rejected swap (policy instance reuse) must leave the dedicated
    group's queue and lease untouched — same contract as failed attach."""
    from repro.core.arbiter import ArbiterError

    sim = SimExecutor(Topology(1, 1), SchedCoop(quantum=0.01), max_time=600.0)
    job, other = Job("swapvictim"), Job("swapholder")
    own_policy = SchedFair(slice_s=0.002)
    used_policy = SchedFair(slice_s=0.002)
    sim.attach(job, policy=own_policy, share=1.0)
    sim.attach(other, policy=used_policy, share=1.0)
    tasks = [sim.spawn(job, _prog_body(random.Random(3))) for _ in range(3)]
    sim.run(until=0.002)
    ready_before = own_policy.ready_count_of(job)
    lease_before = job.lease
    with pytest.raises(ArbiterError):
        sim.attach(job, policy=used_policy)  # sibling's instance
    with pytest.raises(ArbiterError):
        sim.attach(job, policy=own_policy)  # its own current instance
    assert job.lease is lease_before
    assert own_policy.ready_count_of(job) == ready_before
    sim.run()
    assert all(t.done for t in tasks)


def test_attach_with_raising_custom_policy_leaves_job_state_intact():
    """Regression: a CUSTOM policy whose attach()/on_job() raises must
    fail the re-home before any withdrawal — otherwise the job's READY
    tasks would be left queued in no policy (never dispatched again)."""
    sim = SimExecutor(Topology(2, 1), SchedCoop(quantum=0.01), max_time=600.0)
    job = Job("rvictim")
    tasks = [sim.spawn(job, _prog_body(random.Random(13))) for _ in range(4)]
    sim.run(until=0.002)
    default_pol = sim.sched.arbiter.default_policy
    ready_before = default_pol.ready_count_of(job)
    lease_before = job.lease

    class BoomPolicy(SchedFair):
        def attach(self, sched):
            raise RuntimeError("topology validation failed")

    class BoomOnJob(SchedFair):
        def on_job(self, j):
            raise RuntimeError("job rejected")

    for bad in (BoomPolicy(slice_s=0.002), BoomOnJob(slice_s=0.002)):
        with pytest.raises(RuntimeError):
            sim.attach(job, policy=bad, share=1.0)
        assert job.lease is lease_before
        assert default_pol.ready_count_of(job) == ready_before
    sim.run()
    assert all(t.done for t in tasks)


def test_failed_demote_from_legacy_policy_leaves_no_phantom_job():
    """Regression: a demote refused because the dedicated policy lacks
    remove() must not have pre-registered the job with the default
    policy — a phantom entry would sit in its rotation forever."""
    from repro.core.arbiter import ArbiterError

    sim = SimExecutor(Topology(1, 1), SchedCoop(quantum=0.01), max_time=600.0)
    job = Job("phantom")
    legacy = RefFair(slice_s=0.002)  # pre-refactor surface: no remove()
    sim.attach(job, policy=legacy, share=1.0)

    def long_compute():
        yield st.compute(0.05)

    tasks = [sim.spawn(job, long_compute) for _ in range(3)]
    sim.run(until=0.001)  # 1 slot: one RUNNING, two queued READY
    assert any(t.state is TaskState.READY for t in job.tasks)
    default_pol = sim.sched.arbiter.default_policy
    with pytest.raises(ArbiterError, match="does not implement"):
        sim.demote(job)
    assert job.jid not in default_pol._jobs  # no phantom registration
    assert job.lease is not None and job.lease.group.policy is legacy
    sim.run()
    assert all(t.done for t in tasks)


def test_failed_attach_leaves_job_state_intact():
    """Regression: a rejected attach (policy reuse / bad share) must not
    have withdrawn the job's queued tasks or dropped its lease."""
    sim = SimExecutor(Topology(2, 1), SchedCoop(quantum=0.01), max_time=600.0)
    job, other = Job("victim"), Job("holder")
    used_policy = SchedFair(slice_s=0.002)
    sim.attach(other, policy=used_policy, share=1.0)
    tasks = [sim.spawn(job, _prog_body(random.Random(5))) for _ in range(4)]
    sim.run(until=0.002)
    default_pol = sim.sched.arbiter.default_policy
    ready_before = default_pol.ready_count_of(job)
    lease_before = job.lease
    from repro.core.arbiter import ArbiterError

    with pytest.raises(ArbiterError):
        sim.attach(job, policy=used_policy)  # instance already in use
    with pytest.raises(ArbiterError):
        sim.attach(job, policy=SchedFair(slice_s=0.002), share=-1.0)
    assert job.lease is lease_before  # untouched
    assert default_pol.ready_count_of(job) == ready_before
    sim.run()  # and the workload still completes through the default group
    assert all(t.done for t in tasks)


def test_shutdown_with_sleeping_task_does_not_hang():
    """Regression: watchdog stop() fires pending timed wakeups early
    instead of dropping them — a sleeper resumes and the worker takes its
    poison pill within the shutdown timeout."""
    rt = UsfRuntime(Topology(1, 1), SchedCoop())
    job = Job("sleeper")
    t = rt.create(lambda: rt.sleep(30.0), job=job)
    deadline = time.monotonic() + 5.0
    while not t.stats.dispatches and time.monotonic() < deadline:
        time.sleep(0.01)  # wait until the task is parked in its sleep
    time.sleep(0.05)
    t0 = time.monotonic()
    rt.shutdown(timeout=10.0)
    assert time.monotonic() - t0 < 5.0, "shutdown hung on a sleeping task"
    assert t.done  # woke early, finished, worker consumed the poison pill


def test_legacy_default_policy_without_new_api_still_works():
    """Back-compat: a custom default policy implementing only the
    pre-refactor Policy surface (no remove/pick_filtered/ready_count_of)
    must keep working in multi-group mode (group-granular fallback), and
    live re-homing out of it is refused cleanly BEFORE any state is
    touched."""
    from repro.core.arbiter import ArbiterError

    sim = SimExecutor(Topology(2, 1), RefFair(slice_s=0.002), max_time=600.0)
    a, b, c = Job("lega"), Job("legb"), Job("legc")
    sim.attach(c, policy=SchedCoop(quantum=0.01), share=1.0)  # multi mode
    rng = random.Random(9)
    tasks = [sim.spawn(j, _prog_body(rng)) for j in (a, b, a, b, c)]
    sim.run()  # the 2-member legacy default group must not crash picks
    assert all(t.done for t in tasks)

    # queue READY work for `a` (2 slots, 3 tasks: at least one stays READY)
    more = [sim.spawn(a, _prog_body(rng)) for _ in range(3)]
    assert any(t.state is TaskState.READY for t in a.tasks)
    with pytest.raises(ArbiterError, match="does not implement"):
        sim.attach(a, policy=SchedFair(slice_s=0.002), share=1.0)
    sim.run()  # refused attach left the legacy queue intact
    assert all(t.done for t in more)


def test_per_job_lease_enforcement_inside_default_group():
    """Two jobs sharing the DEFAULT group at a 3:1 share split: with
    job-filtered picks their service tracks the per-job leases even though
    one policy instance multiplexes both (previously group-granular only,
    i.e. ~1:1 from SCHED_COOP's round-robin)."""
    sim = SimExecutor(Topology(8, 1), SchedCoop(quantum=0.01), max_time=1e9)
    heavy, light = Job("heavy", share=3.0), Job("light", share=1.0)
    dedicated = Job("fairside", share=4.0)
    sim.attach(dedicated, policy=SchedFair(slice_s=0.003))

    def churn():
        while True:
            yield st.compute(0.002)
            yield st.sleep(0.0005)

    for _ in range(16):
        sim.spawn(heavy, churn)
        sim.spawn(light, churn)
        sim.spawn(dedicated, churn)
    sim.run(until=1.0)
    frac_heavy = heavy.service_time / (heavy.service_time + light.service_time)
    assert 0.65 <= frac_heavy <= 0.85, (
        f"per-job lease not enforced in default group: {frac_heavy:.3f}")


# --------------------------------------------------------------------- #
# executor layer: the watchdog tick driver on real threads
# --------------------------------------------------------------------- #
def _spin_until(rt, stop_event, *, poll=2000):
    """CPU-bound loop with explicit preemption points (checkpoint)."""
    n = 0
    while not stop_event.is_set():
        n += 1
        if n % poll == 0:
            rt.checkpoint()
        else:
            # a tiny pure-python burn so the loop is compute-, not
            # syscall-dominated
            pass


def test_real_thread_preemptive_policy_time_slices():
    """Two CPU-bound SCHED_FAIR tasks on ONE slot: the watchdog must
    time-slice them (both run concurrently-ish, both get preempted) —
    under the old runtime the first task would hold the slot to the end."""
    rt = UsfRuntime(Topology(1, 1), SchedFair(slice_s=TICK))
    try:
        job = Job("fair")
        stop = threading.Event()
        started = {}

        def body(name):
            def fn():
                started[name] = time.monotonic()
                _spin_until(rt, stop)

            return fn

        t0 = time.monotonic()
        t1 = rt.create(body("a"), job=job, name="a")
        t2 = rt.create(body("b"), job=job, name="b")
        deadline = time.monotonic() + 10.0
        while len(started) < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        stop.set()
        assert rt.join(t1, timeout=10.0) and rt.join(t2, timeout=10.0)
        assert len(started) == 2, "second task never time-sliced in"
        # the second task ran while the first was still spinning
        assert started["b"] - t0 < RECLAIM_BOUND
        assert t1.stats.preemptions + t2.stats.preemptions >= 1
        # either the self-ticking checkpoint path or the watchdog backstop
        # initiated the slice expiry (the fast path usually wins the race)
        assert rt.sched.poll_preempts + rt.watchdog.preempts_requested >= 1
    finally:
        rt.shutdown(timeout=5.0)


def test_watchdog_revokes_borrowed_slot_within_tick_period():
    """A preemptive job borrowing beyond its lease is kicked off within a
    tick period once the under-lease coop sibling has ready work."""
    rt = UsfRuntime(Topology(2, 1), SchedCoop(quantum=0.02))
    try:
        borrower, coop = Job("borrower"), Job("coop")
        rt.attach(borrower, policy=SchedFair(slice_s=TICK), share=1.0)
        lease_c = rt.attach(coop, policy=SchedCoop(quantum=0.02), share=1.0)
        assert lease_c.quota == 1
        stop = threading.Event()
        spinners = [rt.create(lambda: _spin_until(rt, stop), job=borrower)
                    for _ in range(2)]  # borrows BOTH slots (sibling idle)
        deadline = time.monotonic() + 5.0
        while (len(rt.sched.slots_running(borrower)) < 2
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert len(rt.sched.slots_running(borrower)) == 2
        t_submit = time.monotonic()
        ran_at = {}

        def coop_body():
            ran_at["t"] = time.monotonic()

        ct = rt.create(coop_body, job=coop)
        assert rt.join(ct, timeout=10.0), "lease revocation never landed"
        latency = ran_at["t"] - t_submit
        assert latency < RECLAIM_BOUND, (
            f"revocation took {latency:.3f}s (tick {TICK}s)")
        assert sum(t.stats.preemptions for t in borrower.tasks) >= 1
        # I2: the cooperative job itself was never preempted
        assert sum(t.stats.preemptions for t in coop.tasks) == 0
        stop.set()
        for t in spinners:
            assert rt.join(t, timeout=10.0)
    finally:
        rt.shutdown(timeout=5.0)


def test_lease_resize_reclaim_lands_under_real_threads():
    """Mid-run ``lease.resize()``: the reclaimed slot is surrendered at
    the next watchdog tick, not at the borrower's next (never-arriving)
    blocking point."""
    rt = UsfRuntime(Topology(2, 1), SchedCoop(quantum=0.02))
    try:
        fair, coop = Job("fairjob"), Job("coopjob")
        lease_f = rt.attach(fair, policy=SchedFair(slice_s=TICK), share=1.0)
        lease_c = rt.attach(coop, policy=SchedCoop(quantum=0.02), share=0.0)
        stop = threading.Event()
        spinners = [rt.create(lambda: _spin_until(rt, stop), job=fair)
                    for _ in range(2)]
        # wait until the borrower actually owns BOTH slots: rt.create
        # returns before the worker submits, so an immediate probe could
        # legitimately borrow a still-idle slot (work-conserving I5)
        deadline = time.monotonic() + 5.0
        while (len(rt.sched.slots_running(fair)) < 2
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert len(rt.sched.slots_running(fair)) == 2
        ran_at = {}
        ct = rt.create(lambda: ran_at.setdefault("t", time.monotonic()),
                       job=coop)
        time.sleep(2 * TICK)
        assert "t" not in ran_at  # share 0: queued behind the borrower
        t_resize = time.monotonic()
        lease_c.resize(1.0)  # reclaim one slot from the fair borrower
        assert lease_f.quota == 1 and lease_c.quota == 1
        assert rt.join(ct, timeout=10.0), "resize reclaim never landed"
        latency = ran_at["t"] - t_resize
        assert latency < RECLAIM_BOUND, (
            f"resize reclaim took {latency:.3f}s (tick {TICK}s)")
        stop.set()
        for t in spinners:
            assert rt.join(t, timeout=10.0)
        assert sum(t.stats.preemptions for t in coop.tasks) == 0
    finally:
        rt.shutdown(timeout=5.0)


def test_coop_slots_are_never_ticked():
    """Zero preemptions delivered to SCHED_COOP tasks while a preemptive
    sibling is ticked on its own slots; the coop job's checkpoints stay
    no-ops."""
    rt = UsfRuntime(Topology(2, 1), SchedCoop(quantum=0.02))
    try:
        coop, fair = Job("c"), Job("f")
        rt.attach(coop, policy=SchedCoop(quantum=0.02), share=1.0)
        rt.attach(fair, policy=SchedFair(slice_s=TICK), share=1.0)
        stop = threading.Event()
        tasks = [rt.create(lambda: _spin_until(rt, stop), job=coop),
                 rt.create(lambda: _spin_until(rt, stop), job=fair)]
        time.sleep(4 * TICK)
        stop.set()
        for t in tasks:
            assert rt.join(t, timeout=10.0)
        assert sum(t.stats.preemptions for t in coop.tasks) == 0
        assert sum(t.stats.yields for t in coop.tasks) == 0
    finally:
        rt.shutdown(timeout=5.0)


def test_real_thread_live_rehoming_mid_run():
    """attach with queued real-thread work: tasks created under the
    default group migrate to a dedicated preemptive group mid-run and all
    complete exactly once."""
    rt = UsfRuntime(Topology(1, 1), SchedCoop(quantum=0.02))
    try:
        job = Job("migrant")
        stop = threading.Event()
        done = []

        def body(i):
            def fn():
                t_end = time.monotonic() + 0.05
                n = 0
                while time.monotonic() < t_end and not stop.is_set():
                    n += 1
                    if n % 1000 == 0:
                        rt.checkpoint()
                done.append(i)

            return fn

        tasks = [rt.create(body(i), job=job) for i in range(4)]
        time.sleep(0.01)  # some running, some queued in the default group
        lease = rt.attach(job, policy=SchedFair(slice_s=TICK), share=1.0)
        assert lease.group.dedicated
        for t in tasks:
            assert rt.join(t, timeout=20.0)
        assert sorted(done) == [0, 1, 2, 3]
        assert all(t.stats.dispatches >= 1 for t in tasks)
    finally:
        rt.shutdown(timeout=5.0)


def test_real_thread_live_policy_swap_mid_run():
    """dedicated→dedicated live swap under real threads: spinners running
    under SCHED_FAIR swap to a fresh SCHED_RR group mid-flight and keep
    time-slicing — ticks follow the new policy's interval class."""
    rt = UsfRuntime(Topology(1, 1), SchedCoop(quantum=0.02))
    try:
        job = Job("rtswap")
        rt.attach(job, policy=SchedFair(slice_s=TICK), share=1.0)
        stop = threading.Event()
        spinners = [rt.create(lambda: _spin_until(rt, stop), job=job)
                    for _ in range(2)]
        deadline = time.monotonic() + 5.0
        while (not rt.sched.slots_running(job)
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert rt.sched.slots_running(job)
        swapped = SchedRR(quantum=TICK)
        lease = rt.attach(job, policy=swapped, share=1.0)  # live swap
        assert lease.group.dedicated and lease.group.policy is swapped
        assert rt.sched.policy_of(job) is swapped
        preempts_at_swap = sum(t.stats.preemptions for t in job.tasks)
        # both spinners still share the slot under the NEW policy
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            if sum(t.stats.preemptions for t in job.tasks) \
                    > preempts_at_swap:
                break
            time.sleep(0.01)
        assert sum(t.stats.preemptions for t in job.tasks) \
            > preempts_at_swap, "no slicing under the swapped-in policy"
        stop.set()
        for t in spinners:
            assert rt.join(t, timeout=10.0)
    finally:
        rt.shutdown(timeout=5.0)


def test_real_thread_demote_mid_run():
    """dedicated→default live demotion under real threads: a spinning
    SCHED_FAIR job demotes into the (cooperative) default group mid-run;
    its tasks keep completing there and stop being ticked."""
    rt = UsfRuntime(Topology(2, 1), SchedCoop(quantum=0.02))
    try:
        job = Job("rtdemote")
        rt.attach(job, policy=SchedFair(slice_s=TICK), share=2.0)
        stop = threading.Event()
        tasks = [rt.create(lambda: _spin_until(rt, stop), job=job)
                 for _ in range(3)]  # 3 tasks, 2 slots: one stays READY
        deadline = time.monotonic() + 5.0
        while (len(rt.sched.slots_running(job)) < 2
               and time.monotonic() < deadline):
            time.sleep(0.005)
        lease = rt.demote(job)
        assert not lease.group.dedicated
        assert rt.sched.policy_of(job) is rt.sched.arbiter.default_policy
        stop.set()
        for t in tasks:
            assert rt.join(t, timeout=10.0)
        assert all(t.done for t in tasks)
    finally:
        rt.shutdown(timeout=5.0)


def test_sleep_routes_through_watchdog_no_timer_threads():
    """The timer-churn satellite: N concurrent timed waits use the single
    watchdog thread, not one threading.Timer thread per call."""
    rt = UsfRuntime(Topology(2, 1), SchedCoop())
    try:
        job = Job("sleepy")

        def body():
            for _ in range(3):
                rt.sleep(0.03)

        tasks = [rt.create(body, job=job) for _ in range(6)]
        time.sleep(0.04)  # mid-flight: 6 pending timed wakeups
        names = [t.name for t in threading.enumerate()]
        assert names.count("usf-watchdog") == 1
        assert not any(isinstance(t, threading.Timer)
                       for t in threading.enumerate())
        for t in tasks:
            assert rt.join(t, timeout=10.0)
    finally:
        rt.shutdown(timeout=5.0)


def test_join_timeout_routes_through_watchdog():
    rt = UsfRuntime(Topology(2, 1), SchedCoop())
    try:
        from repro.core.sync import CoopEvent

        job = Job("j")
        gate = CoopEvent(rt)
        hung = rt.create(gate.wait, job=job)
        res = {}

        def joiner():
            res["timed_out"] = rt.join(hung, timeout=0.05)

        j = rt.create(joiner, job=job)
        assert rt.join(j, timeout=10.0)
        assert res["timed_out"] is False
        assert not any(isinstance(t, threading.Timer)
                       for t in threading.enumerate())
        gate.set()
        assert rt.join(hung, timeout=10.0)
    finally:
        rt.shutdown(timeout=5.0)


def test_arm_tick_earlier_interval_supersedes_pending():
    """Regression: a pending long-interval tick (e.g. from a SCHED_RR
    quantum) must not suppress arming a shorter one when the slot hands
    off to a short-slice policy — the slot migrates to the faster
    interval class and is serviced at ITS next fire, not after 10s."""
    rt = UsfRuntime(Topology(1, 1), SchedCoop())
    try:
        wd = rt.watchdog
        wd.arm_tick(0, 10.0)  # long tick pending
        wd.arm_tick(0, 0.01)  # must migrate classes, not be deduped away
        with wd._cv:
            assert wd._slot_interval[0] == 0.01
            assert 0 not in wd._classes[10.0]
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            if wd.ticks_fired >= 1:
                break  # the 0.01s class fired; the 10s class is now empty
            time.sleep(0.005)
        assert wd.ticks_fired >= 1
        with wd._cv:
            assert 0 not in wd._slot_interval  # idle slot: not re-armed
    finally:
        rt.shutdown(timeout=5.0)


def test_cancelled_timers_compacted_from_watchdog_heap():
    """Regression: a cancelled long timeout (e.g. a 300s request deadline
    that resolved in ms) must not pin its heap entry + waiter closure
    until the original deadline — cancels trigger lazy compaction."""
    rt = UsfRuntime(Topology(1, 1), SchedCoop())
    try:
        handles = [rt.call_later(300.0, lambda: None) for _ in range(200)]
        for h in handles:
            h.cancel()
        with rt.watchdog._cv:
            live = len(rt.watchdog._heap)
        assert live < 100, f"{live} dead 300s entries still pinned"
    finally:
        rt.shutdown(timeout=5.0)


def test_watchdog_survives_raising_callback():
    """Regression: one bad timer callback must not kill the tick driver
    (every later sleep/timeout/preemption rides the same thread)."""
    rt = UsfRuntime(Topology(1, 1), SchedCoop())
    try:
        rt.call_later(0.0, lambda: 1 / 0)  # raises inside _fire
        job = Job("after")
        t = rt.create(lambda: rt.sleep(0.05), job=job)
        assert rt.join(t, timeout=10.0)  # timed wakeups still delivered
    finally:
        rt.shutdown(timeout=5.0)


def test_watchdog_idle_when_purely_cooperative():
    """No preemptive policy, no timed waits: the tick driver costs nothing
    — not even its thread."""
    rt = UsfRuntime(Topology(2, 1), SchedCoop())
    try:
        job = Job("j")
        tasks = [rt.create(lambda: None, job=job) for _ in range(4)]
        for t in tasks:
            assert rt.join(t, timeout=10.0)
        assert rt.watchdog.ticks_fired == 0
        assert "usf-watchdog" not in [t.name for t in threading.enumerate()]
    finally:
        rt.shutdown(timeout=5.0)


# --------------------------------------------------------------------- #
# elastic: mesh rescale -> lease resize share one path
# --------------------------------------------------------------------- #
def test_mesh_rescale_resizes_leases_mid_run():
    from repro.launch.rescale import ElasticCoordinator, MeshRescaleEvent

    sim = SimExecutor(Topology(8, 1), SchedCoop(quantum=0.01), max_time=1e9)
    train, serve = Job("train"), Job("serve")
    coord = ElasticCoordinator()
    lease_t = coord.register(
        sim.attach(train, policy=SchedCoop(quantum=0.01), share=6.0))
    lease_s = sim.attach(serve, policy=SchedFair(slice_s=0.002), share=2.0)
    assert (lease_t.quota, lease_s.quota) == (6, 2)

    def churn():
        while True:
            yield st.compute(0.002)
            yield st.sleep(0.0005)

    for _ in range(16):
        sim.spawn(train, churn)
        sim.spawn(serve, churn)
    sim.run(until=0.25)
    w1 = (train.service_time, serve.service_time)

    event = MeshRescaleEvent((16, 16), (8, 16))  # lost half the devices
    assert event.scale == 0.5
    shares = coord.on_rescale(event)
    assert shares == {"train": 3.0}
    assert lease_t.share == 3.0
    assert (lease_t.quota, lease_s.quota) == (5, 3)  # 3:2 of 8 slots

    sim.run(until=0.5)
    w2 = (train.service_time - w1[0], serve.service_time - w1[1])
    frac1 = w1[0] / sum(w1)
    frac2 = w2[0] / sum(w2)
    assert frac1 > 0.70          # 6:2 split before the event
    assert frac2 < frac1 - 0.05  # reclaim visibly landed after it


def test_mesh_collapse_demotes_job_live():
    """Losing the WHOLE mesh demotes the job into the default group
    (rescale-driven policy swap without drain): its dedicated lease is
    gone, in-flight work keeps completing under default multiplexing, and
    the coordinator stops tracking the dead lease."""
    from repro.launch.rescale import ElasticCoordinator, MeshRescaleEvent

    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    train, serve = Job("ctrain"), Job("cserve")
    coord = ElasticCoordinator(runtime=sim)
    coord.register(
        sim.attach(train, policy=SchedFair(slice_s=0.002), share=2.0),
        demote_on_collapse=True)
    lease_s = coord.register(
        sim.attach(serve, policy=SchedCoop(quantum=0.01), share=2.0))

    def churn(n):
        def gen():
            for _ in range(n):
                yield st.compute(0.002)
                yield st.sleep(0.0005)
        return gen

    tasks = [sim.spawn(train, churn(30)) for _ in range(4)]
    tasks += [sim.spawn(serve, churn(30)) for _ in range(4)]
    sim.run(until=0.01)  # train is busy mid-flight

    shares = coord.on_rescale(MeshRescaleEvent((8, 16), (0, 16)))
    assert shares["ctrain"] == 0.0
    assert train.lease is not None and not train.lease.group.dedicated
    assert sim.sched.policy_of(train) is sim.sched.arbiter.default_policy
    # the dead dedicated lease left elastic tracking; the sibling did not
    assert list(coord.leases()) == [lease_s]
    sim.run()
    assert all(t.done for t in tasks)

    # re-promotion: a fresh attach + register WITHOUT the flag revokes the
    # stale opt-in — the next collapse resizes instead of demoting again
    lease_t2 = sim.attach(train, policy=SchedFair(slice_s=0.002), share=2.0)
    coord.register(lease_t2)
    shares2 = coord.on_rescale(MeshRescaleEvent((8, 16), (0, 16)))
    assert shares2["ctrain"] == 0.0  # share scaled to zero, NOT demoted
    assert train.lease is lease_t2 and lease_t2.group.dedicated
    assert lease_t2 in coord.leases()

    # a lease superseded OUT-OF-BAND (here: a direct demote the
    # coordinator did not perform) is dropped gracefully on the next
    # event instead of crashing the fan-out mid-loop
    lease_t3 = sim.attach(train, policy=SchedFair(slice_s=0.002), share=2.0)
    coord.register(lease_t3, demote_on_collapse=True)
    sim.demote(train)  # out-of-band: lease_t3 is now dead
    shares3 = coord.on_rescale(MeshRescaleEvent((8, 16), (0, 16)))
    assert "ctrain" not in shares3  # dead registration dropped, no crash
    assert lease_t3 not in coord.leases()
    assert shares3["cserve"] == 0.0  # siblings still processed

    # a stale flagged registration must not erase the opt-in of a NEWER
    # live registration of the same job: processing dead lease_t4 first
    # still leaves lease_t5's flag effective — the job is demoted, not
    # parked on a dedicated zero-share lease
    lease_t4 = sim.attach(train, policy=SchedFair(slice_s=0.002), share=2.0)
    coord.register(lease_t4, demote_on_collapse=True)
    lease_t5 = sim.attach(train, policy=SchedRR(quantum=0.002), share=2.0)
    coord.register(lease_t5, demote_on_collapse=True)  # t4 now stale
    shares4 = coord.on_rescale(MeshRescaleEvent((8, 16), (0, 16)))
    assert shares4["ctrain"] == 0.0
    assert train.lease is not None and not train.lease.group.dedicated

    # registering for collapse-demotion without a runtime is refused,
    # as is flagging a default-group lease (nothing to demote)
    with pytest.raises(ValueError):
        ElasticCoordinator().register(lease_s, demote_on_collapse=True)
    with pytest.raises(ValueError, match="dedicated"):
        ElasticCoordinator(runtime=sim).register(
            train.lease, demote_on_collapse=True)


def test_rescale_reregister_updates_flag_without_duplicating():
    """Re-registering the same lease (e.g. to revoke its collapse opt-in)
    must not duplicate it in the fan-out — a duplicate would apply every
    rescale twice (share scaled by scale^2)."""
    from repro.launch.rescale import ElasticCoordinator, MeshRescaleEvent

    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    job = Job("dup")
    lease = sim.attach(job, policy=SchedCoop(quantum=0.01), share=2.0)
    coord = ElasticCoordinator(runtime=sim)
    coord.register(lease, demote_on_collapse=True)
    coord.register(lease)  # revoke the flag: must NOT duplicate
    assert list(coord.leases()) == [lease]
    shares = coord.on_rescale(MeshRescaleEvent((8,), (4,)))
    assert shares["dup"] == 2.0 * 0.5  # halved once, not squared
    assert lease.share == 1.0
    # and the revoked flag means a collapse resizes instead of demoting
    coord.on_rescale(MeshRescaleEvent((4,), (0,)))
    assert job.lease is lease and lease.group.dedicated
    assert lease.share == 0.0


def test_mesh_rescale_regrow_restores_share():
    from repro.launch.rescale import ElasticCoordinator, MeshRescaleEvent

    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    job = Job("train")
    coord = ElasticCoordinator()
    lease = coord.register(
        sim.attach(job, policy=SchedCoop(quantum=0.01), share=4.0))
    coord.on_rescale(MeshRescaleEvent((16, 16), (8, 16)))
    assert lease.share == 2.0
    coord.on_rescale(MeshRescaleEvent((8, 16), (16, 16)))
    assert lease.share == 4.0
    with pytest.raises(ValueError):
        MeshRescaleEvent((0,), (8,)).scale


def test_mesh_regrow_auto_repromotes_collapsed_job():
    """The PR 4 caveat closed: with a policy_factory registered, a
    collapse-demoted job is automatically RE-PROMOTED (fresh dedicated
    policy + lease) by the first event that regrows its mesh — no manual
    attach needed — at the pre-collapse share scaled by the regrown
    fraction."""
    from repro.launch.rescale import ElasticCoordinator, MeshRescaleEvent

    sim = SimExecutor(Topology(8, 1), SchedCoop(quantum=0.01), max_time=1e9)
    train, serve = Job("rtrain"), Job("rserve")
    coord = ElasticCoordinator(runtime=sim)
    factory = lambda: SchedFair(slice_s=0.002)  # noqa: E731
    coord.register(
        sim.attach(train, policy=factory(), share=4.0),
        demote_on_collapse=True, policy_factory=factory)
    # the sibling is co-located but tracks its OWN mesh: not registered
    # with this coordinator (a collapse event would zero its share too)
    sim.attach(serve, policy=SchedCoop(quantum=0.01), share=4.0)

    def churn(n):
        def gen():
            for _ in range(n):
                yield st.compute(0.002)
                yield st.sleep(0.0005)
        return gen

    tasks = [sim.spawn(train, churn(200)) for _ in range(4)]
    tasks += [sim.spawn(serve, churn(200)) for _ in range(4)]
    sim.run(until=0.01)  # busy mid-flight

    # collapse: train demoted live into the default group
    shares = coord.on_rescale(MeshRescaleEvent((8, 16), (0, 16)))
    assert shares["rtrain"] == 0.0
    assert train.lease is not None and not train.lease.group.dedicated
    sim.run(until=0.02)

    # regrow to HALF the pre-collapse mesh: auto re-promotion at half the
    # pre-collapse share, under a FRESH dedicated policy instance
    shares = coord.on_rescale(MeshRescaleEvent((0, 16), (4, 16)))
    assert shares["rtrain"] == pytest.approx(2.0)
    lease = train.lease
    assert lease is not None and lease.group.dedicated
    assert lease.share == pytest.approx(2.0)
    assert sim.sched.policy_of(train).name == "SCHED_FAIR"
    # the unregistered sibling was untouched throughout
    assert "rserve" not in shares
    assert serve.lease.share == pytest.approx(4.0)
    sim.run(until=0.03)

    # the re-registered lease keeps tracking: a SECOND collapse demotes
    # again, and a full regrow re-promotes at the full original fraction
    shares = coord.on_rescale(MeshRescaleEvent((4, 16), (0, 16)))
    assert shares["rtrain"] == 0.0
    assert not train.lease.group.dedicated
    shares = coord.on_rescale(MeshRescaleEvent((0, 16), (4, 16)))
    assert train.lease.group.dedicated
    assert train.lease.share == pytest.approx(2.0)
    sim.run()
    assert all(t.done for t in tasks)


def test_regrow_skips_manually_repromoted_job():
    """A job the user already re-attached out-of-band is left alone by
    the auto-re-promotion pass (the manual registration is in charge)."""
    from repro.launch.rescale import ElasticCoordinator, MeshRescaleEvent

    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    job = Job("manual")
    coord = ElasticCoordinator(runtime=sim)
    factory = lambda: SchedFair(slice_s=0.002)  # noqa: E731
    coord.register(sim.attach(job, policy=factory(), share=2.0),
                   demote_on_collapse=True, policy_factory=factory)
    coord.on_rescale(MeshRescaleEvent((8,), (0,)))
    assert not job.lease.group.dedicated

    manual_policy = SchedRR(quantum=0.002)
    manual = sim.attach(job, policy=manual_policy, share=3.0)
    shares = coord.on_rescale(MeshRescaleEvent((0,), (8,)))
    assert "manual" not in shares  # auto pass left it alone
    assert job.lease is manual and manual.share == 3.0
    assert sim.sched.policy_of(job) is manual_policy


def test_policy_factory_requires_collapse_opt_in():
    from repro.launch.rescale import ElasticCoordinator

    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    job = Job("nope")
    lease = sim.attach(job, policy=SchedFair(slice_s=0.002), share=1.0)
    with pytest.raises(ValueError, match="policy_factory"):
        ElasticCoordinator(runtime=sim).register(
            lease, policy_factory=lambda: SchedFair(slice_s=0.002))


def test_rescale_routes_to_node_broker():
    """With a broker wired in, every mesh event also rescales the
    process's NODE-level share (cross-process reclaim)."""
    from repro.launch.rescale import ElasticCoordinator, MeshRescaleEvent

    class FakeBrokerClient:
        def __init__(self):
            self.scales = []

        def rescale(self, scale):
            self.scales.append(scale)

    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    job = Job("routed")
    broker = FakeBrokerClient()
    coord = ElasticCoordinator(runtime=sim, broker=broker)
    coord.register(sim.attach(job, policy=SchedCoop(quantum=0.01),
                              share=2.0))
    coord.on_rescale(MeshRescaleEvent((8, 16), (4, 16)))
    assert broker.scales == [0.5]
    assert job.lease.share == pytest.approx(1.0)
    # events reach the broker even when no local lease is registered
    # (the node share tracks the mesh regardless of in-process attach)
    coord2 = ElasticCoordinator(broker=broker)
    coord2.on_rescale(MeshRescaleEvent((4, 16), (8, 16)))
    assert broker.scales == [0.5, 2.0]


def test_broker_share_recovers_across_collapse_round_trip():
    """A collapse zeroes the node share multiplicatively — 0 times any
    later scale stays 0 — so the regrow must RESTORE it absolutely
    (broker.resize), scaled by the regrown device fraction."""
    from repro.launch.rescale import ElasticCoordinator, MeshRescaleEvent

    class FakeBrokerClient:
        def __init__(self):
            self.share = 4.0
            self.calls = []

        def rescale(self, scale):
            self.share *= scale
            self.calls.append(("rescale", scale))

        def resize(self, share):
            self.share = share
            self.calls.append(("resize", share))

    broker = FakeBrokerClient()
    coord = ElasticCoordinator(broker=broker)
    coord.on_rescale(MeshRescaleEvent((8, 16), (0, 16)))  # collapse
    assert broker.share == 0.0
    # regrow to half the pre-collapse mesh: node share restored to half
    coord.on_rescale(MeshRescaleEvent((0, 16), (4, 16)))
    assert broker.share == pytest.approx(2.0)
    assert broker.calls[-1] == ("resize", 2.0)
    # a second regrow-from-zero without a recorded collapse is a no-op
    coord.on_rescale(MeshRescaleEvent((0, 16), (8, 16)))
    assert broker.share == pytest.approx(2.0)
    # and ordinary events keep multiplying from the restored base
    coord.on_rescale(MeshRescaleEvent((4, 16), (8, 16)))
    assert broker.share == pytest.approx(4.0)
