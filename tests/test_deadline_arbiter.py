"""SLO-native serving layer: DeadlineArbiter, urgent grants, adaptive slices.

Covers the deadline-aware arbitration contract end to end:

* **EDF grant order** within a dedicated group (earliest-deadline task
  runs first regardless of submission order) and across groups within an
  I5 tier;
* **I5 interplay**: a borrowing deadline group can never starve a
  non-deadline sibling with spare lease — checked with the same pick
  wrapper the arbiter fuzz uses;
* **urgent grants**: a negative-laxity submission lands within one
  scheduling point under ``SimExecutor`` (immediate kick tick) and within
  one checkpoint under ``UsfRuntime`` (watchdog CV kick + checkpoint
  consumption + successor-hinted redispatch);
* **zero cost when unused**: a ``DeadlineArbiter`` with no deadline
  anywhere reproduces the base ``SlotArbiter`` schedule bit-identically;
* **SliceController**: deterministic shrink-under-pressure /
  grow-when-calm hysteresis, bounded scale, no state allocated while calm.
"""

import threading
import time

from repro.core import simtask as st
from repro.core.adaptive import SliceController
from repro.core.deadline import DeadlineArbiter
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair
from repro.core.task import Job
from repro.core.topology import Topology

from tests.test_arbiter import install_i5_checker


def make_dl_sim(n_slots=2, domains=1, **kw):
    pol = SchedCoop(quantum=0.02)
    return SimExecutor(Topology(n_slots, domains), pol,
                       max_time=kw.pop("max_time", 1e9),
                       arbiter=DeadlineArbiter(pol), **kw)


# --------------------------------------------------------------------- #
# SliceController
# --------------------------------------------------------------------- #
def test_slice_controller_calm_allocates_no_state():
    sc = SliceController()
    for _ in range(100):
        assert sc.observe(0.003, depth=5, laxity=None) == 0.003
        assert sc.observe(0.003, depth=0, laxity=1.0) == 0.003
    assert sc.n_classes() == 0
    assert sc.effective(0.003) == 0.003


def test_slice_controller_shrinks_under_pressure_and_floors():
    sc = SliceController()  # shrink_after=1, min_scale=1/8
    base = 0.003
    eff = sc.observe(base, depth=3, laxity=0.001)  # < 2*base: pressured
    assert eff == base * 0.5
    for _ in range(10):
        eff = sc.observe(base, depth=3, laxity=0.001)
    assert eff == base / 8  # floored at base * min_scale
    assert sc.effective(base) == base / 8


def test_slice_controller_grow_needs_calm_streak_and_empty_queue():
    sc = SliceController()  # grow_after=3
    base = 0.010
    sc.observe(base, depth=0, laxity=0.0)  # shrink once
    assert sc.scale_of(base) == 0.5
    # backlog without pressure: hold, never grow
    for _ in range(10):
        sc.observe(base, depth=4, laxity=None)
    assert sc.scale_of(base) == 0.5
    # calm + empty: grows only after 3 consecutive observations
    sc.observe(base, depth=0, laxity=None)
    sc.observe(base, depth=0, laxity=None)
    assert sc.scale_of(base) == 0.5
    sc.observe(base, depth=0, laxity=None)
    assert sc.scale_of(base) == 1.0
    # settled back to base: the class state is dropped again
    assert sc.n_classes() == 0


def test_slice_controller_deterministic_and_per_class():
    obs = [(0.003, 2, 0.001), (0.003, 0, None), (0.010, 1, 0.005),
           (0.003, 2, 0.0001), (0.010, 0, None)] * 4

    def run():
        sc = SliceController()
        return [sc.observe(b, depth=d, laxity=lx) for b, d, lx in obs]

    assert run() == run()
    sc = SliceController()
    for b, d, lx in obs:
        sc.observe(b, depth=d, laxity=lx)
    # pressure on the 3 ms class never touches the 10 ms class's scale
    assert sc.scale_of(0.003) < 1.0
    assert sc.effective(0.010) == 0.010 * sc.scale_of(0.010)


# --------------------------------------------------------------------- #
# zero cost when unused
# --------------------------------------------------------------------- #
def test_deadline_arbiter_without_deadlines_is_bit_identical():
    """No posted deadline, no deadline task: the DeadlineArbiter must
    reproduce the base arbiter's schedule exactly (same dispatch count,
    makespan and per-task stats) — the machinery costs nothing when no
    deadline job attaches."""

    def run(deadline_aware: bool):
        pol = SchedCoop(quantum=0.02)
        arb = DeadlineArbiter(pol) if deadline_aware else None
        sim = SimExecutor(Topology(4, 2), pol, max_time=1e9, arbiter=arb)
        a, b = Job("a"), Job("b")
        sim.attach(a, policy=SchedFair(slice_s=0.003), share=1.0)
        sim.attach(b, policy=SchedCoop(quantum=0.02), share=1.0)

        def churn(iters):
            def gen():
                for _ in range(iters):
                    yield st.compute(0.002)
                    yield st.sleep(0.0005)

            return gen

        tasks = [sim.spawn(j, churn(8 + i)) for i, j in
                 enumerate([a, b] * 4)]
        stats = sim.run()
        return (round(stats.makespan, 9), stats.dispatches,
                stats.preemptions,
                [(t.stats.dispatches, round(t.stats.wait_time, 9))
                 for t in tasks])

    assert run(False) == run(True)


def test_deadline_arbiter_single_group_fast_path_intact():
    sim = make_dl_sim(n_slots=2)
    job = Job("only")
    done = []

    def body():
        yield st.compute(0.001)
        done.append(sim.now())

    sim.spawn(job, body)
    sim.run()
    assert done and not sim.sched.arbiter.multi


# --------------------------------------------------------------------- #
# EDF grant order
# --------------------------------------------------------------------- #
def test_edf_orders_tasks_within_dedicated_group():
    """Three deadline tasks released while the only slot is busy complete
    earliest-deadline-first even though they were submitted in the
    opposite order."""
    sim = make_dl_sim(n_slots=1)
    serve = Job("serve")
    sim.attach(serve, policy=SchedFair(slice_s=0.010), share=1.0)
    order = []

    def hold():
        yield st.compute(0.005)

    def req(tag):
        def gen():
            yield st.compute(0.001)
            order.append(tag)

        return gen

    sim.spawn(serve, hold)  # occupies the slot; the rest queue behind it
    # submitted worst-deadline-first: EDF must invert the order
    sim.spawn(serve, req("late"), at=0.0005, deadline=0.9)
    sim.spawn(serve, req("mid"), at=0.001, deadline=0.5)
    sim.spawn(serve, req("early"), at=0.0015, deadline=0.1)
    sim.run()
    assert order == ["early", "mid", "late"]


def test_edf_group_preference_within_tier():
    """Two borrowing groups, one holding the earlier deadline: freed slots
    go to the earlier-deadline group first."""
    sim = make_dl_sim(n_slots=1)
    a, b = Job("dl-a"), Job("dl-b")
    sim.attach(a, policy=SchedFair(slice_s=0.010), share=1.0)
    sim.attach(b, policy=SchedFair(slice_s=0.010), share=1.0)
    order = []

    def hold():
        yield st.compute(0.004)

    def req(tag):
        def gen():
            yield st.compute(0.001)
            order.append(tag)

        return gen

    sim.spawn(a, hold)
    sim.spawn(b, req("b"), at=0.0005, deadline=0.8)
    sim.spawn(a, req("a"), at=0.001, deadline=0.2)
    sim.run()
    assert order.index("a") < order.index("b")


def test_edf_never_starves_non_deadline_spare_lease_group():
    """I5 interplay: a deadline-holding group saturating the node cannot
    borrow a slot while the non-deadline sibling still has spare lease and
    ready work — checked at every grant with the arbiter-fuzz pick
    wrapper, plus a service-share floor for the sibling."""
    sim = make_dl_sim(n_slots=4, domains=2)
    slo = Job("slo")
    plain = Job("plain")
    sim.attach(slo, policy=SchedFair(slice_s=0.003), share=2.0)
    sim.attach(plain, policy=SchedFair(slice_s=0.003), share=2.0)
    violations = install_i5_checker(sim)
    horizon = 1.0

    def churn():
        while sim.now() < horizon:
            yield st.compute(0.002)
            yield st.sleep(0.0002)

    # a deadline task flood: always more READY slo tasks than slots,
    # every one carrying a (soon overdue) deadline
    def slo_req(i):
        def gen():
            yield st.compute(0.004)

        return gen

    for _ in range(6):
        sim.spawn(plain, churn)
    for i in range(600):
        at = 0.0015 * i
        sim.spawn(slo, slo_req(i), at=at, deadline=at + 0.002)
    sim.run(until=horizon + 2.0)
    assert not violations, violations[:3]
    total = slo.service_time + plain.service_time
    # the sibling's lease is half the node; EDF pressure must not push its
    # realized share anywhere near starvation
    assert plain.service_time / total > 0.30, (
        f"non-deadline sibling starved: {plain.service_time / total:.3f}")


# --------------------------------------------------------------------- #
# urgent grants
# --------------------------------------------------------------------- #
def test_urgent_grant_lands_within_one_scheduling_point_sim():
    """A past-deadline submission while a borrower holds every slot fires
    the urgent path at on-ready time: the kick tick preempts the borrowed
    slot immediately, so the urgent task starts after dispatch costs only
    — far inside the borrower's 50 ms tick period."""
    sim = make_dl_sim(n_slots=1)
    serve = Job("serve")
    batch = Job("batch")
    sim.attach(serve, policy=SchedFair(slice_s=0.003), share=3.0)
    sim.attach(batch, policy=SchedFair(slice_s=0.050), share=1.0)
    started = []

    def spin():
        while sim.now() < 0.5:
            yield st.compute(0.005)

    def urgent():
        started.append(sim.now())
        yield st.compute(0.001)

    sim.spawn(batch, spin)  # quota 0: runs borrowed
    submit_at = 0.020
    sim.spawn(serve, urgent, at=submit_at, deadline=submit_at - 0.001)
    sim.run(until=1.0)
    arb = sim.sched.arbiter
    assert arb.urgent_grants >= 1
    assert started, "urgent task never ran"
    # one scheduling point: the immediate kick tick + dispatch costs —
    # nowhere near the borrower's 50 ms slice (or even its 5 ms segment)
    assert started[0] - submit_at < 0.004, (
        f"urgent grant took {started[0] - submit_at:.6f}s")


def test_urgent_grant_lands_within_one_checkpoint_usf():
    """Real threads: the urgent flag is serviced by the watchdog CV kick
    and consumed at the borrower's next checkpoint; the successor hint
    redispatches the urgent task without a full pick."""
    from repro.core.threads import UsfRuntime

    pol = SchedCoop(quantum=0.02)
    rt = UsfRuntime(Topology(1, 1), pol, arbiter=DeadlineArbiter(pol))
    try:
        serve = Job("serve")
        batch = Job("batch")
        rt.attach(serve, policy=SchedFair(slice_s=0.003), share=3.0)
        rt.attach(batch, policy=SchedFair(slice_s=0.050), share=1.0)
        stop = threading.Event()

        def spin():
            n = 0
            while not stop.is_set():
                n += 1
                if n % 64 == 0:
                    rt.checkpoint()

        spinner = rt.create(spin, job=batch)
        deadline = time.monotonic() + 5.0
        while not rt.sched.slots_running(batch):
            assert time.monotonic() < deadline, "spinner never dispatched"
            time.sleep(0.001)

        got = []
        t0 = time.monotonic()
        t = rt.create(lambda: got.append(time.monotonic()), job=serve,
                      deadline=t0 - 1e-3)
        assert rt.join(t, timeout=10.0)
        stop.set()
        assert rt.join(spinner, timeout=10.0)
        arb = rt.sched.arbiter
        assert arb.urgent_grants >= 1
        assert rt.watchdog.kicks >= 1
        # one checkpoint of the spinner (~µs cadence) plus dispatch, with
        # a generous CI-noise margin — still far under the 50 ms slice
        # the batch policy would otherwise allow
        assert got[0] - t0 < 0.045, f"urgent grant took {got[0] - t0:.4f}s"
    finally:
        rt.shutdown(timeout=5.0)


def test_posted_deadlines_boost_quota_and_retire():
    """post_deadline tilts apportionment toward the pressed job while the
    obligation is urgent; retire_deadline restores the configured split
    at the next rebalance."""
    sim = make_dl_sim(n_slots=4, domains=2)
    a, b = Job("press"), Job("calm")
    la = sim.attach(a, policy=SchedFair(slice_s=0.003), share=1.0)
    lb = sim.attach(b, policy=SchedFair(slice_s=0.003), share=1.0)
    assert (la.quota, lb.quota) == (2, 2)
    arb = sim.sched.arbiter
    tok = arb.post_deadline(a, sim.now() - 0.001)  # overdue: urgent
    arb._recompute_quotas()
    assert la.quota > lb.quota  # boosted share tilts the integer split
    assert la.share == 1.0  # the configured share itself is untouched
    arb.retire_deadline(a, tok)
    arb._recompute_quotas()
    assert (la.quota, lb.quota) == (2, 2)
