"""Property-based tests (hypothesis) for USF scheduler invariants.

Random multi-job workloads of compute / mutex / sleep / yield ops are run
under every policy; we assert the framework invariants:

  P1. Completion: every task finishes (no lost wakeups, no stuck queues).
  P2. I2: SCHED_COOP never preempts; preemptive policies may.
  P3. Work conservation: accounted run time >= requested compute time, and
      bounded above by compute + dispatch overheads.
  P4. Mutual exclusion: critical sections never overlap.
  P5. Determinism: the sim is reproducible (same seed -> same makespan).
"""

import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; deterministic seeded equivalents run "
    "in tests/test_sched_fastpath.py",
)
from hypothesis import given, settings, strategies as hst

from repro.core import simtask as st
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair, SchedRR
from repro.core.task import Job
from repro.core.topology import Topology

# an op-program is a list of (kind, value) drawn from this:
_op = hst.one_of(
    hst.tuples(hst.just("compute"), hst.floats(0.0005, 0.02)),
    hst.tuples(hst.just("crit"), hst.floats(0.0005, 0.01)),  # lock+compute+unlock
    hst.tuples(hst.just("sleep"), hst.floats(0.0005, 0.01)),
    hst.tuples(hst.just("yield"), hst.just(0.0)),
)

workloads = hst.tuples(
    hst.integers(1, 4),                      # n_slots
    hst.integers(1, 3),                      # n_jobs
    hst.lists(hst.lists(_op, min_size=1, max_size=5), min_size=1, max_size=10),
)

policies = hst.sampled_from(["coop", "fair", "rr"])


def _mk_policy(name):
    return {
        "coop": lambda: SchedCoop(quantum=0.01),
        "fair": lambda: SchedFair(slice_s=0.002),
        "rr": lambda: SchedRR(quantum=0.002),
    }[name]()


@settings(max_examples=40, deadline=None)
@given(workloads, policies)
def test_invariants_random_workloads(workload, polname):
    n_slots, n_jobs, programs = workload
    policy = _mk_policy(polname)
    sim = SimExecutor(Topology(n_slots, 1), policy, max_time=600.0)
    jobs = [Job(f"j{i}") for i in range(n_jobs)]
    mutex = st.SimMutex()
    cs = {"cur": 0, "max": 0}
    requested_compute = 0.0

    def body(prog):
        def gen():
            for kind, v in prog:
                if kind == "compute":
                    yield st.compute(v)
                elif kind == "crit":
                    yield st.lock(mutex)
                    cs["cur"] += 1
                    cs["max"] = max(cs["max"], cs["cur"])
                    yield st.compute(v)
                    cs["cur"] -= 1
                    yield st.unlock(mutex)
                elif kind == "sleep":
                    yield st.sleep(v)
                elif kind == "yield":
                    yield st.yield_()

        return gen

    tasks = []
    for i, prog in enumerate(programs):
        requested_compute += sum(
            v for k, v in prog if k in ("compute", "crit")
        )
        tasks.append(sim.spawn(jobs[i % n_jobs], body(prog)))

    stats = sim.run()

    # P1 completion
    assert all(t.done for t in tasks)
    # P2 preemption discipline
    if polname == "coop":
        assert stats.preemptions == 0
    # P3 work conservation (run_time includes dispatch delays; bound them)
    overhead_bound = stats.dispatches * (
        sim.costs.ctx_switch + sim.costs.dispatch_latency + sim.costs.migration_cross
    )
    assert stats.total_run_time >= requested_compute - 1e-9
    assert stats.total_run_time <= requested_compute + overhead_bound + 1e-9
    # P4 mutual exclusion
    assert cs["max"] <= 1
    # slots never oversubscribed in accounting: busy fraction <= 1 (+eps)
    assert stats.slot_busy_fraction <= 1.0 + 1e-6


@settings(max_examples=10, deadline=None)
@given(workloads)
def test_simulation_deterministic(workload):
    """P5: two identical runs produce identical makespans and stats."""
    n_slots, n_jobs, programs = workload

    def run_once():
        sim = SimExecutor(Topology(n_slots, 1), SchedCoop(), max_time=600.0)
        jobs = [Job(f"j{i}") for i in range(n_jobs)]

        def body(prog):
            def gen():
                for kind, v in prog:
                    if kind in ("compute", "crit"):
                        yield st.compute(v)
                    elif kind == "sleep":
                        yield st.sleep(v)
                    else:
                        yield st.yield_()

            return gen

        for i, prog in enumerate(programs):
            sim.spawn(jobs[i % n_jobs], body(prog))
        s = sim.run()
        return (s.makespan, s.dispatches, s.migrations, s.tasks_completed)

    assert run_once() == run_once()


@settings(max_examples=15, deadline=None)
@given(
    hst.integers(2, 6),   # parties
    hst.integers(1, 8),   # slots
    hst.integers(1, 16),  # yield_every
)
def test_spin_barrier_always_completes_with_yield(parties, n_slots, yield_every):
    """The §5.2 adaptation guarantees progress for ANY (parties, slots)
    combination under SCHED_COOP — even parties >> slots."""
    sim = SimExecutor(Topology(n_slots, 1), SchedCoop(), max_time=300.0)
    job = Job("j")
    bar = st.SimSpinBarrier(parties, yield_every=yield_every)

    def body():
        yield st.compute(0.001)
        yield st.spin_barrier_wait(bar)
        yield st.compute(0.001)

    tasks = [sim.spawn(job, body) for _ in range(parties)]
    sim.run()
    assert all(t.done for t in tasks)


@settings(max_examples=15, deadline=None)
@given(hst.integers(1, 3), hst.integers(2, 12))
def test_fifo_mutex_order_any_shape(n_slots, n_waiters):
    """P-FIFO: mutex handoff strictly follows arrival order regardless of
    slot count (Listing 1's explicit FIFO queue)."""
    sim = SimExecutor(Topology(n_slots, 1), SchedCoop(), max_time=300.0)
    job = Job("j")
    m = st.SimMutex()
    order = []

    def body(i):
        def gen():
            yield st.compute(0.001 * (i + 1))  # distinct arrival times
            yield st.lock(m)
            order.append(i)
            yield st.compute(0.005)
            yield st.unlock(m)

        return gen

    for i in range(n_waiters):
        sim.spawn(job, body(i))
    sim.run()
    assert order == sorted(order)
