"""Multi-process serving (repro.serve.multiproc): N model-server
processes behind one gateway, node slots brokered across them. Slow: the
server children each initialize their own JAX runtime."""

import pytest

pytestmark = pytest.mark.slow


@pytest.mark.parametrize("coordinate", [True, False])
def test_multiprocess_gateway_serves(coordinate):
    """Requests fan out to every server process and join; with
    coordination the broker splits the node, without it the processes run
    free — both complete (coordination is never a liveness dependency)."""
    from repro.serve.multiproc import MultiProcessGateway

    gw = MultiProcessGateway(
        {"srv-a": "smollm_360m", "srv-b": "qwen1_5_110b"},
        coordinate=coordinate, node_capacity=2, slots_per_server=2,
        max_batch=2, max_len=32, smoke=True)
    try:
        gw.start(ready_timeout=300.0)
        if coordinate:
            snap = gw.broker.snapshot()
            assert sorted(snap["workers"]) == ["srv-a", "srv-b"]
            assert sum(w["granted"] for w in snap["workers"].values()) == 2
        for _ in range(2):
            rec = gw.handle([5, 6, 7], max_new=3, timeout=300.0)
            assert rec["latency"] > 0
            assert sorted(rec["outputs"]) == ["srv-a", "srv-b"]
            for out in rec["outputs"].values():
                assert len(out) == 3
        assert len(gw.responses) == 2
        if coordinate:
            # each server pump reported its brokered grant with results
            assert all(s.served == 2 for s in gw.servers)
    finally:
        gw.stop()


def test_dead_server_process_surfaces_not_hangs():
    """Unsupervised (the PR 5 fail-fast contract): a server process killed
    mid-flight raises ServerProcessError at the caller (and, under
    coordination, its node lease is reclaimed)."""
    from repro.serve.multiproc import MultiProcessGateway, ServerProcessError

    gw = MultiProcessGateway(
        {"srv-a": "smollm_360m", "srv-b": "qwen1_5_110b"},
        coordinate=True, node_capacity=2, slots_per_server=2,
        max_batch=2, max_len=32, smoke=True, supervise=False)
    try:
        gw.start(ready_timeout=300.0)
        gw.handle([5, 6], max_new=2, timeout=300.0)  # warm + sane
        victim = gw.servers[0]
        victim._proc.kill()
        victim._proc.join(10.0)
        with pytest.raises((ServerProcessError, TimeoutError)):
            gw.handle([5, 6], max_new=2, timeout=60.0)
        # the broker reclaimed the dead server's node lease
        import time

        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            workers = gw.broker.snapshot()["workers"]
            if list(workers) == ["srv-b"]:
                break
            time.sleep(0.1)
        assert list(gw.broker.snapshot()["workers"]) == ["srv-b"]
    finally:
        gw.stop()


# --------------------------------------------------------------------- #
# supervision: restart, crash-loop breaker, in-flight retry (PR 6)
# --------------------------------------------------------------------- #
def _wait_until(cond, timeout, step=0.1):
    import time

    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(step)
    return cond()


def test_supervisor_restarts_dead_server_then_breaker_benches_crashloop():
    """A killed server is respawned (capped backoff) and serves again; a
    crash-looping server trips the circuit breaker — the slot is marked
    failed in snapshots and requests keep routing to the survivors."""
    from repro.serve.multiproc import MultiProcessGateway

    gw = MultiProcessGateway(
        {"srv-a": "smollm_360m", "srv-b": "qwen1_5_110b"},
        coordinate=True, node_capacity=2, slots_per_server=2,
        max_batch=2, max_len=32, smoke=True,
        supervise=True, max_restarts=2, restart_window=600.0,
        restart_backoff=(0.1, 0.4), poll_interval=0.1)
    try:
        gw.start(ready_timeout=300.0)
        gw.handle([5, 6], max_new=2, timeout=300.0)  # warm + sane
        victim = gw.servers[0]

        # phase 1: heal — a dead server is restarted and serves again
        victim._proc.kill()
        assert _wait_until(lambda: victim.restarts >= 1 and victim.alive(),
                           timeout=300.0)
        rec = gw.handle([5, 6], max_new=2, timeout=300.0)
        assert sorted(rec["outputs"]) == ["srv-a", "srv-b"]
        assert rec["retried"] == {}
        snap = gw.snapshot()
        assert snap["servers"]["srv-a"]["restarts"] >= 1
        assert snap["servers"]["srv-a"]["failed"] is False

        # phase 2: crash loop — every respawn now dies during init, so
        # the window fills and the breaker opens (slot benched, routed
        # around), instead of burning the node respawning forever
        victim.spec["arch"] = "no-such-arch"
        victim._proc.kill()
        assert _wait_until(lambda: victim.failed, timeout=300.0)
        snap = gw.snapshot()
        assert snap["servers"]["srv-a"]["failed"] is True
        rec = gw.handle([5, 6], max_new=2, timeout=300.0)
        assert list(rec["outputs"]) == ["srv-b"]  # survivors keep serving
    finally:
        gw.stop()


def test_inflight_request_retried_once_on_survivor():
    """A request in flight on a dying server is retried once on a
    survivor and recorded under the dead server's key with a
    ``retried_on`` marker, instead of surfacing ServerProcessError."""
    from repro.serve.multiproc import MultiProcessGateway

    # quiescent supervisor (long poll): the restart machinery must not
    # race the deterministic in-flight window this test pins below
    gw = MultiProcessGateway(
        {"srv-a": "smollm_360m", "srv-b": "qwen1_5_110b"},
        coordinate=True, node_capacity=2, slots_per_server=2,
        max_batch=2, max_len=32, smoke=True,
        supervise=True, poll_interval=60.0)
    try:
        gw.start(ready_timeout=300.0)
        gw.handle([5, 6], max_new=2, timeout=300.0)  # warm + sane
        victim = gw.servers[0]
        victim._proc.kill()
        victim._proc.join(30.0)
        # pin the window: the gateway targets the (already dead) server
        # exactly once more, so the submitted request is provably in
        # flight on a dead process when the collector reaches it
        forced = []
        real_alive = victim.alive

        def one_last_alive():
            if not forced:
                forced.append(1)
                return True
            return real_alive()

        victim.alive = one_last_alive
        try:
            rec = gw.handle([5, 6], max_new=2, timeout=300.0)
        finally:
            victim.alive = real_alive
        assert sorted(rec["outputs"]) == ["srv-a", "srv-b"]
        assert rec["retried"] == {"srv-a": "srv-b"}
    finally:
        gw.stop()
