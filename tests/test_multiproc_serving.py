"""Multi-process serving (repro.serve.multiproc): N model-server
processes behind one gateway, node slots brokered across them. Slow: the
server children each initialize their own JAX runtime."""

import pytest

pytestmark = pytest.mark.slow


@pytest.mark.parametrize("coordinate", [True, False])
def test_multiprocess_gateway_serves(coordinate):
    """Requests fan out to every server process and join; with
    coordination the broker splits the node, without it the processes run
    free — both complete (coordination is never a liveness dependency)."""
    from repro.serve.multiproc import MultiProcessGateway

    gw = MultiProcessGateway(
        {"srv-a": "smollm_360m", "srv-b": "qwen1_5_110b"},
        coordinate=coordinate, node_capacity=2, slots_per_server=2,
        max_batch=2, max_len=32, smoke=True)
    try:
        gw.start(ready_timeout=300.0)
        if coordinate:
            snap = gw.broker.snapshot()
            assert sorted(snap["workers"]) == ["srv-a", "srv-b"]
            assert sum(w["granted"] for w in snap["workers"].values()) == 2
        for _ in range(2):
            rec = gw.handle([5, 6, 7], max_new=3, timeout=300.0)
            assert rec["latency"] > 0
            assert sorted(rec["outputs"]) == ["srv-a", "srv-b"]
            for out in rec["outputs"].values():
                assert len(out) == 3
        assert len(gw.responses) == 2
        if coordinate:
            # each server pump reported its brokered grant with results
            assert all(s.served == 2 for s in gw.servers)
    finally:
        gw.stop()


def test_dead_server_process_surfaces_not_hangs():
    """A server process killed mid-flight raises ServerProcessError at the
    caller (and, under coordination, its node lease is reclaimed)."""
    from repro.serve.multiproc import MultiProcessGateway, ServerProcessError

    gw = MultiProcessGateway(
        {"srv-a": "smollm_360m", "srv-b": "qwen1_5_110b"},
        coordinate=True, node_capacity=2, slots_per_server=2,
        max_batch=2, max_len=32, smoke=True)
    try:
        gw.start(ready_timeout=300.0)
        gw.handle([5, 6], max_new=2, timeout=300.0)  # warm + sane
        victim = gw.servers[0]
        victim._proc.kill()
        victim._proc.join(10.0)
        with pytest.raises((ServerProcessError, TimeoutError)):
            gw.handle([5, 6], max_new=2, timeout=60.0)
        # the broker reclaimed the dead server's node lease
        import time

        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            workers = gw.broker.snapshot()["workers"]
            if list(workers) == ["srv-b"]:
                break
            time.sleep(0.1)
        assert list(gw.broker.snapshot()["workers"]) == ["srv-b"]
    finally:
        gw.stop()
