"""Model-based scheduler fuzz: the any↔any migration matrix under random ops.

A deterministic seeded driver applies random operations —
spawn / wake / advance-virtual-time / request-preempt / attach (promote) /
attach (live policy swap) / demote / detach / ``lease.resize`` — to a
``SimExecutor`` while a flat reference model independently tracks every
task's lifecycle (wakes owed vs delivered, completion) and every job's
expected group kind. After each operation the sim is advanced and
cross-checked against the model:

* **I1**: at most one RUNNING task per slot; the slot table, the idle
  free-list and every task's ``slot`` field agree;
* **I2** (era-aware, per job): a job never accrues preemptions while its
  current policy is cooperative — including after swapping OUT of a
  preemptive policy mid-run;
* **I3**: a delivered wake leaves the task READY or (re)dispatched by the
  policy — never still BLOCKED;
* **I5**: the grant rule, via a pick wrapper re-installed after every
  lifecycle op (group changes rebind the arbiter's entry points);
* **conservation / exactly-once**: per job, the owning policy's
  ``ready_count_of`` equals a census of its READY tasks (a task lost in
  migration under-counts; a duplicated one over-counts and would also
  trip I1), the arbiter's global ready_count matches, and at the end
  every task is DONE with executor-observed dispatch callbacks equal to
  ``task.stats.dispatches``.

Half the seeds run the whole program under a ``DeadlineArbiter`` with
mixed traffic — tasks randomly carry deadlines (sometimes overdue on
arrival, firing the urgent grant path mid-fuzz) and engine-level
``post_deadline``/``retire_deadline`` obligation churn rides alongside
the op stream — asserting that EDF tie-breaking, urgency-boosted quotas
and urgent grants preserve every invariant above bit-for-bit.

Every migration op is classified into the 3x3 matrix of
(source, destination) group kinds — ``default`` / ``coop`` (dedicated
cooperative) / ``preempt`` (dedicated preemptive). ``attach`` covers the
promote and swap edges, ``demote`` the dedicated→default edges, and a
quiescent ``detach`` followed by dynamic re-registration on wakeup covers
default→default. The suite asserts all nine edges are exercised across
the seeded sweep (I4 — parked-not-destroyed workers — is executor-level
and covered by tests/test_threads.py).
"""

import random
from collections import Counter

import pytest

from repro.core import simtask as st
from repro.core.arbiter import ArbiterError
from repro.core.autockpt import preemptible_body
from repro.core.deadline import DeadlineArbiter
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair, SchedRR
from repro.core.task import Job, TaskState
from repro.core.topology import Topology

N_SEEDS = 50
KINDS = ("default", "coop", "preempt")
ALL_EDGES = {(a, b) for a in KINDS for b in KINDS}

#: (source, destination) group-kind edges exercised, accumulated across
#: the whole seeded sweep and asserted complete at the end of the module
EDGES_SEEN: set = set()
#: seeds that actually ran this session — the coverage assertion only
#: applies to a FULL sweep (a -k subset must not fail it spuriously)
SEEDS_RUN: set = set()


def kind_of(job) -> str:
    lease = job.lease
    if lease is None or not lease.group.dedicated:
        return "default"
    return "preempt" if lease.group.policy.preemptive else "coop"


def make_policy(rng, dst_kind):
    if dst_kind == "coop":
        return SchedCoop(quantum=rng.choice((0.005, 0.02)))
    return rng.choice((
        lambda: SchedFair(slice_s=rng.choice((0.001, 0.003))),
        lambda: SchedRR(quantum=rng.choice((0.001, 0.004))),
    ))()


class TaskModel:
    """Flat per-task reference state: how many blocking waits its program
    contains vs how many wakes the driver has delivered."""

    __slots__ = ("task", "sem", "blocks_total", "wakes_sent")

    def __init__(self, task, sem, blocks_total):
        self.task = task
        self.sem = sem
        self.blocks_total = blocks_total
        self.wakes_sent = 0

    @property
    def wakes_owed(self) -> int:
        return self.blocks_total - self.wakes_sent


def spawn_task(sim, rng, job, *, deadline=None) -> TaskModel:
    sem = st.SimSemaphore(0)
    ops = []
    n_blocks = 0
    for _ in range(rng.randint(2, 6)):
        k = rng.random()
        if k < 0.40:
            ops.append(("compute", rng.uniform(3e-4, 4e-3)))
        elif k < 0.55:
            ops.append(("sleep", rng.uniform(3e-4, 4e-3)))
        elif k < 0.70:
            ops.append(("yield",))
        elif k < 0.85:
            ops.append(("checkpoint",))
        else:
            ops.append(("block",))
            n_blocks += 1

    def gen():
        for op in ops:
            if op[0] == "compute":
                yield st.compute(op[1])
            elif op[0] == "sleep":
                yield st.sleep(op[1])
            elif op[0] == "yield":
                yield st.yield_()
            elif op[0] == "checkpoint":
                yield st.checkpoint()
            else:
                yield st.sem_acquire(sem)

    # half the fuzz programs run auto-instrumented (repro.core.autockpt):
    # checkpoints injected between ops must preserve every invariant —
    # they are extra scheduling points, never extra blocks or wakes
    body = (preemptible_body(gen, every=rng.choice((1, 2, 3)))
            if rng.random() < 0.5 else gen)
    task = sim.spawn(job, body, deadline=deadline)
    return TaskModel(task, sem, n_blocks)


def maybe_deadline(sim, rng):
    """A task deadline for the DeadlineArbiter seeds: usually a small
    positive horizon, sometimes already overdue (exercising the urgent
    grant path mid-fuzz), often absent (mixed traffic)."""
    k = rng.random()
    if k < 0.50:
        return None
    if k < 0.85:
        return sim.now() + rng.uniform(0.001, 0.05)
    return sim.now() - rng.uniform(0.0, 0.01)  # overdue on arrival


def deliver_wake(sim, tm: TaskModel) -> None:
    """Replicate the engine's sem_release semantics from outside a task
    (safe between run() calls: the sim is not mid-drain)."""
    tm.wakes_sent += 1
    if tm.sem.queue:
        sim.sched.unblock(tm.sem.queue.popleft())
    else:
        tm.sem.value += 1


def install_i5(sim, violations: list) -> None:
    """Wrap the arbiter's (re)bound pick with the I5 grant-rule check.
    Must be re-installed after every op that rebinds the entry points."""
    arb = sim.sched.arbiter
    orig_pick = arb.pick

    def checked(slot_id):
        task = orig_pick(slot_id)
        if task is not None and arb.multi:
            g = task.job.lease.group
            if g.in_use >= g.quota:  # borrowing grant (in_use not bumped yet)
                for h in arb.groups():
                    if h is not g and h.in_use < h.quota \
                            and h.policy.has_ready():
                        violations.append(
                            f"I5: {g!r} granted slot {slot_id} while {h!r} "
                            f"had ready work and spare lease")
        return task

    arb.pick = checked


def check_model(sim, jobs, coop_base) -> None:
    """The flat cross-check run after every driver op."""
    sched = sim.sched
    # I1: slot table, idle free-list and task.slot agree; one task per slot
    seen_tids = set()
    for sid, sl in enumerate(sched._slots):
        t = sl.running
        if t is None:
            assert sid in sched._idle, f"idle slot {sid} missing from free-list"
        else:
            assert sid not in sched._idle
            assert t.state is TaskState.RUNNING and t.slot == sid
            assert t.tid not in seen_tids, f"task {t.tid} on two slots"
            seen_tids.add(t.tid)
    for t in sched.all_tasks:
        if t.state is TaskState.RUNNING:
            assert t.slot is not None and sched._slots[t.slot].running is t

    # conservation / exactly-once queueing across every migration edge
    total_ready = 0
    for job in jobs:
        expect = sum(1 for t in job.tasks if t.state is TaskState.READY)
        total_ready += expect
        if job.lease is None:
            assert expect == 0, f"detached {job} holds READY tasks"
            continue
        pol = sched.arbiter.policy_of(job)
        got = pol.ready_count_of(job)
        assert got == expect, (
            f"{job}: policy {pol.name} holds {got} READY tasks, "
            f"census says {expect} (lost or duplicated in migration)")
    assert sched.arbiter.ready_count() == total_ready

    # I2, era-aware: no preemption accrual while cooperatively scheduled
    for job in jobs:
        base = coop_base.get(job.jid)
        if base is not None and job.lease is not None \
                and not sched.arbiter.policy_of(job).preemptive:
            cur = sum(t.stats.preemptions for t in job.tasks)
            assert cur == base, (
                f"I2: {job} preempted under a cooperative policy "
                f"({cur} vs era baseline {base})")


def note_policy_era(sim, job, coop_base) -> None:
    """(Re)baseline the I2 era whenever a job's policy may have changed."""
    if job.lease is None:
        coop_base.pop(job.jid, None)
    elif sim.sched.arbiter.policy_of(job).preemptive:
        coop_base.pop(job.jid, None)
    else:
        coop_base[job.jid] = sum(t.stats.preemptions for t in job.tasks)


def run_fuzz(seed: int) -> set:
    rng = random.Random(seed)
    n_slots = rng.choice((2, 3, 4, 8))
    # half the sweep runs under the DeadlineArbiter with mixed traffic
    # (deadline and plain tasks, posted-deadline churn): every invariant
    # below must hold unchanged under EDF tie-breaking and urgent grants
    use_deadline = seed % 2 == 0
    default_pol = SchedCoop(quantum=0.01)
    arb = DeadlineArbiter(default_pol) if use_deadline else None
    sim = SimExecutor(Topology(n_slots, 1), default_pol,
                      max_time=1e9, arbiter=arb)

    dispatch_counts: Counter = Counter()
    orig_cb = sim.sched._dispatch_cb

    def counting_cb(task, slot_id):
        dispatch_counts[task.tid] += 1
        orig_cb(task, slot_id)

    sim.sched._dispatch_cb = counting_cb

    i5_violations: list = []
    edges: set = set()
    coop_base: dict = {}
    detached_kind: dict = {}  # jid -> kind the job had before detach

    jobs = [Job(f"fz{seed}-{i}") for i in range(rng.randint(2, 4))]
    models: list[TaskModel] = []
    posted: list = []  # (job, token) obligations awaiting retire
    for job in jobs:
        for _ in range(rng.randint(1, 3)):
            dl = maybe_deadline(sim, rng) if use_deadline else None
            models.append(spawn_task(sim, rng, job, deadline=dl))
        note_policy_era(sim, job, coop_base)
    install_i5(sim, i5_violations)

    def advance(dt: float) -> None:
        sim.run(until=sim.now() + dt)

    for _ in range(rng.randint(30, 60)):
        op = rng.random()
        job = rng.choice(jobs)
        if op < 0.18:  # spawn more work
            dl = maybe_deadline(sim, rng) if use_deadline else None
            models.append(spawn_task(sim, rng, job, deadline=dl))
        elif op < 0.38:  # wake a blocked-or-soon-blocking task
            owed = [m for m in models if m.wakes_owed > 0]
            if owed:
                tm = rng.choice(owed)
                # blocked on the sem itself (not e.g. mid-sleep)?
                was_queued = tm.task in tm.sem.queue
                deliver_wake(sim, tm)
                if was_queued:  # I3: queued/dispatched, never left BLOCKED
                    assert tm.task.state is not TaskState.BLOCKED
        elif op < 0.50:  # attach: promote or live policy swap
            src = kind_of(job)
            dst = rng.choice(("coop", "preempt"))
            try:
                sim.attach(job, policy=make_policy(rng, dst),
                           share=rng.choice((0.5, 1.0, 2.0, 4.0)))
            except ArbiterError:
                pytest.fail(f"seed {seed}: live {src}->{dst} attach refused")
            edges.add((src, dst))
            install_i5(sim, i5_violations)
            note_policy_era(sim, job, coop_base)
        elif op < 0.58:  # demote back into the default group
            if kind_of(job) != "default":
                edges.add((kind_of(job), "default"))
                sim.demote(job, share=rng.choice((None, 1.0, 2.0)))
                install_i5(sim, i5_violations)
                note_policy_era(sim, job, coop_base)
        elif op < 0.66:  # detach: teardown only, quiescence-enforced
            busy = [t for t in job.tasks
                    if t.state in (TaskState.READY, TaskState.RUNNING)]
            if job.lease is None:
                pass  # already detached, waiting for re-registration
            elif busy:
                with pytest.raises(ArbiterError) as exc:
                    sim.detach(job)
                # the satellite fix: the refusal enumerates the offenders
                msg = str(exc.value)
                assert f"#{busy[0].tid}" in msg and busy[0].name in msg
            else:
                detached_kind[job.jid] = kind_of(job)
                sim.detach(job)
                install_i5(sim, i5_violations)
                note_policy_era(sim, job, coop_base)
        elif op < 0.74:  # elastic resize
            if job.lease is not None:
                job.lease.resize(rng.choice((0.5, 1.0, 3.0, 6.0)))
        elif op < 0.80:  # external preemption request against a busy slot
            busy_slots = [sid for sid, sl in enumerate(sim.sched._slots)
                          if sl.running is not None]
            if busy_slots:
                sim.sched.request_preempt(rng.choice(busy_slots))
        else:  # let virtual time run
            advance(rng.uniform(0.001, 0.01))

        # deadline-seed rider: engine-level posted-obligation churn (the
        # serve-gateway pattern) interleaved with everything above —
        # posts are sometimes already overdue, firing the urgent path
        # mid-fuzz; retires hit both heap-top and out-of-order tokens
        if use_deadline and rng.random() < 0.25:
            darb = sim.sched.arbiter
            if posted and rng.random() < 0.5:
                j, tok = posted.pop(rng.randrange(len(posted)))
                darb.retire_deadline(j, tok)
            else:
                dl = sim.now() + rng.uniform(-0.005, 0.05)
                posted.append((job, darb.post_deadline(job, dl)))

        advance(rng.uniform(0.0005, 0.004))
        # dynamic re-registration closes the detach edge of the matrix
        for jid, src in list(detached_kind.items()):
            j = next(x for x in jobs if x.jid == jid)
            if j.lease is not None:
                edges.add((src, kind_of(j)))
                del detached_kind[jid]
                note_policy_era(sim, j, coop_base)
                install_i5(sim, i5_violations)  # re-registration rebound pick
        check_model(sim, jobs, coop_base)
        assert not i5_violations, f"seed {seed}: {i5_violations[:3]}"

    # drain: retire outstanding obligations, deliver every owed wake,
    # then run to completion
    for j, tok in posted:
        sim.sched.arbiter.retire_deadline(j, tok)
    for tm in models:
        while tm.wakes_owed > 0:
            deliver_wake(sim, tm)
    sim.run()
    check_model(sim, jobs, coop_base)
    assert not i5_violations, f"seed {seed}: {i5_violations[:3]}"

    assert all(m.task.done for m in models), f"seed {seed}: lost tasks"
    assert len(sim.sched.all_tasks) == len(models)  # registry intact (I4-ish)
    for m in models:
        assert dispatch_counts[m.task.tid] == m.task.stats.dispatches, (
            f"seed {seed}: task {m.task.tid} saw "
            f"{dispatch_counts[m.task.tid]} executor dispatches vs "
            f"{m.task.stats.dispatches} accounted (lost/duplicated)")
    return edges


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_fuzz_migration_matrix(seed):
    SEEDS_RUN.add(seed)
    EDGES_SEEN.update(run_fuzz(seed))


def test_fuzz_deterministic():
    """The driver is fully deterministic: re-running a seed reproduces the
    identical edge set, makespan and dispatch census."""

    def once():
        rng_probe = random.Random(7)
        _ = rng_probe  # seeds are independent of global random state
        return sorted(run_fuzz(4242))

    assert once() == once()


def test_all_nine_migration_edges_covered():
    """Runs after the seeded sweep (pytest executes in definition order):
    every (source, destination) pair of the 3x3 group-kind matrix must
    have been exercised with zero invariant violations. Only a FULL sweep
    is held to full coverage — under -k / distributed subsets this skips
    rather than fail on edges the deselected seeds would have hit."""
    if len(SEEDS_RUN) < N_SEEDS:
        pytest.skip(f"only {len(SEEDS_RUN)}/{N_SEEDS} sweep seeds ran; "
                    "full-matrix coverage is asserted on the full sweep")
    missing = ALL_EDGES - EDGES_SEEN
    assert not missing, f"migration edges never exercised: {sorted(missing)}"
