"""Unit + property tests for the logical-axis sharding rules.

The shape/axes property sweep uses hypothesis when installed; otherwise a
seeded-random fallback covers the same domain so nothing silently skips
(the dry-run integration test asserts a skip-free run of this file).
"""

import random

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.analysis.hlo import (
    CollectiveOp,
    collective_bytes_per_device,
    parse_collectives,
)
from repro.launch.mesh import make_mesh
from repro.runtime.sharding import DEFAULT_RULES, Sharder, logical_to_spec


@pytest.fixture(scope="module")
def mesh():
    if jax.device_count() < 8:
        pytest.skip("needs >=8 devices (run under dry-run env)")
    return make_mesh((2, 4), ("data", "model"))


def mk_mesh():
    n = jax.device_count()
    if n < 8:
        pytest.skip("needs >=8 devices")
    return make_mesh((2, 4), ("data", "model"))


def test_basic_mapping():
    mesh = mk_mesh()
    spec = logical_to_spec((64, 128), ("embed", "mlp"), mesh)
    assert spec == P("data", "model")


def test_auto_drop_non_divisible():
    mesh = mk_mesh()
    # 6 kv heads on a 4-way model axis -> replicated
    spec = logical_to_spec((64, 6, 16), ("embed", "kv_heads", None), mesh)
    assert spec == P("data")
    # batch=1 cannot shard
    spec = logical_to_spec((1, 128), ("batch", None), mesh)
    assert spec == P()


def test_no_axis_reuse_within_tensor():
    mesh = mk_mesh()
    # both dims prefer "model": second one must drop it
    spec = logical_to_spec((8, 8), ("mlp", "heads"), mesh)
    assert spec == P("model")


def test_multi_axis_batch():
    if jax.device_count() < 8:
        pytest.skip("needs >=8 devices")
    mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    spec = logical_to_spec((8, 16), ("batch", None), mesh3)
    assert spec == P(("pod", "data"))


def test_partial_multi_axis_when_divisibility_limits():
    if jax.device_count() < 8:
        pytest.skip("needs >=8 devices")
    mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    # dim 2 divisible by pod(2) but not pod*data(4)
    spec = logical_to_spec((2, 16), ("batch", None), mesh3)
    assert spec == P("pod")


_LOGICAL_AXES = [None, "batch", "embed", "mlp", "heads", "kv_heads",
                 "vocab", "experts", "act_seq"]


def _check_spec_valid(dims):
    """Property: any (shape, axes) resolves to a spec whose mesh axes are
    unique and divide the corresponding dims."""
    mesh = mk_mesh()
    shape = tuple(d for d, _ in dims)
    axes = tuple(a for _, a in dims)
    spec = logical_to_spec(shape, axes, mesh)
    seen = set()
    for i, part in enumerate(tuple(spec)):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        prod = 1
        for m in parts:
            assert m not in seen
            seen.add(m)
            prod *= mesh.shape[m]
        assert shape[i] % prod == 0


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(
        hst.lists(
            hst.tuples(
                hst.integers(1, 512),
                hst.sampled_from(_LOGICAL_AXES),
            ),
            min_size=1,
            max_size=4,
        )
    )
    def test_spec_always_valid(dims):
        _check_spec_valid(dims)

else:

    @pytest.mark.parametrize("seed", range(50))
    def test_spec_always_valid(seed):
        rng = random.Random(seed)
        dims = [
            (rng.randint(1, 512), rng.choice(_LOGICAL_AXES))
            for _ in range(rng.randint(1, 4))
        ]
        _check_spec_valid(dims)


def test_sharder_noop_without_mesh():
    s = Sharder(None)
    x = np.ones((4, 4))
    assert s.constrain(x, "batch", None) is x


# --------------------------------------------------------------------------- #
# distributed-optimization helpers (run under the 8-fake-device subprocess)
# --------------------------------------------------------------------------- #
def test_quantize_roundtrip():
    import jax.numpy as jnp

    from repro.runtime.dist import dequantize_int8, quantize_int8

    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 3.0,
                    jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(s) * 0.51)


def test_compressed_psum_matches_fp32():
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.runtime.dist import compressed_psum

    if jax.device_count() < 8:
        pytest.skip("needs >=8 devices")
    mesh = make_mesh((8,), ("pod",))
    x = jnp.asarray(
        np.random.default_rng(1).normal(size=(8, 128)), jnp.float32
    )
    f = shard_map(lambda a: compressed_psum(a, "pod"), mesh=mesh,
                  in_specs=P("pod"), out_specs=P("pod"))
    got = np.asarray(f(x))
    want = np.asarray(x.sum(0, keepdims=True))
    # every shard holds the (quantized) global sum
    for i in range(8):
        np.testing.assert_allclose(got[i], want[0], atol=0.2, rtol=0.05)


def test_topk_error_feedback_conserves_mass():
    import jax.numpy as jnp

    from repro.runtime.dist import topk_compress

    g = jnp.asarray(np.random.default_rng(2).normal(size=(256,)), jnp.float32)
    err = jnp.zeros_like(g)
    sparse, new_err = topk_compress(g, err, frac=0.1)
    # decomposition is exact
    np.testing.assert_allclose(np.asarray(sparse + new_err), np.asarray(g),
                               rtol=1e-6)
    assert int((np.asarray(sparse) != 0).sum()) <= 26 + 5  # ~top 10% (+ties)


def test_gpipe_matches_sequential():
    import jax.numpy as jnp

    from repro.runtime.pipeline import gpipe_forward

    if jax.device_count() < 8:
        pytest.skip("needs >=8 devices")
    mesh = make_mesh((4,), ("pipe",))
    # stage i: y = x * w_i + i-agnostic bias stored in params
    ws = jnp.asarray([[1.5], [0.5], [2.0], [1.0]], jnp.float32)  # [4,1]

    def stage(w, x):
        return x * w[0]

    xs = jnp.arange(24, dtype=jnp.float32).reshape(6, 4)  # 6 microbatches
    out = gpipe_forward(mesh, stage, ws, xs, axis="pipe")
    want = xs * 1.5 * 0.5 * 2.0 * 1.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


# --------------------------------------------------------------------------- #
# HLO collective parsing
# --------------------------------------------------------------------------- #
HLO_SAMPLE = """
  %all-reduce = f32[32,128]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  ROOT %ag = bf16[4,256]{1,0} all-gather(%p), channel_id=2, replica_groups=[4,2]<=[2,4]T(1,0), dimensions={0}
  %rs = f32[8]{0} reduce-scatter(%x), channel_id=3, replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = u32[16]{0} collective-permute(%y), channel_id=4, source_target_pairs={{0,1}}
  %nota = f32[2]{0} add(%a, %b)
"""


def test_parse_collectives_kinds_and_bytes():
    ops = parse_collectives(HLO_SAMPLE)
    kinds = [o.kind for o in ops]
    assert kinds == ["all-reduce", "all-gather", "reduce-scatter",
                     "collective-permute"]
    ar, ag, rs, cp = ops
    assert ar.out_bytes == 32 * 128 * 4 and ar.group_size == 4
    assert ag.out_bytes == 4 * 256 * 2 and ag.group_size == 2
    assert rs.out_bytes == 8 * 4 and rs.group_size == 4
    assert cp.out_bytes == 16 * 4


def test_collective_traffic_model():
    ar = CollectiveOp("all-reduce", 1000, 4)
    assert ar.traffic_bytes == pytest.approx(2 * 3 / 4 * 1000)
    rs = CollectiveOp("reduce-scatter", 100, 8)
    assert rs.traffic_bytes == pytest.approx(7 * 100)
    assert CollectiveOp("all-gather", 100, 1).traffic_bytes == 0.0


def test_traffic_summary():
    s = collective_bytes_per_device(HLO_SAMPLE)
    assert s["n_ops"] == 4
    assert s["total_traffic_bytes"] > 0
    assert set(s["by_kind"]) == {"all-reduce", "all-gather",
                                 "reduce-scatter", "collective-permute"}
