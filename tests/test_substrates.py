"""Substrate tests: checkpointing, fault-tolerant trainer, data pipeline,
straggler detection, serving engine under USF."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import get_smoke
from repro.core.policies import SchedCoop
from repro.core.threads import UsfRuntime
from repro.core.topology import Topology
from repro.data.pipeline import SyntheticLMDataset
from repro.train.trainer import StragglerDetector, Trainer, TrainerConfig


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "step": jnp.asarray(7, jnp.int32),
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "opt": {"m": [jnp.zeros((2,)), jnp.full((3,), 2.5)]},
    }
    save_checkpoint(state, str(tmp_path), 7)
    assert latest_step(str(tmp_path)) == 7
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    back = restore_checkpoint(str(tmp_path), 7, target)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_last_k(tmp_path):
    state = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(state, str(tmp_path), s, keep=2)
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert steps == ["step_00000004", "step_00000005"]


def test_data_pipeline_deterministic_and_learnable():
    cfg = get_smoke("smollm_360m")
    ds1 = SyntheticLMDataset(cfg, global_batch=4, seq_len=32, seed=1)
    ds2 = SyntheticLMDataset(cfg, global_batch=4, seq_len=32, seed=1)
    b1, b2 = ds1.batch_at(5), ds2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    b3 = ds1.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_straggler_detector():
    det = StragglerDetector(factor=2.0)
    flags = [det.observe(i, 0.1) for i in range(5)]
    assert not any(flags)
    assert det.observe(5, 0.5)  # 5x the EWMA
    assert det.flagged == [5]
    assert not det.observe(6, 0.1)  # recovered


def test_trainer_loss_decreases(tmp_path):
    cfg = get_smoke("smollm_360m")
    t = Trainer(cfg, TrainerConfig(steps=50, global_batch=4, seq_len=64,
                                   ckpt_dir=None, peak_lr=1e-2, warmup=5,
                                   log_every=100))
    t.run(resume=False)
    losses = [m["loss"] for m in t.metrics_log]
    assert all(np.isfinite(losses))
    # structured bigram stream: CE must fall well below the ~6.0 start
    assert np.mean(losses[-5:]) < 4.0


def test_trainer_crash_restart_is_deterministic(tmp_path):
    """Fault tolerance: crash after 10 steps, resume from checkpoint,
    final state equals the uninterrupted run (deterministic data + step)."""
    cfg = get_smoke("smollm_360m")

    def mk(ckpt_dir, steps):
        return Trainer(cfg, TrainerConfig(
            steps=steps, global_batch=2, seq_len=32, ckpt_every=5,
            ckpt_dir=ckpt_dir, peak_lr=1e-3, warmup=2, seed=3,
        ))

    # uninterrupted reference
    ref_state = mk(None, 14).run(resume=False)

    # interrupted run: "crash" after step 10 (ckpt at 10), resume to 14
    d = str(tmp_path / "ckpt")
    mk(d, 14).run(resume=False, stop_at=10)
    assert latest_step(d) == 10
    resumed = mk(d, 14).run(resume=True)

    for a, b in zip(jax.tree_util.tree_leaves(ref_state["params"]),
                    jax.tree_util.tree_leaves(resumed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-7)


def test_serving_engine_under_usf():
    """Two oversubscribed model servers + gateway on a 2-slot runtime:
    all requests complete; USF gates concurrency; blocking points swap."""
    from repro.serve.engine import Gateway, InferenceServer, Request
    from repro.core.task import Job

    usf = UsfRuntime(Topology(2, 1), SchedCoop(quantum=0.05))
    try:
        s1 = InferenceServer("srv-a", get_smoke("smollm_360m"), usf,
                             max_batch=2, max_len=32, nice=10)
        s2 = InferenceServer("srv-b", get_smoke("qwen1_5_110b"), usf,
                             max_batch=2, max_len=32, nice=10)
        s1.start()
        s2.start()
        gw = Gateway(usf, [s1, s2])
        results = []

        def client():
            results.append(gw.handle([5, 6, 7], max_new=3))

        tasks = [usf.create(client, job=gw.job, name=f"client{i}")
                 for i in range(3)]
        for t in tasks:
            assert usf.join(t, timeout=120.0), "client timed out"
        assert len(results) == 3
        assert s1.served == 3 and s2.served == 3
        for r in results:
            assert r["latency"] > 0

        # live policy change without drain (the rescale-driven swap):
        # s1 swaps to a fresh dedicated policy, s2 demotes into the
        # default group — both keep serving without restarting
        lease1 = s1.set_policy(SchedCoop(quantum=0.02), share=2.0)
        assert lease1.group.dedicated and s1.job.lease is lease1
        lease2 = s2.set_policy(None)
        assert not lease2.group.dedicated
        t = usf.create(client, job=gw.job, name="client-post-swap")
        assert usf.join(t, timeout=120.0), "post-swap client timed out"
        assert s1.served == 4 and s2.served == 4

        s1.stop()
        s2.stop()
    finally:
        usf.shutdown(timeout=5.0)
