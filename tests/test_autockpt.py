"""Auto-checkpoint instrumentation (repro.core.autockpt).

Covers the PR's tentpole contracts:

* wrap idempotence and identity adoption (``preemptible``/``wrap_jit``/
  ``preemptible_body`` are fixed points on their own output);
* the checkpoint-safety bugfix: ``UsfRuntime.checkpoint()`` is a no-op
  from a plain (non-USF) thread and from free-running tasks, on both
  executors — so unconditionally instrumented code runs identically in
  baselines;
* revoke-lands-within-K-dispatches: an elastic shrink against
  auto-wrapped, otherwise uninstrumented CPU-bound tasks parks a slot
  within a bounded number of step dispatches (the previously-unbounded
  case);
* sim/thread lockstep: the same logical program — N compute steps per
  task, instrumented only by the auto-checkpoint wrappers — yields the
  same structural interleaving around a preemption request on the
  ``SimExecutor`` (virtual time) and the ``UsfRuntime`` (real threads):
  the flagged task parks at the next step boundary, the sibling runs to
  completion, the flagged task resumes.
"""

import threading
import time
from types import SimpleNamespace

from repro.core import simtask as st
from repro.core.autockpt import (maybe_checkpoint, preemptible,
                                 preemptible_body, wrap_jit)
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop
from repro.core.task import Job
from repro.core.threads import UsfRuntime
from repro.core.topology import Topology


def counting_runtime():
    calls = [0]

    def ckpt():
        calls[0] += 1

    return SimpleNamespace(checkpoint=ckpt), calls


# --------------------------------------------------------------------------- #
# wrapping contracts
# --------------------------------------------------------------------------- #
def test_preemptible_wrap_idempotent_and_identity():
    rt, calls = counting_runtime()

    def step(x):
        """a docstring"""
        return x + 1

    w = preemptible(step, runtime=rt)
    assert w is not step
    assert preemptible(w, runtime=rt) is w          # fixed point
    assert wrap_jit(w, runtime=rt) is w             # cross-helper too
    assert w.__name__ == "step" and w.__doc__ == "a docstring"
    assert w.__wrapped__ is step
    assert w(41) == 42
    assert calls[0] == 1


def test_wrap_jit_forwards_jit_surface():
    rt, calls = counting_runtime()
    lowered = object()

    class FakeJit:
        """Shape of a jax.jit output: callable + AOT/cache surface."""

        def __call__(self):
            return "y"

        def lower(self):
            return lowered

        def clear_cache(self):
            return "cleared"

    w = wrap_jit(FakeJit(), runtime=rt)
    assert w() == "y" and calls[0] == 1
    assert w.lower() is lowered
    assert w.clear_cache() == "cleared"
    assert wrap_jit(w, runtime=rt) is w  # idempotent through the alias


def test_every_n_counting():
    rt, calls = counting_runtime()
    w = preemptible(lambda: None, runtime=rt, every=3)
    for _ in range(7):
        w()
    assert calls[0] == 2  # calls 3 and 6

    rt2, calls2 = counting_runtime()
    tick = maybe_checkpoint(rt2, every=4)
    for _ in range(10):
        tick()
    assert calls2[0] == 2  # ticks 4 and 8


# --------------------------------------------------------------------------- #
# checkpoint is a safe no-op everywhere (the satellite bugfix)
# --------------------------------------------------------------------------- #
def test_checkpoint_noop_from_plain_thread():
    rt = UsfRuntime(Topology(2, 1), SchedCoop())
    try:
        rt.checkpoint()  # regression: used to raise UsfError
        tick = maybe_checkpoint(rt, every=1)
        tick()
        w = preemptible(lambda: "v", runtime=rt)
        assert w() == "v"
        # and from a plain helper thread, same contract
        err = []

        def helper():
            try:
                rt.checkpoint()
                w()
            except BaseException as e:  # noqa: BLE001
                err.append(e)

        t = threading.Thread(target=helper)
        t.start()
        t.join(5.0)
        assert not err
    finally:
        rt.shutdown(timeout=5.0)


def test_checkpoint_noop_free_running_task():
    """gating=False: instrumented code runs unchanged in the baseline."""
    rt = UsfRuntime(Topology(2, 1), SchedCoop(), gating=False)
    try:
        out = []
        w = preemptible(lambda: out.append("ran"), runtime=rt)

        def body():
            rt.checkpoint()  # free-running task: _slot_state is None
            w()

        task = rt.create(body, job=Job("free"))
        assert rt.join(task, timeout=10.0)
        assert out == ["ran"]
    finally:
        rt.shutdown(timeout=5.0)


def test_sim_checkpoint_noop_unflagged():
    """Sim twin of the no-op contract: a body that is all checkpoints
    completes synchronously when no preemption is pending."""
    sim = SimExecutor(Topology(1, 1), SchedCoop(), max_time=1e9)

    def gen():
        for _ in range(3):
            yield st.checkpoint()

    task = sim.spawn(Job("ck"), preemptible_body(gen))
    sim.run()
    assert task.done
    assert task.stats.preemptions == 0


# --------------------------------------------------------------------------- #
# preemptible_body mechanics
# --------------------------------------------------------------------------- #
def test_preemptible_body_passes_send_values_through():
    sim = SimExecutor(Topology(1, 1), SchedCoop(), max_time=1e9)
    ch = st.SimChannel()
    for item in ("a", "b", None):
        ch.items.append(item)
    got = []

    def gen():
        while True:
            item = yield st.channel_get(ch)
            if item is None:
                return
            got.append(item)
            yield st.compute(1e-4)

    wrapped = preemptible_body(gen, every=1)
    assert preemptible_body(wrapped) is wrapped  # idempotent
    task = sim.spawn(Job("ch"), wrapped)
    sim.run()
    assert task.done
    assert got == ["a", "b"]


# --------------------------------------------------------------------------- #
# revoke-lands-within-K-dispatches (UsfRuntime)
# --------------------------------------------------------------------------- #
def test_revoke_parks_within_k_dispatches():
    """Elastic shrink against auto-wrapped CPU-bound tasks: the surplus
    slot parks within a handful of step dispatches. Without the wrapper
    these bodies have NO scheduling point until they finish — the
    unbounded case this layer exists to close."""
    rt = UsfRuntime(Topology(2, 1), SchedCoop())
    stop = threading.Event()
    steps = [0, 0]
    step_s = 0.002

    def make_step(i):
        def step():
            t_end = time.monotonic() + step_s
            while time.monotonic() < t_end:
                pass
            steps[i] += 1

        return preemptible(step, runtime=rt)

    def make_body(i):
        wstep = make_step(i)

        def body():
            while not stop.is_set():
                wstep()

        return body

    job = Job("revoke")
    tasks = [rt.create(make_body(i), job=job) for i in range(2)]
    try:
        deadline = time.monotonic() + 10.0
        while (steps[0] < 3 or steps[1] < 3) and time.monotonic() < deadline:
            time.sleep(0.001)
        assert steps[0] >= 3 and steps[1] >= 3, "tasks never got going"

        before = sum(steps)
        rt.set_slot_target(1)
        while not rt.sched.parked_slot_ids() and time.monotonic() < deadline:
            time.sleep(0.0002)
        after = sum(steps)
        assert rt.sched.parked_slot_ids(), "revoke never parked a slot"
        # the flagged task parks at its next checkpoint (<= 1 in-flight
        # step + 1 fresh step); the survivor keeps stepping during the
        # poll — bound the TOTAL extra dispatches generously
        K = 5
        assert after - before <= 2 * K, (
            f"revoke-to-park took {after - before} dispatches (> {2 * K})")
    finally:
        stop.set()
        rt.set_slot_target(None)
        for t in tasks:
            assert rt.join(t, timeout=10.0)
        rt.shutdown(timeout=5.0)


# --------------------------------------------------------------------------- #
# sim/thread lockstep
# --------------------------------------------------------------------------- #
N_STEPS = 5


def _run_sim_program(wrap: bool):
    """Two 5-step compute tasks, one slot, SCHED_COOP; a preemption
    request lands mid-step-2 of task A. Returns the (task, step)
    completion order."""
    sim = SimExecutor(Topology(1, 1), SchedCoop(), max_time=1e9)
    trace = []

    def mk(name):
        def gen():
            for k in range(N_STEPS):
                trace.append((name, k))  # logs the step the task REACHED
                yield st.compute(1e-3)

        return preemptible_body(gen) if wrap else gen

    # one job: a consumed preemption lands as nosv_yield, which rotates
    # between the job's tasks (cross-job rotation is quantum-driven and
    # would re-pick the yielder's job)
    job = Job("lockstep")
    ta = sim.spawn(job, mk("A"))
    tb = sim.spawn(job, mk("B"))
    sim.run(until=1.5e-3)          # A is mid-compute of its second step
    sim.sched.request_preempt(0)   # the only slot — A is the victim
    sim.run()
    assert ta.done and tb.done
    return trace


def _structure(trace):
    """(A-steps before B started, B contiguous?, A resumed after B?)"""
    first_b = next(i for i, (n, _) in enumerate(trace) if n == "B")
    b_idx = [i for i, (n, _) in enumerate(trace) if n == "B"]
    a_before = sum(1 for n, _ in trace[:first_b] if n == "A")
    b_contig = b_idx == list(range(first_b, first_b + len(b_idx)))
    a_after = sum(1 for n, _ in trace[b_idx[-1] + 1:] if n == "A")
    return a_before, b_contig, a_after


def test_sim_lockstep_instrumented_vs_not():
    # uninstrumented: coop + no scheduling points -> A runs to completion
    # before B ever starts, despite the pending preemption request
    bare = _run_sim_program(wrap=False)
    assert bare == [("A", k) for k in range(N_STEPS)] + \
                   [("B", k) for k in range(N_STEPS)]
    # instrumented: A parks at the injected checkpoint right after the
    # step the request landed in (step 1 -> 2 steps reached), B runs to
    # completion, A resumes
    wrapped = _run_sim_program(wrap=True)
    a_before, b_contig, a_after = _structure(wrapped)
    assert a_before == 2 and b_contig and a_after == N_STEPS - a_before


def test_thread_lockstep_matches_sim_structure():
    """The real-thread twin of the sim program above: same policy, same
    single slot, same wrapper — the interleaving around the preemption
    request has the same structure (A parks at a step boundary within a
    small jitter window, B runs contiguously, A resumes after)."""
    rt = UsfRuntime(Topology(1, 1), SchedCoop())
    trace = []
    step_s = 0.002

    def mk(name):
        def step():
            t_end = time.monotonic() + step_s
            while time.monotonic() < t_end:
                pass

        wstep = preemptible(step, runtime=rt)

        def body():
            for k in range(N_STEPS):
                wstep()
                trace.append((name, k))

        return body

    job = Job("lockstep")  # one job: same rotation semantics as the sim
    try:
        ta = rt.create(mk("A"), job=job)
        deadline = time.monotonic() + 10.0
        while sum(1 for n, _ in trace if n == "A") < 2 \
                and time.monotonic() < deadline:
            time.sleep(0.0002)
        tb = rt.create(mk("B"), job=job)       # queued: one slot, coop
        rt.sched.request_preempt(0)            # flag A mid-flight
        assert rt.join(ta, timeout=20.0) and rt.join(tb, timeout=20.0)
    finally:
        rt.shutdown(timeout=5.0)

    a_before, b_contig, a_after = _structure(trace)
    # real threads add jitter between the poll and the flag landing: A
    # may complete a couple more steps before its next checkpoint sees
    # the request — but it must park long before finishing, B must run
    # contiguously (coop, no flags on it), and A must resume after
    assert 2 <= a_before <= 4, f"A ran {a_before} steps before parking"
    assert b_contig, f"B's run was interleaved: {trace}"
    assert a_after == N_STEPS - a_before
