"""Equivalence + invariant tests for the scheduler hot-path overhaul.

The incremental-EEVDF ``SchedFair`` and the allocation-free ``SchedCoop``
dispatch must be *behaviourally identical* to the straightforward O(n)/O(n²)
seed implementations — same pick order, same stats, same makespans. These
tests pin that down without depending on hypothesis (seeded ``random`` keeps
them runnable everywhere):

  * ``RefFair`` below IS the seed implementation (O(n) scans over a plain
    ready list), kept as the executable specification;
  * lockstep driving: random on_ready/pick/on_stop traces must produce the
    identical task at every pick, under mixed nice weights and affinities;
  * end-to-end: random sim workloads run under both policies must produce
    identical SchedStats;
  * ``SchedCoop`` dispatch must follow the §4.1 placement order
    affinity -> unaffine -> same domain -> anywhere;
  * the framework invariants I1–I4 (at most one task per slot, coop never
    preempts, unblock queues rather than resumes, determinism) hold on
    random workloads.
"""

import random
from types import SimpleNamespace
from typing import Optional

import pytest

from repro.core import simtask as st
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair, SchedRR
from repro.core.policies.base import Policy, StopReason
from repro.core.task import Job, Task
from repro.core.topology import Topology


# --------------------------------------------------------------------------- #
# the seed SCHED_FAIR as executable specification
# --------------------------------------------------------------------------- #
class RefFair(Policy):
    """Brute-force EEVDF: the pre-overhaul O(n²) implementation, verbatim."""

    name = "REF_FAIR"
    preemptive = True

    def __init__(self, *, slice_s: float = 0.003):
        super().__init__()
        self.slice_s = slice_s
        self.tick_interval = slice_s
        self._ready: list[Task] = []
        self._vruntime: dict[int, float] = {}
        self._run_started: dict[int, float] = {}
        self._min_vruntime = 0.0

    def _w(self, task: Task) -> float:
        return 1024.0 / (1.25 ** task.job.nice)

    def _vr(self, task: Task) -> float:
        return self._vruntime.setdefault(task.tid, self._min_vruntime)

    def _pool_virtual_time(self) -> float:
        if not self._ready:
            return self._min_vruntime
        wsum = sum(self._w(t) for t in self._ready)
        return sum(self._vr(t) * self._w(t) for t in self._ready) / wsum

    def _deadline(self, task: Task) -> float:
        return self._vr(task) + self.slice_s / self._w(task)

    def on_ready(self, task: Task) -> None:
        self._vruntime[task.tid] = max(self._vr(task), self._min_vruntime)
        self._ready.append(task)

    def pick(self, slot_id: int) -> Optional[Task]:
        if not self._ready:
            return None
        V = self._pool_virtual_time()
        eligible = [t for t in self._ready if self._vr(t) <= V + 1e-12]
        pool = eligible if eligible else self._ready
        local = [t for t in pool if t.last_slot in (slot_id, None)]
        best = min(local or pool, key=self._deadline)
        self._ready.remove(best)
        return best

    def on_run(self, task: Task, slot_id: int, now: float) -> None:
        self._run_started[task.tid] = now

    def on_stop(self, task, slot_id, now, elapsed, reason) -> None:
        vr = self._vr(task) + elapsed / self._w(task)
        self._vruntime[task.tid] = vr
        if self._ready:
            self._min_vruntime = max(
                self._min_vruntime, min(self._vr(t) for t in self._ready)
            )
        else:
            self._min_vruntime = max(self._min_vruntime, vr)

    def should_preempt(self, task: Task, slot_id: int, now: float) -> bool:
        if not self._ready:
            return False
        ran = now - self._run_started.get(task.tid, now)
        return ran >= self.slice_s / self._w(task)

    def ready_count(self) -> int:
        return len(self._ready)


# --------------------------------------------------------------------------- #
# lockstep pick-order equivalence on random traces
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(25))
def test_incremental_eevdf_matches_bruteforce(seed):
    rng = random.Random(seed)
    n_slots = rng.randint(1, 8)
    jobs = [Job(f"j{i}", nice=rng.choice([0, 0, 0, 5, 10, -5]))
            for i in range(3)]
    tasks = [Task(jobs[i % 3]) for i in range(rng.randint(1, 40))]
    ref, new = RefFair(slice_s=0.002), SchedFair(slice_s=0.002)
    now = 0.0
    queued: set[int] = set()
    running: dict[int, tuple[Task, int]] = {}
    for step in range(500):
        act = rng.random()
        if act < 0.45 and len(queued) + len(running) < len(tasks):
            cand = [t for t in tasks
                    if t.tid not in queued and t.tid not in running]
            t = rng.choice(cand)
            t.last_slot = rng.choice([None] + list(range(n_slots)))
            ref.on_ready(t)
            new.on_ready(t)
            queued.add(t.tid)
        elif act < 0.8 and queued:
            slot = rng.randrange(n_slots)
            a, b = ref.pick(slot), new.pick(slot)
            assert a is b, f"step {step}: ref picked {a}, new picked {b}"
            queued.discard(a.tid)
            running[a.tid] = (a, slot)
            ref.on_run(a, slot, now)
            new.on_run(a, slot, now)
        elif running:
            tid = rng.choice(sorted(running))
            t, slot = running.pop(tid)
            elapsed = rng.uniform(1e-4, 1e-2)
            now += elapsed
            t.last_slot = slot
            reason = rng.choice(list(StopReason))
            ref.on_stop(t, slot, now, elapsed, reason)
            new.on_stop(t, slot, now, elapsed, reason)
        assert ref.ready_count() == new.ready_count()
        assert ref._min_vruntime == new._min_vruntime


@pytest.mark.parametrize("seed", range(10))
def test_swap_churn_remove_reinsert_preserves_pick_order(seed):
    """The any↔any migration path withdraws a whole job's READY pool
    (``Policy.remove``) and may re-admit it later (e.g. a demote back, or
    repeated policy swaps through the default group). Lockstep SchedFair
    against the RefFair spec under that churn: after every
    withdraw-all/re-admit round the incremental sums, min_vruntime, pool
    virtual time and pick order must stay bit-identical."""
    rng = random.Random(40_000 + seed)
    n_slots = rng.randint(1, 6)
    jobs = [Job(f"sw{seed}-{i}", nice=rng.choice([0, 0, 5, -5]))
            for i in range(3)]
    tasks = [Task(jobs[i % 3]) for i in range(rng.randint(6, 30))]
    ref, new = RefFair(slice_s=0.002), SchedFair(slice_s=0.002)
    ref.remove = lambda t: ref._ready.remove(t)  # list spec of remove()
    now = 0.0
    queued: list[Task] = []
    running: dict[int, tuple[Task, int]] = {}
    withdrawn: list[Task] = []  # a "migrated-away" pool awaiting re-admit
    for step in range(500):
        act = rng.random()
        if act < 0.3 and len(queued) + len(running) + len(withdrawn) \
                < len(tasks):
            cand = [t for t in tasks if t not in queued
                    and t.tid not in running and t not in withdrawn]
            t = rng.choice(cand)
            t.last_slot = rng.choice([None] + list(range(n_slots)))
            ref.on_ready(t)
            new.on_ready(t)
            queued.append(t)
        elif act < 0.45 and queued:
            # the swap: withdraw EVERY queued task of one job, job.tasks
            # order — exactly the arbiter's _withdraw_ready traversal
            job = rng.choice(jobs)
            moving = [t for t in job.tasks if t in queued]
            for t in moving:
                ref.remove(t)
                new.remove(t)
                queued.remove(t)
            withdrawn.extend(moving)
        elif act < 0.6 and withdrawn:
            # the demote-back: re-admit the withdrawn pool in order
            for t in withdrawn:
                ref.on_ready(t)
                new.on_ready(t)
                queued.append(t)
            withdrawn.clear()
        elif act < 0.85 and queued:
            slot = rng.randrange(n_slots)
            a, b = ref.pick(slot), new.pick(slot)
            assert a is b, f"step {step}: ref {a} vs new {b}"
            queued.remove(a)
            running[a.tid] = (a, slot)
            ref.on_run(a, slot, now)
            new.on_run(a, slot, now)
        elif running:
            tid = rng.choice(sorted(running))
            t, slot = running.pop(tid)
            elapsed = rng.uniform(1e-4, 1e-2)
            now += elapsed
            t.last_slot = slot
            ref.on_stop(t, slot, now, elapsed, StopReason.BLOCK)
            new.on_stop(t, slot, now, elapsed, StopReason.BLOCK)
        assert ref.ready_count() == new.ready_count()
        assert ref._min_vruntime == new._min_vruntime
        if new.ready_count():
            assert ref._pool_virtual_time() == pytest.approx(
                new._wvsum / new._wsum, abs=1e-9)
    # drain both pools: identical pick order to the very end
    while new.ready_count():
        a, b = ref.pick(0), new.pick(0)
        assert a is b


def test_incremental_eevdf_heaps_stay_bounded_under_churn():
    """Steady-state churn with a pool that never drains: lazy-invalidated
    heap entries must be compacted away, not accumulate per admission —
    and picks must still match the brute-force reference throughout."""
    jobs = [Job(f"jb{i}", nice=5 * (i % 2)) for i in range(2)]
    tasks = [Task(jobs[i % 2]) for i in range(256)]
    ref, new = RefFair(slice_s=0.002), SchedFair(slice_s=0.002)
    n_slots = 16
    for i, t in enumerate(tasks):
        t.last_slot = None if i % 7 == 0 else i % n_slots
        ref.on_ready(t)
        new.on_ready(t)
    now = 0.0
    for i in range(5000):
        slot = i % n_slots
        a, b = ref.pick(slot), new.pick(slot)
        assert a is b
        ref.on_run(a, slot, now)
        new.on_run(a, slot, now)
        now += 5e-4
        a.last_slot = slot
        ref.on_stop(a, slot, now, 5e-4, StopReason.BLOCK)
        new.on_stop(a, slot, now, 5e-4, StopReason.BLOCK)
        ref.on_ready(a)
        new.on_ready(a)
    # pool held at 256 the whole time; without compaction _dl_all would
    # hold ~5256 entries here
    assert new.ready_count() == 256
    assert len(new._dl_all) <= 4 * 256 + 1
    assert len(new._vr_heap) <= 4 * 256 + 1


def test_events_processed_not_double_counted_at_max_time():
    """A pending event beyond max_time with no unfinished tasks (e.g. a
    delayed spawn) ends the run without an exception; the drained-event
    counter must be added exactly once."""

    def run_with_late_spawn(late):
        sim = SimExecutor(Topology(2, 1), SchedCoop(), max_time=1.0)
        job = Job("late")

        def body():
            yield st.compute(0.01)

        done = sim.spawn(job, body)
        if late:
            sim.spawn(job, body, at=5.0)  # never submitted: beyond max_time
        sim.run()
        assert done.done
        return sim.events_processed

    base = run_with_late_spawn(False)
    assert base > 0
    assert run_with_late_spawn(True) == base


@pytest.mark.parametrize("seed", range(8))
def test_incremental_eevdf_same_sim_stats(seed):
    """End-to-end: identical SchedStats under RefFair and SchedFair."""
    rng = random.Random(1000 + seed)
    n_slots = rng.randint(1, 4)
    programs = [
        [(rng.choice(["compute", "sleep", "yield"]), rng.uniform(5e-4, 2e-2))
         for _ in range(rng.randint(1, 6))]
        for _ in range(rng.randint(2, 12))
    ]

    def run_with(policy):
        sim = SimExecutor(Topology(n_slots, 1), policy, max_time=600.0)
        jobs = [Job(f"j{i}", nice=5 * (i % 2)) for i in range(2)]

        def body(prog):
            def gen():
                for kind, v in prog:
                    if kind == "compute":
                        yield st.compute(v)
                    elif kind == "sleep":
                        yield st.sleep(v)
                    else:
                        yield st.yield_()
            return gen

        for i, prog in enumerate(programs):
            sim.spawn(jobs[i % 2], body(prog))
        s = sim.run()
        return (s.makespan, s.dispatches, s.migrations, s.preemptions,
                s.total_run_time, s.total_wait_time, s.tasks_completed)

    assert run_with(RefFair(slice_s=0.003)) == run_with(SchedFair(slice_s=0.003))


# --------------------------------------------------------------------------- #
# SCHED_COOP placement order (§4.1) through the cached neighbor tuples
# --------------------------------------------------------------------------- #
def _coop_with_topology(n_slots=8, n_domains=2):
    topo = Topology(n_slots, n_domains)
    pol = SchedCoop(quantum=1.0)  # large quantum: no rotation interference
    pol.attach(SimpleNamespace(topology=topo))
    return pol, topo


def _ready_task(pol, job, last_slot, yielded=False):
    t = Task(job)
    t.last_slot = last_slot
    t._yielded = yielded
    pol.on_ready(t)
    return t


def test_coop_dispatch_order_affinity_unaffine_domain_anywhere():
    pol, topo = _coop_with_topology(8, 2)  # domains {0..3} and {4..7}
    job = Job("order")
    remote = _ready_task(pol, job, last_slot=6)   # cross-domain for slot 1
    domain = _ready_task(pol, job, last_slot=3)   # same domain as slot 1
    fresh = _ready_task(pol, job, last_slot=None)  # unaffine (new work)
    affine = _ready_task(pol, job, last_slot=1)   # exact affinity
    assert pol.pick(1) is affine
    assert pol.pick(1) is fresh
    assert pol.pick(1) is domain
    assert pol.pick(1) is remote
    assert pol.pick(1) is None


def test_coop_yielded_task_goes_behind_new_work():
    pol, _ = _coop_with_topology(4, 1)
    job = Job("yield-order")
    spun = _ready_task(pol, job, last_slot=2, yielded=True)  # nosv_yield
    fresh = _ready_task(pol, job, last_slot=None)
    # both land in the unaffine FIFO; the yielder arrived first
    assert pol.pick(0) is spun
    assert pol.pick(0) is fresh


@pytest.mark.parametrize("n_slots,n_domains", [(4, 1), (8, 2), (12, 3)])
def test_neighbor_tuples_are_distance_ordered(n_slots, n_domains):
    topo = Topology(n_slots, n_domains)
    for sid in range(n_slots):
        order = topo.neighbors_first(sid)
        assert isinstance(order, tuple)
        assert order is topo.neighbors_first(sid)  # cached, not rebuilt
        sids = [s.sid for s in order]
        assert sorted(sids) == list(range(n_slots))  # a permutation
        dists = [topo.distance(sid, s.sid) for s in order]
        assert dists == sorted(dists)  # §4.1: nearest first
        assert dists[0] == 0 and order[0].sid == sid


# --------------------------------------------------------------------------- #
# framework invariants I1–I4 on random workloads (hypothesis-free port of
# tests/test_scheduler_props.py)
# --------------------------------------------------------------------------- #
def _policy_for(name):
    return {
        "coop": lambda: SchedCoop(quantum=0.01),
        "fair": lambda: SchedFair(slice_s=0.002),
        "rr": lambda: SchedRR(quantum=0.002),
    }[name]()


@pytest.mark.parametrize("polname", ["coop", "fair", "rr"])
@pytest.mark.parametrize("seed", range(6))
def test_invariants_random_workloads(polname, seed):
    rng = random.Random(2000 + seed)
    n_slots = rng.randint(1, 4)
    n_jobs = rng.randint(1, 3)
    programs = [
        [(rng.choice(["compute", "crit", "sleep", "yield"]),
          rng.uniform(5e-4, 1e-2))
         for _ in range(rng.randint(1, 5))]
        for _ in range(rng.randint(1, 10))
    ]
    policy = _policy_for(polname)
    sim = SimExecutor(Topology(n_slots, 1), policy, max_time=600.0)
    jobs = [Job(f"j{i}") for i in range(n_jobs)]
    mutex = st.SimMutex()
    cs = {"cur": 0, "max": 0}
    requested = 0.0

    def body(prog):
        def gen():
            for kind, v in prog:
                if kind == "compute":
                    yield st.compute(v)
                elif kind == "crit":
                    yield st.lock(mutex)
                    cs["cur"] += 1
                    cs["max"] = max(cs["max"], cs["cur"])
                    yield st.compute(v)
                    cs["cur"] -= 1
                    yield st.unlock(mutex)
                elif kind == "sleep":
                    yield st.sleep(v)
                else:
                    yield st.yield_()
        return gen

    tasks = []
    for i, prog in enumerate(programs):
        requested += sum(v for k, v in prog if k in ("compute", "crit"))
        tasks.append(sim.spawn(jobs[i % n_jobs], body(prog)))
    stats = sim.run()

    assert all(t.done for t in tasks)  # P1 completion
    if polname == "coop":
        assert stats.preemptions == 0  # I2
    overhead = stats.dispatches * (
        sim.costs.ctx_switch + sim.costs.dispatch_latency
        + sim.costs.migration_cross
    )
    assert stats.total_run_time >= requested - 1e-9  # P3
    assert stats.total_run_time <= requested + overhead + 1e-9
    assert cs["max"] <= 1  # P4 mutual exclusion
    assert stats.slot_busy_fraction <= 1.0 + 1e-6  # I1 in accounting


@pytest.mark.parametrize("seed", range(4))
def test_simulation_deterministic(seed):
    """P5: two identical runs produce identical stats (the event engine's
    tuple fast path must not depend on iteration order side effects)."""
    rng = random.Random(3000 + seed)
    n_slots = rng.randint(1, 4)
    programs = [
        [(rng.choice(["compute", "sleep", "yield"]), rng.uniform(5e-4, 1e-2))
         for _ in range(rng.randint(1, 5))]
        for _ in range(rng.randint(1, 10))
    ]

    def run_once():
        sim = SimExecutor(Topology(n_slots, 1), SchedCoop(), max_time=600.0)
        jobs = [Job(f"j{i}") for i in range(2)]

        def body(prog):
            def gen():
                for kind, v in prog:
                    if kind == "compute":
                        yield st.compute(v)
                    elif kind == "sleep":
                        yield st.sleep(v)
                    else:
                        yield st.yield_()
            return gen

        for i, prog in enumerate(programs):
            sim.spawn(jobs[i % 2], body(prog))
        s = sim.run()
        return (s.makespan, s.dispatches, s.migrations, s.tasks_completed,
                sim.events_processed)

    assert run_once() == run_once()


def test_sched_ops_bench_smoke(tmp_path):
    """The perf-tracking microbench runs end-to-end and writes its JSON."""
    from benchmarks.sched_ops import main

    out = tmp_path / "bench.json"
    assert main(["--smoke", "--out", str(out)]) == 0
    import json

    payload = json.loads(out.read_text())
    r = payload["results"]
    assert r["policy.fair.pick_cycle"]["ops_per_sec"] > 0
    assert r["sim.yield_churn"]["events_per_sec"] > 0
