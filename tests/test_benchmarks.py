"""Validation of the paper's claims on (reduced) benchmark cells.

Each test pins one claim from the paper's evaluation to a concrete
assertion over the simulated node. Cells are scaled down (smaller matrix /
fewer steps) to keep the suite fast; the full sweeps live in benchmarks/.
"""

import pytest

from benchmarks.common import STACKS


@pytest.mark.slow
def test_fig3_stack_ordering_oversubscribed():
    """§5.3: in the oversubscribed mid-band, original < baseline <=
    sched_coop <= manual (hypotheses 1 and 2)."""
    from benchmarks.matmul_heatmap import run_cell

    res = {s: run_cell(STACKS[s], 28, 1024)["gflops"]
           for s in ("original", "baseline", "sched_coop", "manual")}
    assert res["original"] <= res["baseline"]
    assert res["baseline"] < res["sched_coop"] * 1.02  # coop >= baseline-2%
    assert res["sched_coop"] <= res["manual"] * 1.05   # manual is the bound


@pytest.mark.slow
def test_table2_speedup_grows_with_oversubscription():
    """§5.4: SCHED_COOP speedup grows from mild to high oversubscription."""
    from benchmarks.cholesky_compositions import run_composition

    def speedup(degree):
        b = run_composition("gnu+llvm+opb", degree, "baseline")
        c = run_composition("gnu+llvm+opb", degree, "sched_coop")
        return c["mops"] / b["mops"]

    mild, high = speedup("mild"), speedup("high")
    assert high > mild
    assert high > 1.2


@pytest.mark.slow
def test_fig5_coop_highest_aggregate():
    """§5.6: SCHED_COOP co-execution beats Linux co-execution and
    exclusive execution in aggregate Katom-step/s."""
    from benchmarks.ensembles import run_scenario

    excl = run_scenario("exclusive")["katom_steps_per_s"]
    coex = run_scenario("coexecution_node")["katom_steps_per_s"]
    coop = run_scenario("schedcoop_node")["katom_steps_per_s"]
    assert coop > coex
    assert coop > excl


def test_sim_spin_waste_is_policy_dependent():
    """The mechanism behind every table: busy-wait waste under the
    preemptive baseline exceeds SCHED_COOP's (yield-adapted) waste."""
    from benchmarks.matmul_heatmap import run_cell

    base = run_cell(STACKS["baseline"], 14, 512, matrix=2048)
    coop = run_cell(STACKS["sched_coop"], 14, 512, matrix=2048)
    assert coop["preemptions"] == 0
    assert base["preemptions"] > 0
    assert coop["spin_frac"] <= base["spin_frac"] + 0.05
