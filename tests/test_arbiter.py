"""Two-level scheduler tests: SlotArbiter leases, attach/detach, I5.

Invariant I5 (grant rule): no job is granted a slot beyond its current
lease while a sibling policy group has ready tasks and spare lease. The
lockstep harness wraps ``arbiter.pick`` and checks the rule at every
grant across seeded-random mixed-policy workloads (the hypothesis-free
property-test pattern of tests/test_sched_fastpath.py).

Also covered: per-job policy mixing end-to-end (SCHED_COOP co-located
with SCHED_FAIR: I2 per job, share enforcement, determinism), elastic
lease resize, dynamic re-registration, and the satellite fixes (locked
stats, task-exception surfacing in join, CoopEvent timed wait).
"""

import random
import threading
import time

import pytest

from repro.core import simtask as st
from repro.core.arbiter import ArbiterError, SlotArbiter
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair, SchedRR
from repro.core.task import Job, TaskState
from repro.core.topology import Topology


def make_sim(n_slots=8, domains=2, **kw):
    return SimExecutor(Topology(n_slots, domains), SchedCoop(quantum=0.02),
                       max_time=kw.pop("max_time", 1e9), **kw)


def churn(compute=0.002, pause=0.0005, iters=None):
    def gen():
        i = 0
        while iters is None or i < iters:
            yield st.compute(compute)
            yield st.sleep(pause)
            i += 1

    return gen


def install_i5_checker(sim):
    """Wrap arbiter.pick: every borrowing grant (a group at/over quota)
    must find no sibling group with both ready work and spare lease.
    Install AFTER all attach()/detach() calls: a group change rebinds the
    arbiter's pick entry point, which would clobber the wrapper."""
    arb = sim.sched.arbiter
    violations = []
    orig_pick = arb.pick

    def checked_pick(slot_id):
        task = orig_pick(slot_id)
        if task is not None and arb.multi:
            lease = task.job.lease
            g = lease.group
            if g.in_use >= g.quota:  # borrowing grant (in_use not yet bumped)
                for h in arb.groups():
                    if h is g:
                        continue
                    if h.in_use < h.quota and h.policy.has_ready():
                        violations.append(
                            f"I5: {g!r} granted slot {slot_id} while "
                            f"{h!r} had ready work and spare lease"
                        )
        return task

    arb.pick = checked_pick
    return violations


# --------------------------------------------------------------------- #
# lease apportionment & lifecycle
# --------------------------------------------------------------------- #
def test_quota_apportionment_sums_to_slots():
    sim = make_sim(n_slots=8)
    leases = [
        sim.attach(Job(f"j{i}"), policy=SchedCoop(), share=s)
        for i, s in enumerate((5.0, 2.0, 1.0))
    ]
    assert sum(l.quota for l in leases) == 8
    assert [l.quota for l in leases] == [5, 2, 1]
    # re-apportioned when a job leaves
    sim.detach(leases[1].job)
    assert leases[0].quota + leases[2].quota == 8
    assert leases[0].quota > leases[2].quota


def test_attach_detach_lifecycle_and_reregistration():
    sim = make_sim(n_slots=4)
    job = Job("burst")
    lease = sim.attach(job, policy=SchedFair(slice_s=0.002), share=1.0)
    assert job.lease is lease and sim.sched.arbiter.multi
    tasks = [sim.spawn(job, churn(iters=5)) for _ in range(6)]

    # detach while work is in flight must be refused
    with pytest.raises(ArbiterError):
        sim.detach(job)
    sim.run()
    assert all(t.done for t in tasks)

    sim.detach(job)
    assert job.lease is None and not sim.sched.arbiter.multi
    with pytest.raises(ArbiterError):
        sim.detach(job)  # double detach

    # dynamic re-registration: a fresh submit transparently re-registers
    # the detached job through the default group
    t = sim.spawn(job, churn(iters=3))
    sim.run()
    assert t.done
    assert sim.sched.arbiter.policy_of(job) is sim.sched.policy


def test_detached_jobs_blocked_task_reregisters_across_mode_switch():
    """Regression: a detached job's BLOCKED task waking up while the
    arbiter is in single-group mode must re-register (get a lease), or a
    later switch to multi-group mode crashes on the leaseless task."""
    sim = make_sim(n_slots=1, domains=1)
    job_a, job_f = Job("sleeper"), Job("filler")

    def sleeper():
        yield st.sleep(0.01)
        yield st.compute(0.005)

    t_a = sim.spawn(job_a, sleeper)
    sim.spawn(job_f, churn(compute=0.001, pause=0.0001, iters=100))
    sim.run(until=0.005)
    assert t_a.state is TaskState.BLOCKED
    sim.detach(job_a)  # allowed: only BLOCKED work left
    assert job_a.lease is None
    sim.run(until=0.012)  # the sleep expires in single-group mode
    assert job_a.lease is not None  # dynamically re-registered
    job_b = Job("late")
    sim.attach(job_b, policy=SchedFair(slice_s=0.002), share=1.0)
    sim.spawn(job_b, churn(iters=5))
    sim.run()  # must not crash in the multi-group accounting
    assert t_a.done


def test_attach_rejects_shared_policy_instance_and_reattach_swaps():
    sim = make_sim()
    job_a, job_b = Job("a"), Job("b")
    pol = SchedCoop()
    sim.attach(job_a, policy=pol)
    with pytest.raises(ArbiterError):
        sim.attach(job_b, policy=pol)  # policy instance reuse
    with pytest.raises(ArbiterError):
        sim.attach(job_a, policy=pol)  # swap must pass a FRESH instance
    with pytest.raises(ArbiterError):
        sim.attach(job_a)  # policy=None on an attached job: use demote_job
    # re-attach with a fresh dedicated policy is a live policy swap now
    swap = SchedFair(slice_s=0.002)
    lease = sim.attach(job_a, policy=swap, share=2.0)
    assert job_a.lease is lease and lease.group.dedicated
    assert sim.sched.policy_of(job_a) is swap
    # demote re-homes it back into the shared default group
    lease2 = sim.demote(job_a)
    assert job_a.lease is lease2 and not lease2.group.dedicated
    assert sim.sched.policy_of(job_a) is sim.sched.arbiter.default_policy
    with pytest.raises(ArbiterError):
        sim.demote(job_a)  # already in the default group
    with pytest.raises(ArbiterError):
        sim.demote(Job("never-attached"))


def test_attach_with_busy_job_rehomes_live():
    """A job with READY/RUNNING tasks is migrated into the new group by
    attach (live re-homing) instead of being rejected; every task still
    completes exactly once."""
    sim = make_sim(n_slots=1, domains=1)
    job = Job("busy")
    tasks = [sim.spawn(job, churn(iters=20)) for _ in range(4)]
    lease = sim.attach(job, policy=SchedFair(slice_s=0.002), share=1.0)
    assert job.lease is lease and lease.group.dedicated
    assert sim.sched.policy_of(job).name == "SCHED_FAIR"
    sim.run()
    assert all(t.done for t in tasks)
    # detach still requires quiescence (there is no group to serve leftovers)
    sim.detach(job)
    assert job.lease is None


# --------------------------------------------------------------------- #
# I5 lockstep + seeded property sweep
# --------------------------------------------------------------------- #
def _random_mixed_run(seed: int) -> None:
    rng = random.Random(seed)
    n_slots = rng.choice((2, 4, 8))
    sim = SimExecutor(Topology(n_slots, 1), SchedCoop(quantum=0.01),
                      max_time=600.0)

    jobs = []
    for i in range(rng.randint(2, 3)):
        job = Job(f"p{seed}-{i}")
        pol = rng.choice((
            lambda: SchedCoop(quantum=0.01),
            lambda: SchedFair(slice_s=0.002),
            lambda: SchedRR(quantum=0.002),
        ))()
        sim.attach(job, policy=pol, share=rng.choice((1.0, 2.0, 5.0)))
        jobs.append(job)
    violations = install_i5_checker(sim)

    def body(prog):
        def gen():
            for kind, v in prog:
                if kind == "compute":
                    yield st.compute(v)
                elif kind == "sleep":
                    yield st.sleep(v)
                else:
                    yield st.yield_()

        return gen

    tasks = []
    for _ in range(rng.randint(4, 4 * n_slots)):
        prog = [
            (rng.choice(("compute", "sleep", "yield")), rng.uniform(5e-4, 8e-3))
            for _ in range(rng.randint(1, 6))
        ]
        tasks.append(sim.spawn(rng.choice(jobs), body(prog)))

    sim.run()
    assert all(t.done for t in tasks), f"seed {seed}: unfinished tasks"
    assert not violations, f"seed {seed}: {violations[:3]}"
    # I2 held per job: cooperative jobs saw zero preemptions
    for job in jobs:
        if not sim.sched.policy_of(job).preemptive:
            assert sum(t.stats.preemptions for t in job.tasks) == 0


@pytest.mark.parametrize("seed", range(12))
def test_i5_lockstep_random_mixed_workloads(seed):
    _random_mixed_run(seed)


def test_i5_holds_under_elastic_resize():
    sim = make_sim(n_slots=8, domains=1)
    job_a, job_b = Job("a"), Job("b")
    lease_a = sim.attach(job_a, policy=SchedCoop(quantum=0.01), share=1.0)
    sim.attach(job_b, policy=SchedFair(slice_s=0.002), share=1.0)
    violations = install_i5_checker(sim)
    for _ in range(12):
        sim.spawn(job_a, churn())
        sim.spawn(job_b, churn())
    sim.run(until=0.2)
    for share in (6.0, 0.5, 3.0):
        lease_a.resize(share)
        sim.run(until=sim.now() + 0.2)
    assert not violations, violations[:3]


# --------------------------------------------------------------------- #
# policy mixing end-to-end
# --------------------------------------------------------------------- #
def test_policy_mixing_share_enforcement_and_i2():
    """Saturated SCHED_COOP + SCHED_FAIR co-location at a 3:1 share split:
    realized service tracks the lease, coop never preempted, fair is."""
    sim = make_sim(n_slots=8, domains=2)
    job_a, job_b = Job("coop", share=3.0), Job("fair", share=1.0)
    lease_a = sim.attach(job_a, policy=SchedCoop(quantum=0.02))
    lease_b = sim.attach(job_b, policy=SchedFair(slice_s=0.003))
    assert (lease_a.quota, lease_b.quota) == (6, 2)
    for _ in range(16):
        sim.spawn(job_a, churn())
        sim.spawn(job_b, churn())
    sim.run(until=1.0)

    total = job_a.service_time + job_b.service_time
    frac_a = job_a.service_time / total
    assert 0.70 <= frac_a <= 0.80, f"share not enforced: frac_a={frac_a:.3f}"
    assert sum(t.stats.preemptions for t in job_a.tasks) == 0  # I2 per job
    assert sum(t.stats.preemptions for t in job_b.tasks) > 0
    snap = sim.sched.snapshot()
    assert snap["policy"] == "arbiter[SCHED_COOP+SCHED_FAIR]"
    assert snap["leases"]["coop"]["quota"] == 6


def test_work_conserving_borrowing_when_sibling_idle():
    """A job with a tiny lease expands to the whole node while the sibling
    has nothing ready (no static-partition waste)."""
    sim = make_sim(n_slots=8, domains=1)
    job_a, job_b = Job("small"), Job("idle")
    lease_a = sim.attach(job_a, policy=SchedCoop(quantum=0.02), share=1.0)
    sim.attach(job_b, policy=SchedFair(slice_s=0.003), share=7.0)
    assert lease_a.quota == 1
    for _ in range(16):
        sim.spawn(job_a, churn())
    sim.run(until=0.5)
    # ~all of the node's 0.5s * 8 slots went to the small-lease job
    assert job_a.service_time > 0.9 * 0.5 * 8


def test_mixed_workload_deterministic():
    def run_once():
        sim = make_sim(n_slots=4, domains=1)
        job_a, job_b = Job("a"), Job("b")
        sim.attach(job_a, policy=SchedCoop(quantum=0.01), share=2.0)
        sim.attach(job_b, policy=SchedFair(slice_s=0.002), share=1.0)
        tasks = [sim.spawn(job_a, churn(iters=20)) for _ in range(6)]
        tasks += [sim.spawn(job_b, churn(iters=20)) for _ in range(6)]
        s = sim.run()
        return (s.makespan, s.dispatches, s.preemptions,
                round(job_a.service_time, 9), round(job_b.service_time, 9))

    assert run_once() == run_once()


def test_lease_revocation_tick_reclaims_borrowed_slots():
    """A preemptive job borrowing beyond its lease is preempted at the next
    tick once the under-lease sibling has ready work again."""
    sim = make_sim(n_slots=4, domains=1)
    job_a, job_b = Job("coop"), Job("fair")
    sim.attach(job_a, policy=SchedCoop(quantum=0.01), share=2.0)
    sim.attach(job_b, policy=SchedFair(slice_s=0.002), share=2.0)
    # B starts alone and borrows the whole node with long computes
    for _ in range(8):
        sim.spawn(job_b, churn(compute=0.05, pause=0.0001))
    # A arrives later: its lease must be honoured without waiting for B's
    # 50ms computes to end voluntarily (the revocation scheduling point)
    for _ in range(8):
        sim.spawn(job_a, churn(compute=0.002, pause=0.0001), at=0.01)
    sim.run(until=0.5)
    assert job_a.service_time > 0.15  # got its half in reasonable time
    assert sum(t.stats.preemptions for t in job_b.tasks) > 0


# --------------------------------------------------------------------- #
# satellites: locked introspection, exception surfacing, timed waits
# --------------------------------------------------------------------- #
def test_stats_and_running_tasks_locked_under_thread_executor():
    """Concurrent stats()/running_tasks()/snapshot() while real threads
    churn through the scheduler must not race (satellite: they now take
    the scheduler lock like snapshot always did)."""
    from repro.core.threads import UsfRuntime

    rt = UsfRuntime(Topology(2, 1), SchedCoop())
    try:
        job = Job("j")
        stop = threading.Event()
        errors = []

        def reader():
            while not stop.is_set():
                try:
                    rt.sched.stats()
                    rt.sched.running_tasks()
                    rt.sched.snapshot()
                except Exception as e:  # pragma: no cover - the regression
                    errors.append(e)
                    return

        readers = [threading.Thread(target=reader) for _ in range(2)]
        for r in readers:
            r.start()
        tasks = [rt.create(lambda: time.sleep(0.002), job=job)
                 for _ in range(24)]
        for t in tasks:
            assert rt.join(t, timeout=10.0)
        stop.set()
        for r in readers:
            r.join(5.0)
        assert not errors
    finally:
        rt.shutdown(timeout=5.0)


def test_join_reraises_task_exception():
    from repro.core.threads import UsfRuntime, UsfTaskError

    rt = UsfRuntime(Topology(2, 1), SchedCoop())
    try:
        job = Job("j")

        def boom():
            raise ValueError("worker died")

        t = rt.create(boom, job=job)
        with pytest.raises(UsfTaskError, match="worker died"):
            rt.join(t, timeout=10.0)
        # joining again keeps raising (no silent success on retry)
        with pytest.raises(UsfTaskError):
            rt.join(t, timeout=10.0)
    finally:
        rt.shutdown(timeout=5.0)


def test_join_timeout_from_gated_task():
    from repro.core.sync import CoopEvent
    from repro.core.threads import UsfRuntime

    rt = UsfRuntime(Topology(2, 1), SchedCoop())
    try:
        job = Job("j")
        gate = CoopEvent(rt)
        hung = rt.create(gate.wait, job=job)
        results = {}

        def joiner():
            results["timed_out"] = rt.join(hung, timeout=0.1)

        j = rt.create(joiner, job=job)
        assert rt.join(j, timeout=10.0)
        assert results["timed_out"] is False
        gate.set()
        assert rt.join(hung, timeout=10.0)
    finally:
        rt.shutdown(timeout=5.0)


def test_coop_event_wait_timeout_both_waiter_kinds():
    from repro.core.sync import CoopEvent
    from repro.core.threads import UsfRuntime

    rt = UsfRuntime(Topology(2, 1), SchedCoop())
    try:
        ev = CoopEvent(rt)
        # plain-thread waiter
        t0 = time.monotonic()
        assert ev.wait(timeout=0.05) is False
        assert time.monotonic() - t0 < 5.0
        # gated-task waiter
        job = Job("j")
        results = {}

        def waiter():
            results["first"] = ev.wait(timeout=0.05)
            results["second"] = ev.wait(timeout=30.0)

        t = rt.create(waiter, job=job)
        time.sleep(0.3)  # let the timed wait expire
        ev.set()
        assert rt.join(t, timeout=10.0)
        assert results["first"] is False
        assert results["second"] is True
        assert ev.wait(timeout=0.0) is True  # already set: immediate
    finally:
        rt.shutdown(timeout=5.0)
