"""Behavioural tests for the USF discrete-event executor + policies.

These encode the paper's scheduling semantics:
  * SCHED_COOP never preempts; swaps happen at blocking points only.
  * Unblocked tasks are queued, not resumed immediately.
  * Busy-wait barriers livelock cooperative policies when waiters > slots
    (§4.4) unless the yield adaptation is applied (§5.2); preemptive
    policies mask the deadlock into a performance problem.
  * LHP: preemption of a lock holder stalls the FIFO queue — SCHED_COOP
    avoids it.
"""

import pytest

from repro.core import simtask as st
from repro.core.events import SimDeadlock, SimExecutor, SimLivelock
from repro.core.policies import SchedCoop, SchedFair, SchedRR
from repro.core.task import Job
from repro.core.topology import Topology


def make_sim(n_slots=4, policy=None, domains=1, **kw):
    topo = Topology(n_slots, domains)
    return SimExecutor(topo, policy or SchedCoop(), **kw)


def test_compute_tasks_all_complete_and_makespan():
    sim = make_sim(n_slots=2)
    job = Job("j")

    def body():
        yield st.compute(1.0)

    tasks = [sim.spawn(job, body, name=f"t{i}") for i in range(6)]
    stats = sim.run()
    assert all(t.done for t in tasks)
    assert stats.tasks_completed == 6
    # 6 x 1s tasks on 2 slots ~ 3s (+ small switch costs)
    assert 3.0 <= stats.makespan < 3.1
    assert stats.preemptions == 0  # I2: SCHED_COOP never preempts


def test_oversubscription_gated_to_slots():
    """More ready tasks than slots: at most n_slots run concurrently."""
    sim = make_sim(n_slots=3)
    job = Job("j")
    running = {"cur": 0, "max": 0}

    def body():
        running["cur"] += 1
        running["max"] = max(running["max"], running["cur"])
        yield st.compute(0.5)
        running["cur"] -= 1

    for i in range(12):
        sim.spawn(job, body)
    sim.run()
    assert running["max"] <= 3


def test_mutex_fifo_handoff_order():
    """Listing 1: unlock hands the mutex to waiters in FIFO order."""
    sim = make_sim(n_slots=8)
    job = Job("j")
    m = st.SimMutex()
    order = []

    def body(i):
        def gen():
            yield st.compute(0.001 * (i + 1))  # stagger arrivals
            yield st.lock(m)
            order.append(i)
            yield st.compute(0.01)
            yield st.unlock(m)

        return gen

    for i in range(6):
        sim.spawn(job, body(i))
    sim.run()
    assert order == sorted(order)


def test_unblocked_tasks_are_queued_not_resumed():
    """I3: an unblock with no idle slot leaves the task READY (queued)."""
    sim = make_sim(n_slots=1)
    job = Job("j")
    m = st.SimMutex()
    trace = []

    def holder():
        yield st.lock(m)
        yield st.compute(0.1)
        yield st.unlock(m)
        trace.append("holder-released")
        yield st.compute(0.5)  # keeps the only slot busy after unlock
        trace.append("holder-done")

    def waiter():
        yield st.compute(0.001)
        yield st.lock(m)
        trace.append("waiter-got-lock")
        yield st.unlock(m)

    sim.spawn(job, holder)
    sim.spawn(job, waiter)
    sim.run()
    # waiter got the mutex by transfer but only *ran* after holder's slot
    # freed up: holder-done precedes waiter-got-lock in wall order? No —
    # waiter runs when holder *finishes* (cooperative, 1 slot).
    assert trace == ["holder-released", "holder-done", "waiter-got-lock"]


def test_cooperative_barrier():
    sim = make_sim(n_slots=4)
    job = Job("j")
    b = st.SimBarrier(4)
    done_at = {}

    def body(i):
        def gen():
            yield st.compute(0.1 * (i + 1))  # imbalanced phases
            yield st.barrier_wait(b)
            done_at[i] = sim.now()

        return gen

    for i in range(4):
        sim.spawn(job, body(i))
    sim.run()
    assert len(done_at) == 4
    times = list(done_at.values())
    assert max(times) - min(times) < 0.02  # all released together


def test_spin_barrier_livelock_without_yield():
    """§4.4: waiters exceed slots + pure busy-wait + cooperative policy
    = livelock. The engine must detect it, not spin forever."""
    sim = make_sim(n_slots=2, max_time=5.0)
    job = Job("j")
    b = st.SimSpinBarrier(3, yield_every=None)  # unmodified library

    def body():
        yield st.compute(0.01)
        yield st.spin_barrier_wait(b)

    for _ in range(3):
        sim.spawn(job, body)
    with pytest.raises(SimLivelock):
        sim.run()


def test_spin_barrier_yield_adaptation_fixes_livelock():
    """§5.2: one-line yield adaptation makes the same case complete."""
    sim = make_sim(n_slots=2, max_time=5.0)
    job = Job("j")
    b = st.SimSpinBarrier(3, yield_every=4)

    def body():
        yield st.compute(0.01)
        yield st.spin_barrier_wait(b)

    tasks = [sim.spawn(job, body) for _ in range(3)]
    sim.run()
    assert all(t.done for t in tasks)


def test_preemptive_policy_masks_spin_deadlock_into_slowdown():
    """§4.4: preemptive schedulers guarantee progress without scheduling
    points — the same no-yield case completes under SCHED_FAIR."""
    sim = make_sim(n_slots=2, policy=SchedFair(slice_s=0.005), max_time=30.0)
    job = Job("j")
    b = st.SimSpinBarrier(3, yield_every=None)

    def body():
        yield st.compute(0.01)
        yield st.spin_barrier_wait(b)

    tasks = [sim.spawn(job, body) for _ in range(3)]
    stats = sim.run()
    assert all(t.done for t in tasks)
    assert stats.preemptions > 0
    assert stats.total_spin_time > 0.004  # progress was bought with spin waste


def test_lock_holder_preemption_hurts_fair_not_coop():
    """LHP (§1, §6): a lock-hot job co-located with a compute-hog job on an
    oversubscribed node. Under the preemptive baseline the lock holder gets
    preempted mid-critical-section by hog threads, stalling the whole FIFO
    queue; SCHED_COOP lets critical sections run to completion."""

    def workload(sim):
        lock_job = Job("locky")
        hog_job = Job("hog")
        m = st.SimMutex()
        lock_tasks = []

        def lock_body():
            def gen():
                for _ in range(10):
                    yield st.lock(m)
                    yield st.compute(0.004)  # critical section > fair slice
                    yield st.unlock(m)
                    yield st.compute(0.001)

            return gen

        def hog_body():
            def gen():
                yield st.compute(0.5)

            return gen

        for _ in range(4):
            lock_tasks.append(sim.spawn(lock_job, lock_body()))
        for _ in range(4):
            sim.spawn(hog_job, hog_body())
        return lock_tasks

    sim_coop = make_sim(n_slots=2, policy=SchedCoop())
    workload(sim_coop)
    coop = sim_coop.run()

    sim_fair = make_sim(n_slots=2, policy=SchedFair(slice_s=0.003), max_time=120.0)
    workload(sim_fair)
    fair = sim_fair.run()

    assert coop.preemptions == 0
    assert sim_coop.lhp_preemptions == 0  # by construction: no preemption
    assert fair.preemptions > 0
    assert sim_fair.lhp_preemptions > 0   # the baseline preempts lock holders
    # and pays for it in scheduling overhead
    assert fair.context_switch_time > coop.context_switch_time


def test_quantum_rotates_between_jobs():
    """§4.1: the per-job quantum (evaluated at scheduling points) rotates
    service between jobs instead of starving the second job."""
    sim = make_sim(n_slots=1, policy=SchedCoop(quantum=0.02))
    j1, j2 = Job("a"), Job("b")
    first_service = {}

    def body(jname, i):
        def gen():
            if jname not in first_service:
                first_service[jname] = sim.now()
            yield st.compute(0.01)

        return gen

    # interleave many short tasks of two jobs
    for i in range(20):
        sim.spawn(j1, body("a", i))
        sim.spawn(j2, body("b", i))
    sim.run()
    # job b must get service well before job a fully drains (20 x 10ms)
    assert first_service["b"] < 0.08


def test_affinity_preferred_slot():
    """§4.1: a task that blocks and unblocks is placed back on its last
    slot when that slot is free."""
    sim = make_sim(n_slots=4, domains=2)
    job = Job("j")
    slots_seen = []

    def body():
        slots_seen.append(("phase1", _cur_slot()))
        yield st.compute(0.01)
        yield st.sleep(0.05)  # blocks; slot may serve others meanwhile
        slots_seen.append(("phase2", _cur_slot()))
        yield st.compute(0.01)

    task = sim.spawn(job, body)

    def _cur_slot():
        return task.slot

    sim.run()
    assert slots_seen[0][1] == slots_seen[1][1]  # resumed on the same slot
    assert task.stats.migrations == 0


def test_channel_producer_consumer():
    sim = make_sim(n_slots=2)
    job = Job("j")
    ch = st.SimChannel()
    got = []

    def producer():
        for i in range(5):
            yield st.compute(0.01)
            yield st.channel_put(ch, i)

    def consumer():
        for _ in range(5):
            item = yield st.channel_get(ch)
            got.append(item)
            yield st.compute(0.005)

    sim.spawn(job, producer)
    sim.spawn(job, consumer)
    sim.run()
    assert got == [0, 1, 2, 3, 4]


def test_spawn_join():
    sim = make_sim(n_slots=2)
    job = Job("j")
    from repro.core.task import Task

    events = []

    def child_body():
        yield st.compute(0.05)
        events.append("child-done")

    def parent():
        child = Task(job, body=child_body, name="child")
        yield st.spawn(child)
        yield st.compute(0.01)
        yield st.join(child)
        events.append("parent-after-join")

    sim.spawn(job, parent)
    sim.run()
    assert events == ["child-done", "parent-after-join"]


def test_condvar():
    sim = make_sim(n_slots=2)
    job = Job("j")
    m = st.SimMutex()
    cv = st.SimCondVar()
    state = {"ready": False}
    log = []

    def waiter():
        yield st.lock(m)
        while not state["ready"]:
            yield st.cv_wait(cv, m)
        log.append("consumed")
        yield st.unlock(m)

    def notifier():
        yield st.compute(0.05)
        yield st.lock(m)
        state["ready"] = True
        yield st.cv_notify(cv, 1)
        yield st.unlock(m)

    sim.spawn(job, waiter)
    sim.spawn(job, notifier)
    sim.run()
    assert log == ["consumed"]


def test_deadlock_detection():
    """A mutex never released: the engine reports a cooperative deadlock."""
    sim = make_sim(n_slots=2)
    job = Job("j")
    m = st.SimMutex()

    def holder():
        yield st.lock(m)
        yield st.compute(0.01)
        # never unlocks

    def waiter():
        yield st.compute(0.005)
        yield st.lock(m)

    sim.spawn(job, holder)
    sim.spawn(job, waiter)
    with pytest.raises(SimDeadlock):
        sim.run()


def test_rr_policy_preempts_and_completes():
    sim = make_sim(n_slots=2, policy=SchedRR(quantum=0.005))
    job = Job("j")

    def body():
        yield st.compute(0.05)

    tasks = [sim.spawn(job, body) for _ in range(6)]
    stats = sim.run()
    assert all(t.done for t in tasks)
    assert stats.preemptions > 0


def test_migration_penalty_charged_cross_domain():
    """Tasks forced to migrate across domains accrue warm-up penalty."""
    from repro.core.simtask import SimCosts

    costs = SimCosts(migration_cross=0.05)
    sim = SimExecutor(Topology(2, 2), SchedCoop(), costs=costs)
    job = Job("j")

    def pinner():
        # occupy slot 0 forever-ish
        yield st.compute(1.0)

    def mover():
        yield st.compute(0.01)   # runs on slot 1 (slot 0 busy)
        yield st.sleep(0.001)
        yield st.compute(0.01)

    sim.spawn(job, pinner)
    t = sim.spawn(job, mover)
    sim.run()
    assert t.done
