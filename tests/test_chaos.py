"""Chaos suite: seeded random fault schedules against the broker layer.

Drives real ``NodeBroker`` + ``BrokerClient`` stacks with a deterministic
``FaultPlan`` per client (drops, delays, truncated frames, duplicated and
reordered grants, resets, heartbeat stalls) plus driver-injected lease
churn and broker kills, then clears the faults and asserts the
self-healing invariants:

* **no hang** — every wait in the suite is bounded;
* **liveness floor** — no applied runtime width ever drops below 1 slot;
* **bounded authority** — within one live broker incarnation, granted
  slots never exceed node capacity;
* **bounded convergence** — once faults clear, every client re-reaches
  ``COORDINATED`` and grants match the broker's lease table exactly.

The unmarked smoke (a few seeds, short windows) rides tier-1 and
``make check``; the full sweep (more seeds + broker-restart schedules) is
``slow`` and runs nightly.
"""

import os
import random
import socket
import tempfile
import threading
import time

import pytest

from repro.ipc import BrokerClient, FaultPlan, NodeBroker
from repro.ipc.broker import DemandState
from repro.ipc.protocol import recv_msg, send_msg

CAPACITY = 4
N_CLIENTS = 3


def _path() -> str:
    return os.path.join(tempfile.mkdtemp(prefix="usf-chaos-"), "broker.sock")


def _wait_until(cond, timeout, step=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(step)
    return cond()


class _Width:
    """Fake runtime: records every applied slot-target, thread-safely."""

    class _Topo:
        n_slots = CAPACITY

    def __init__(self):
        self.topology = self._Topo()
        self._lock = threading.Lock()
        self.widths = []

    def set_slot_target(self, n):
        with self._lock:
            self.widths.append(n)

    def applied(self):
        with self._lock:
            return list(self.widths)


def _chaos_plan(seed: int) -> FaultPlan:
    """A moderate everything-at-once schedule: every fault class armed."""
    return FaultPlan(
        seed,
        drop_send=0.05, truncate_send=0.03, reset_send=0.02,
        delay_send=0.05,
        drop_recv=0.10, dup_recv=0.10, reorder_recv=0.10,
        reset_recv=0.05, delay_recv=0.10, delay_range=(0.001, 0.01),
        heartbeat_stall=0.05, stall_beats=(2, 4),
    )


def _run_chaos(seed: int, *, duration: float = 1.2,
               restart_broker: bool = False) -> None:
    path = _path()
    broker = NodeBroker(path, capacity=CAPACITY, heartbeat_timeout=0.5)
    broker.start()
    rng = random.Random(seed)
    fakes = [_Width() for _ in range(N_CLIENTS)]
    plans = [_chaos_plan(seed * 1000 + i) for i in range(N_CLIENTS)]
    # live demand rides the chaos too: every heartbeat carries a backlog
    # the driver churns during the fault window; saturated afterwards so
    # the convergence invariants (grants sum to capacity) stay exact
    backlogs = [{"v": CAPACITY} for _ in range(N_CLIENTS)]
    clients = []
    try:
        for i in range(N_CLIENTS):
            clients.append(BrokerClient(
                path, name=f"c{i}", share=1.0 + i, slots=CAPACITY,
                heartbeat_interval=0.05,
                backlog_probe=(lambda cell=backlogs[i]: cell["v"]),
                reconnect_backoff=(0.02, 0.2),
                faults=plans[i]).bind(fakes[i]).start(connect_timeout=15.0))

        # fault window: protocol faults fire per message; the driver adds
        # lease churn (resizes + backlog swings) and, in the sweep, a
        # broker kill+restart
        deadline = time.monotonic() + duration
        restart_at = (time.monotonic() + duration / 3
                      if restart_broker else None)
        while time.monotonic() < deadline:
            if restart_at is not None and time.monotonic() >= restart_at:
                restart_at = None
                broker.stop()
                time.sleep(0.2)  # every client sees the outage
                broker = NodeBroker(path, capacity=CAPACITY,
                                    heartbeat_timeout=0.5)
                broker.start()
            c = rng.choice(clients)
            try:
                c.resize(0.5 + 2.5 * rng.random())
            except OSError:
                pass  # BrokerLostError: typed, queued — by contract
            backlogs[rng.randrange(N_CLIENTS)]["v"] = \
                rng.randrange(0, CAPACITY + 1)
            time.sleep(0.01 + 0.03 * rng.random())

        # clear faults; the system must converge on its own, boundedly
        for p in plans:
            p.clear()
        for cell in backlogs:
            cell["v"] = CAPACITY  # everyone saturated: full wants again
        assert _wait_until(
            lambda: all(c.state == BrokerClient.COORDINATED
                        for c in clients), timeout=15.0), \
            f"stuck states: {[(c.name, c.state) for c in clients]}"
        assert _wait_until(
            lambda: sum(c.granted or 0 for c in clients) == CAPACITY,
            timeout=15.0), \
            f"grants: {[(c.name, c.granted) for c in clients]}"

        # grants agree with the broker's (rebuilt) lease table, under the
        # live incarnation only — a dead broker's authority never counts
        def _agree():
            snap = broker.snapshot()
            ws = snap["workers"]
            return (sorted(ws) == sorted(c.name for c in clients)
                    and all(ws[c.name]["granted"] == c.granted
                            for c in clients)
                    and all(c.incarnation == broker.incarnation
                            for c in clients))
        assert _wait_until(_agree, timeout=15.0), \
            (broker.snapshot(),
             [(c.name, c.granted, c.incarnation) for c in clients])

        # liveness floor: no applied width ever dipped below 1 slot
        for fake in fakes:
            for w in fake.applied():
                assert w is None or w >= 1
        if restart_broker:
            assert all(c.reconnects >= 1 for c in clients)
    finally:
        for c in clients:
            c.stop()
        broker.stop()


# --------------------------------------------------------------------- #
# smoke: rides tier-1 and `make check`
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_chaos_smoke_converges(seed):
    _run_chaos(seed, duration=1.2)


def test_fault_plan_is_deterministic():
    """Same seed -> the same decision sequence at every protocol step
    (the whole point: a chaos failure is replayable)."""
    msgs = [{"op": "grant", "slots": i % 4, "epoch": i} for i in range(64)]

    def trace(plan):
        out = []
        for m in msgs:
            out.append(plan.send_action(m))
            act, d, deliver = plan.recv_actions(m)
            out.append((act, d, [x.get("epoch") for x in deliver]))
            out.append(plan.stall_heartbeat())
        return out

    a, b = _chaos_plan(42), _chaos_plan(42)
    assert trace(a) == trace(b)
    assert a.injected == b.injected
    assert trace(_chaos_plan(43)) != trace(_chaos_plan(42))


def test_fault_plan_horizon_disarms_and_releases_held():
    plan = FaultPlan(seed=7, reorder_recv=1.0, horizon=1)
    act, _, deliver = plan.recv_actions({"op": "grant", "epoch": 1})
    assert deliver == []  # held
    assert not plan.armed  # horizon reached
    act, _, deliver = plan.recv_actions({"op": "grant", "epoch": 2})
    # disarmed recv releases the held message so nothing is lost forever
    assert [m["epoch"] for m in deliver] == [2, 1]


# --------------------------------------------------------------------- #
# backlog-hostile clients: the demand channel under abuse (PR 9)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("bad", ["wat", -1, 1.5, True, None])
def test_hostile_backlog_drops_sender_not_broker(bad):
    """A malformed backlog field (garbage type, negative, bool, float,
    null) is a protocol violation: it costs the SENDER its connection
    (lease reclaimed, slots flow to the sibling) and never the broker
    loop or a sibling's coordination."""
    path = _path()
    broker = NodeBroker(path, capacity=CAPACITY, heartbeat_timeout=5.0)
    broker.start()
    survivor = BrokerClient(path, name="survivor", share=1.0,
                            slots=CAPACITY, heartbeat_interval=0.05).start()
    try:
        assert survivor.wait_grant(5.0) == CAPACITY
        hostile = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        hostile.connect(path)
        send_msg(hostile, {"op": "register", "name": "hostile",
                           "share": 1.0, "slots": CAPACITY, "pid": 0})
        assert recv_msg(hostile)["op"] == "welcome"
        assert recv_msg(hostile)["op"] == "grant"
        assert _wait_until(lambda: survivor.granted == CAPACITY // 2, 5.0)

        send_msg(hostile, {"op": "heartbeat", "backlog": bad})
        # the offender is dropped and its lease reclaimed at once (no
        # waiting out the heartbeat timeout, which is 5s here on purpose)
        assert _wait_until(lambda: survivor.granted == CAPACITY, 3.0)
        assert list(broker.snapshot()["workers"]) == ["survivor"]
        hostile.close()
        # the broker loop survived: a late registration still lands
        late = BrokerClient(path, name="late", share=1.0, slots=CAPACITY,
                            heartbeat_interval=0.05).start()
        assert late.wait_grant(5.0) == CAPACITY // 2
        late.stop()
    finally:
        survivor.stop()
        broker.stop()


def test_absent_backlog_is_v1_not_hostile():
    """A heartbeat WITHOUT the backlog field is the v1 wire contract,
    not a violation: the sender stays registered at static demand."""
    path = _path()
    broker = NodeBroker(path, capacity=CAPACITY, heartbeat_timeout=5.0)
    broker.start()
    try:
        v1 = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        v1.connect(path)
        send_msg(v1, {"op": "register", "name": "v1", "share": 1.0,
                      "slots": CAPACITY, "pid": 0})
        assert recv_msg(v1)["op"] == "welcome"
        assert recv_msg(v1)["op"] == "grant"
        for _ in range(5):
            send_msg(v1, {"op": "heartbeat"})  # envelope v1: no backlog
            # the ack is an idempotent grant copy (the healing path)
            assert recv_msg(v1)["op"] == "grant"
        snap = broker.snapshot()
        assert list(snap["workers"]) == ["v1"]
        assert snap["workers"]["v1"]["eff_want"] == CAPACITY  # static
        assert snap["workers"]["v1"]["backlog"] is None
        v1.close()
    finally:
        broker.stop()


# --------------------------------------------------------------------- #
# hysteresis state machine: seeded determinism (PR 9)
# --------------------------------------------------------------------- #
def _demand_trace(seed: int, *, beats=3, alpha=0.5, min_interval=0.25):
    """Feed a seeded (backlog, dt) schedule through a DemandState and
    record every decision — the replayable trace."""
    rng = random.Random(seed)
    ds = DemandState(CAPACITY, beats=beats, alpha=alpha,
                     min_interval=min_interval)
    now, out = 0.0, []
    for _ in range(200):
        now += 0.01 + 0.09 * rng.random()
        out.append(ds.observe(rng.randrange(0, CAPACITY + 1), now))
    return out, ds.eff


def test_demand_state_is_deterministic():
    """Same seed -> the same regrant decision sequence (no wall clock,
    no hidden randomness inside the state machine: a demand-driven
    chaos failure is replayable)."""
    a, b = _demand_trace(42), _demand_trace(42)
    assert a == b
    assert any(d is not None for d in a[0])  # the schedule does move
    assert _demand_trace(43) != _demand_trace(42)


def test_demand_state_damps_flapping():
    """A 0/full backlog square wave faster than the hysteresis depth
    never moves the effective want: flap-damping by construction."""
    ds = DemandState(CAPACITY, beats=3, alpha=0.5, min_interval=0.0)
    now = 0.0
    for i in range(60):
        now += 0.05
        assert ds.observe(0 if i % 2 else CAPACITY, now) is None
    assert ds.eff == CAPACITY  # still the static registration width


def test_demand_state_min_interval_rate_limits():
    """Even a persistent one-sided shift regrants at most once per
    min_interval window."""
    ds = DemandState(CAPACITY, beats=1, alpha=1.0, min_interval=1.0)
    assert ds.observe(0, now=0.0) == 0        # first move is free
    moves = [ds.observe(CAPACITY, now=t / 10)
             for t in range(1, 10)]           # 0.1 .. 0.9: inside window
    assert moves == [None] * 9
    assert ds.observe(CAPACITY, now=1.5) is not None  # window elapsed


def test_demand_state_converges_monotone_shift():
    """A step change in backlog walks eff to the new level and stays
    there (EWMA + hysteresis converge, no overshoot ratchet)."""
    ds = DemandState(CAPACITY, beats=2, alpha=0.5, min_interval=0.0)
    now = 0.0
    for _ in range(20):
        now += 0.1
        ds.observe(0, now)
    assert ds.eff == 0
    for _ in range(20):
        now += 0.1
        ds.observe(CAPACITY, now)
    assert ds.eff == CAPACITY


# --------------------------------------------------------------------- #
# full sweep: nightly (more seeds, plus broker kill+restart schedules)
# --------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(10, 17)))
def test_chaos_sweep_converges(seed):
    _run_chaos(seed, duration=2.5)


@pytest.mark.slow
@pytest.mark.parametrize("seed", [20, 21, 22])
def test_chaos_sweep_with_broker_restart(seed):
    _run_chaos(seed, duration=2.5, restart_broker=True)
