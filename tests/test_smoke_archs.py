"""Per-architecture smoke tests (required deliverable f).

For every assigned architecture: instantiate a REDUCED config of the same
family, run one forward + one train step on CPU, assert output shapes and
no NaNs. Decode-capable archs also run one serve_step against a fresh
cache. The FULL configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke, list_archs
from repro.launch.inputs import make_batch, make_decode_inputs
from repro.models.base import init_tree
from repro.models.registry import build_model
from repro.runtime.sharding import Sharder
from repro.train.step import init_train_state, make_train_step

B, S = 2, 32
ARCHS = list_archs()


def _setup(arch_id):
    cfg = get_smoke(arch_id)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_tree(key, model.param_specs(), cfg.param_dtype)
    sharder = Sharder(None)
    return cfg, model, params, sharder


@pytest.mark.parametrize("arch_id", ARCHS)
def test_forward_shapes_and_finite(arch_id):
    cfg, model, params, sharder = _setup(arch_id)
    batch = make_batch(cfg, B, S, jax.random.PRNGKey(1), with_labels=False)
    logits, aux = jax.jit(
        lambda p, b: model.forward(p, b, sharder)
    )(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    if cfg.family == "moe":
        assert np.isfinite(float(aux["moe_aux"]))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_train_step_decreases_nothing_nan(arch_id):
    cfg, model, params, sharder = _setup(arch_id)
    state = init_train_state(model, params)
    step = jax.jit(make_train_step(model, sharder, peak_lr=1e-3, warmup=1,
                                   total_steps=10))
    batch = make_batch(cfg, B, S, jax.random.PRNGKey(2))
    state, metrics = step(state, batch)
    loss0 = float(metrics["loss"])
    assert np.isfinite(loss0)
    assert float(metrics["grad_norm"]) > 0
    # a couple more steps on the same batch must reduce the loss
    for _ in range(3):
        state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) < loss0 + 1e-3
    assert int(state["step"]) == 4


@pytest.mark.parametrize(
    "arch_id", [a for a in ARCHS if get_smoke(a).supports_decode]
)
def test_decode_step(arch_id):
    cfg, model, params, sharder = _setup(arch_id)
    cache, tok, pos = make_decode_inputs(cfg, B, max_len=S,
                                         key=jax.random.PRNGKey(3), pos=0)
    step = jax.jit(
        lambda p, c, t, po: model.decode_step(p, c, t, po, sharder)
    )
    logits, cache = step(params, cache, tok, pos)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    # advance one more position: cache round-trips through the jitted fn
    pos2 = pos + 1
    logits2, cache = step(params, cache, tok, pos2)
    assert np.all(np.isfinite(np.asarray(logits2, dtype=np.float32)))


@pytest.mark.parametrize(
    "arch_id", ["smollm_360m", "mamba2_2_7b", "recurrentgemma_9b",
                "h2o_danube_3_4b", "deepseek_moe_16b", "qwen1_5_110b"]
)
def test_decode_matches_prefill(arch_id):
    """Token-by-token decode must reproduce the full-sequence forward
    (teacher forcing) — validates cache semantics incl. ring buffers.

    MoE: compared under ample expert capacity — GShard prefill drops
    over-capacity tokens while single-token decode is dropless, an
    expected semantic difference, so the equality claim holds only when
    nothing is dropped."""
    import dataclasses

    cfg, model, params, sharder = _setup(arch_id)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        model = build_model(cfg)
    T = 8
    batch = make_batch(cfg, 1, T, jax.random.PRNGKey(4), with_labels=False)
    full_logits, _ = jax.jit(lambda p, b: model.forward(p, b, sharder))(
        params, batch
    )

    cache, _, _ = make_decode_inputs(cfg, 1, max_len=T,
                                     key=jax.random.PRNGKey(5))
    step = jax.jit(
        lambda p, c, t, po: model.decode_step(p, c, t, po, sharder)
    )
    outs = []
    for i in range(T):
        tok = batch["tokens"][:, i]
        pos = jnp.full((1,), i, jnp.int32)
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos, (3, 1))
        lg, cache = step(params, cache, tok, pos)
        outs.append(np.asarray(lg, dtype=np.float32))
    dec = np.stack(outs, axis=1)  # [1,T,V]
    ref = np.asarray(full_logits, dtype=np.float32)
    np.testing.assert_allclose(dec, ref, rtol=2e-3, atol=2e-3)


def test_microbatched_train_step_matches_single():
    cfg, model, params, sharder = _setup("smollm_360m")
    batch = make_batch(cfg, 4, S, jax.random.PRNGKey(6))
    s1 = init_train_state(model, params)
    s2 = jax.tree_util.tree_map(lambda x: x, s1)
    step1 = jax.jit(make_train_step(model, sharder, microbatches=1,
                                    peak_lr=1e-3, warmup=1, total_steps=10))
    step2 = jax.jit(make_train_step(model, sharder, microbatches=2,
                                    peak_lr=1e-3, warmup=1, total_steps=10))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4, atol=1e-5)
    # parameters close after one update
    l1 = jax.tree_util.tree_leaves(s1["params"])
    l2 = jax.tree_util.tree_leaves(s2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)
