"""Elastic slot parking: ``set_slot_target`` caps a runtime's effective
width by parking surplus slots at their tasks' next scheduling points
(riding the need-resched / lease-revocation path) and unparks on regrow.
This is the landing mechanism of node-level broker grants (repro.ipc) and
works identically in virtual time (SimExecutor) and under real threads
(UsfRuntime)."""

import threading
import time

import pytest

from repro.core import simtask as st
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair
from repro.core.task import Job
from repro.core.threads import UsfRuntime
from repro.core.topology import Topology


def _churn(n_phases, compute=0.001, pause=0.0002):
    def gen():
        for _ in range(n_phases):
            yield st.compute(compute)
            yield st.sleep(pause)
    return gen


# --------------------------------------------------------------------- #
# sim (deterministic)
# --------------------------------------------------------------------- #
def test_sim_shrink_parks_at_scheduling_points():
    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    job = Job("j")
    for _ in range(8):
        sim.spawn(job, _churn(400))
    sim.run(until=0.01)
    snap = sim.sched.snapshot()
    assert snap["slots_busy"] == 4 and snap["slots_parked"] == 0

    assert sim.set_slot_target(2) == 2
    sim.run(until=0.02)
    snap = sim.sched.snapshot()
    assert snap["slots_parked"] == 2
    assert snap["slots_busy"] == 2
    assert snap["slot_target"] == 2
    # the parked slots' tasks were requeued, not lost: everything finishes
    sim.set_slot_target(None)
    sim.run()
    assert all(t.done for t in job.tasks)


def test_sim_shrink_is_deferred_not_preemptive_for_coop():
    """SCHED_COOP tasks are never yanked: the width cap lands at each
    task's next scheduling point, so immediately after the cap more than
    ``target`` slots may still be busy — but no NEW dispatch widens."""
    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    job = Job("j")
    for _ in range(8):
        sim.spawn(job, _churn(400, compute=0.005))
    sim.run(until=0.012)  # mid-compute for all four slots
    sim.set_slot_target(1)
    snap = sim.sched.snapshot()
    # nothing was interrupted mid-compute (I2):
    assert snap["slots_busy"] == 4
    sim.run(until=0.03)  # every task passed a scheduling point by now
    snap = sim.sched.snapshot()
    assert snap["slots_busy"] == 1 and snap["slots_parked"] == 3


def test_sim_grow_refills_immediately():
    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    job = Job("j")
    for _ in range(8):
        sim.spawn(job, _churn(400))
    sim.set_slot_target(1)
    sim.run(until=0.02)
    assert sim.sched.snapshot()["slots_busy"] == 1
    sim.set_slot_target(4)
    # the unpark + fill happens inside set_slot_target (work-conserving
    # grant): busy immediately, before any further event
    assert sim.sched.snapshot()["slots_busy"] == 4
    sim.run()
    assert all(t.done for t in job.tasks)


def test_sim_target_floors_at_one_slot():
    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    assert sim.set_slot_target(0) == 1
    assert sim.set_slot_target(-3) == 1
    assert sim.set_slot_target(99) == 4
    job = Job("j")
    for _ in range(4):
        sim.spawn(job, _churn(50))
    sim.set_slot_target(0)  # still one active slot: work completes
    sim.run()
    assert all(t.done for t in job.tasks)


def test_sim_service_tracks_width():
    """Throughput proof: half the width -> about half the service rate
    for a saturated cooperative pool."""
    def measure(target):
        sim = SimExecutor(Topology(8, 1), SchedCoop(quantum=0.01),
                          max_time=1e9)
        job = Job("j")
        for _ in range(16):
            sim.spawn(job, _churn(10_000))
        if target is not None:
            sim.set_slot_target(target)
        sim.run(until=1.0)
        return job.service_time

    full = measure(None)
    half = measure(4)
    assert half / full == pytest.approx(0.5, rel=0.1)


def test_sim_parking_with_preemptive_policy_lands_within_a_tick():
    """A preemptive-policy task needs no cooperative blocking point: the
    cap lands at its next slice-expiry tick (the lease-revocation path)."""
    sim = SimExecutor(Topology(4, 1), SchedCoop(quantum=0.01), max_time=1e9)
    job = Job("fair")
    sim.attach(job, policy=SchedFair(slice_s=0.002), share=1.0)

    def hog():
        while True:
            yield st.compute(0.5)  # way past many slices

    for _ in range(8):
        sim.spawn(job, hog)
    sim.run(until=0.01)
    assert sim.sched.snapshot()["slots_busy"] == 4
    sim.set_slot_target(2)
    sim.run(until=0.02)  # a handful of tick periods later
    snap = sim.sched.snapshot()
    assert snap["slots_busy"] == 2 and snap["slots_parked"] == 2


# --------------------------------------------------------------------- #
# real threads
# --------------------------------------------------------------------- #
def test_threads_shrink_then_grow_bounds_concurrency():
    rt = UsfRuntime(Topology(4, 1), SchedCoop())
    try:
        lock = threading.Lock()
        state = {"cur": 0, "max": 0}
        job = Job("j")

        def body():
            for _ in range(6):
                with lock:
                    state["cur"] += 1
                    state["max"] = max(state["max"], state["cur"])
                time.sleep(0.002)
                with lock:
                    state["cur"] -= 1
                rt.yield_now()  # a scheduling point: parking can land

        assert rt.set_slot_target(1) == 1
        tasks = [rt.create(body, job=job) for _ in range(6)]
        for t in tasks:
            assert rt.join(t, timeout=30.0)
        assert state["max"] == 1  # capped below the 4-slot topology

        # regrow and verify the full width is usable again
        assert rt.set_slot_target(None) == 4
        state["max"] = 0
        tasks = [rt.create(body, job=job) for _ in range(8)]
        for t in tasks:
            assert rt.join(t, timeout=30.0)
        assert state["max"] > 1
    finally:
        rt.shutdown(timeout=5.0)


def test_threads_shrink_parks_running_width_via_checkpoints():
    """A mid-run revoke (the broker push) lands on CPU-bound tasks at
    their explicit checkpoints — the effective width shrinks without any
    cooperation from the task bodies beyond preemption points."""
    rt = UsfRuntime(Topology(4, 1), SchedCoop())
    try:
        lock = threading.Lock()
        state = {"cur": 0, "max_after": 0}
        shrunk = threading.Event()
        job = Job("j")

        def body():
            with lock:
                state["cur"] += 1
            try:
                deadline = time.monotonic() + 10.0
                while time.monotonic() < deadline:
                    rt.checkpoint()
                    if shrunk.is_set():
                        with lock:
                            state["max_after"] = max(state["max_after"],
                                                     state["cur"])
                        if state["cur"] <= 1:
                            return  # finished: observed the shrunk width
                    time.sleep(0)  # plain OS yield, not a USF point
            finally:
                with lock:
                    state["cur"] -= 1

        tasks = [rt.create(body, job=job) for _ in range(4)]
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline and state["cur"] < 4:
            time.sleep(0.005)
        assert state["cur"] == 4  # truly 4-wide before the revoke

        rt.set_slot_target(1)
        shrunk.set()
        for t in tasks:
            assert rt.join(t, timeout=30.0)
        # after the parked tasks drained, exactly one ran at a time; the
        # transient overshoot right after the revoke is expected (parking
        # lands at checkpoints), but it must settle to the target
        assert rt.sched.slot_target() == 1
        assert len(rt.sched.parked_slot_ids()) == 3
    finally:
        rt.shutdown(timeout=5.0)


def test_threads_blocked_wakeups_respect_cap():
    """Tasks waking from sleeps are funneled through the capped width."""
    rt = UsfRuntime(Topology(4, 1), SchedCoop())
    try:
        rt.set_slot_target(2)
        lock = threading.Lock()
        state = {"cur": 0, "max": 0}
        job = Job("j")

        def body():
            for _ in range(4):
                with lock:
                    state["cur"] += 1
                    state["max"] = max(state["max"], state["cur"])
                with lock:
                    state["cur"] -= 1
                rt.sleep(0.003)

        tasks = [rt.create(body, job=job) for _ in range(8)]
        for t in tasks:
            assert rt.join(t, timeout=30.0)
        assert state["max"] <= 2
    finally:
        rt.shutdown(timeout=5.0)
