"""Real-thread USF runtime tests: gating, thread cache, TLS, sync primitives.

These run genuine Python threads through the scheduler — the "glibcv" mode
that executes real JAX work in the serving engine and examples.
"""

import threading
import time

import pytest

from repro.core.policies import SchedCoop
from repro.core.sync import (
    BusyWaitBarrier,
    CoopBarrier,
    CoopCondVar,
    CoopEvent,
    CoopMutex,
    CoopSemaphore,
)
from repro.core.task import Job
from repro.core.threads import UsfRuntime
from repro.core.topology import Topology


@pytest.fixture
def rt():
    runtime = UsfRuntime(Topology(2, 1), SchedCoop())
    yield runtime
    runtime.shutdown(timeout=5.0)


def _join_all(rt, tasks, timeout=10.0):
    for t in tasks:
        assert rt.join(t, timeout=timeout), f"timeout joining {t}"


def test_gating_limits_concurrency(rt):
    """I1 in real mode: at most n_slots tasks run concurrently even when 8
    are created (the rest park, exactly like glibcv's blocked pthreads)."""
    lock = threading.Lock()
    state = {"cur": 0, "max": 0}
    job = Job("j")

    def body():
        with lock:
            state["cur"] += 1
            state["max"] = max(state["max"], state["cur"])
        time.sleep(0.02)
        with lock:
            state["cur"] -= 1

    tasks = [rt.create(body, job=job) for _ in range(8)]
    _join_all(rt, tasks)
    assert state["max"] <= 2


def test_thread_cache_reuse(rt):
    """§4.3.1: sequential create/join cycles reuse parked workers."""
    job = Job("j")
    for _ in range(6):
        t = rt.create(lambda: time.sleep(0.001), job=job)
        assert rt.join(t, timeout=5.0)
    assert rt.cache_hits >= 4
    assert rt.cache_misses <= 2


def test_tls_stable_across_blocking(rt):
    """The seamlessness claim: a task stays on one worker thread for its
    whole life, so threading.local state survives blocking points."""
    job = Job("j")
    sem = CoopSemaphore(rt, value=0)
    tls = threading.local()
    results = []

    def blocker():
        tls.value = "mine"
        tls.ident0 = threading.get_ident()
        sem.acquire()  # blocking point: slot is released and re-acquired
        results.append(
            (tls.value, tls.ident0 == threading.get_ident())
        )

    def releaser():
        time.sleep(0.05)
        sem.release()

    t1 = rt.create(blocker, job=job)
    t2 = rt.create(releaser, job=job)
    _join_all(rt, [t1, t2])
    assert results == [("mine", True)]


def test_coop_mutex_mutual_exclusion(rt):
    job = Job("j")
    m = CoopMutex(rt)
    counter = {"v": 0, "in_cs": 0, "max_in_cs": 0}

    def body():
        for _ in range(50):
            m.lock()
            counter["in_cs"] += 1
            counter["max_in_cs"] = max(counter["max_in_cs"], counter["in_cs"])
            counter["v"] += 1
            counter["in_cs"] -= 1
            m.unlock()

    tasks = [rt.create(body, job=job) for _ in range(4)]
    _join_all(rt, tasks)
    assert counter["v"] == 200
    assert counter["max_in_cs"] == 1


def test_coop_barrier(rt):
    job = Job("j")
    b = CoopBarrier(rt, 4)
    phase_counts = []
    lock = threading.Lock()
    arrived = {"n": 0}

    def body():
        with lock:
            arrived["n"] += 1
        b.wait()
        with lock:
            phase_counts.append(arrived["n"])

    tasks = [rt.create(body, job=job) for _ in range(4)]
    _join_all(rt, tasks)
    # nobody passed the barrier before all 4 arrived
    assert phase_counts == [4, 4, 4, 4]


def test_coop_condvar(rt):
    job = Job("j")
    m = CoopMutex(rt)
    cv = CoopCondVar(rt, m)
    state = {"ready": False, "consumed": False}

    def waiter():
        m.lock()
        while not state["ready"]:
            cv.wait()
        state["consumed"] = True
        m.unlock()

    def notifier():
        time.sleep(0.02)
        m.lock()
        state["ready"] = True
        cv.notify()
        m.unlock()

    tasks = [rt.create(waiter, job=job), rt.create(notifier, job=job)]
    _join_all(rt, tasks)
    assert state["consumed"]


def test_coop_event(rt):
    job = Job("j")
    ev = CoopEvent(rt)
    order = []

    def waiter():
        ev.wait()
        order.append("woken")

    def setter():
        time.sleep(0.02)
        order.append("setting")
        ev.set()

    tasks = [rt.create(waiter, job=job), rt.create(setter, job=job)]
    _join_all(rt, tasks)
    assert order == ["setting", "woken"]


def test_busywait_barrier_with_yield_completes(rt):
    """§5.2 in real mode: 3 spinners on 2 slots complete thanks to the
    yield adaptation (without it they would livelock the runtime)."""
    job = Job("j")
    b = BusyWaitBarrier(rt, 3, yield_every=1)

    def body():
        b.wait(max_spins=100_000)

    tasks = [rt.create(body, job=job) for _ in range(3)]
    _join_all(rt, tasks)


def test_yield_now(rt):
    job = Job("j")
    seen = []

    def body(i):
        def fn():
            seen.append(i)
            rt.yield_now()
            seen.append(i)

        return fn

    tasks = [rt.create(body(i), job=job) for i in range(4)]
    _join_all(rt, tasks)
    assert sorted(seen) == [0, 0, 1, 1, 2, 2, 3, 3]


def test_sleep_is_a_scheduling_point(rt):
    """rt.sleep releases the slot: with 1 sleeping + 1 computing task on a
    1-slot runtime, the computing task runs *during* the sleep."""
    runtime = UsfRuntime(Topology(1, 1), SchedCoop())
    try:
        job = Job("j")
        order = []

        def sleeper():
            order.append("sleep-start")
            runtime.sleep(0.2)
            order.append("sleep-end")

        def worker():
            order.append("worked")

        t1 = runtime.create(sleeper, job=job)
        time.sleep(0.05)
        t2 = runtime.create(worker, job=job)
        _join_all(runtime, [t1, t2])
        assert order == ["sleep-start", "worked", "sleep-end"]
    finally:
        runtime.shutdown(timeout=5.0)


def test_free_mode_is_unmanaged():
    """gating=False = the Linux-baseline: all threads run concurrently."""
    runtime = UsfRuntime(Topology(2, 1), SchedCoop(), gating=False)
    try:
        job = Job("j")
        lock = threading.Lock()
        state = {"cur": 0, "max": 0}
        go = threading.Event()

        def body():
            with lock:
                state["cur"] += 1
                state["max"] = max(state["max"], state["cur"])
            go.wait(1.0)
            with lock:
                state["cur"] -= 1

        tasks = [runtime.create(body, job=job) for _ in range(6)]
        time.sleep(0.2)
        go.set()
        _join_all(runtime, tasks)
        assert state["max"] == 6  # oversubscribed: nobody was gated
    finally:
        runtime.shutdown(timeout=5.0)


def test_watchdog_heap_coalesces_256_slots_into_interval_classes():
    """The watchdog-scale satellite: 256 slots armed across 2 tick
    intervals ride TWO periodic heap entries, not 256 — heap size is
    O(distinct intervals + pending timed wakeups), never O(slots)."""
    runtime = UsfRuntime(Topology(256, 1), SchedCoop())
    try:
        wd = runtime.watchdog
        # long intervals so nothing fires while we inspect the heap
        for sid in range(256):
            wd.arm_tick(sid, 5.0 if sid % 2 == 0 else 8.0)
        stats = wd.tick_heap_stats()
        assert stats["slots_armed"] == 256
        assert stats["interval_classes"] == 2
        assert stats["tick_entries"] == 2, (
            f"per-slot heap entries are back: {stats}")
        # re-arming every slot again is pure dedup: zero heap growth
        for sid in range(256):
            wd.arm_tick(sid, 5.0 if sid % 2 == 0 else 8.0)
        assert wd.tick_heap_stats()["tick_entries"] == 2
        # migrating half the slots to the SHORTER class (an earlier
        # service: migrates immediately) keeps the bound at the number of
        # interval classes (the abandoned entry dies at pop); arming the
        # other half with a LONGER period is refused until the short
        # class fires — an arm never lengthens a pending service
        for sid in range(1, 256, 2):
            wd.arm_tick(sid, 5.0)  # 8.0 -> 5.0: earlier, migrates now
        for sid in range(0, 256, 2):
            wd.arm_tick(sid, 8.0)  # 5.0 -> 8.0: later, deferred to fire
        stats = wd.tick_heap_stats()
        assert stats["tick_entries"] <= 2
        assert stats["interval_classes"] <= 2
        with wd._cv:
            assert all(i == 5.0 for i in wd._slot_interval.values())
        # timed wakeups share the heap and still fire while classes armed
        fired = threading.Event()
        wd.call_later(0.05, fired.set)
        assert fired.wait(5.0), "timed wakeup starved by tick classes"
        # cancelled timed entries are still compacted away (the heap must
        # not pin dead waiter closures among the class entries)
        handles = [wd.call_later(300.0, lambda: None) for _ in range(200)]
        for h in handles:
            h.cancel()
        assert wd.tick_heap_stats()["heap_len"] < 100
    finally:
        runtime.shutdown(timeout=5.0)


def test_watchdog_scale_two_intervals_under_real_threads():
    """Steady-state bound under genuinely ticking real threads: two
    preemptive jobs with different tick periods spin across the slots;
    sampled over many fire/re-arm rounds the heap never holds more tick
    entries than interval classes, preemptions are delivered for both
    periods, and sleep/join timeouts keep firing throughout."""
    from repro.core.policies import SchedFair, SchedRR

    tick_a, tick_b = 0.02, 0.035
    runtime = UsfRuntime(Topology(2, 1), SchedCoop())
    try:
        fair, rr = Job("fair"), Job("rr")
        runtime.attach(fair, policy=SchedFair(slice_s=tick_a), share=1.0)
        runtime.attach(rr, policy=SchedRR(quantum=tick_b), share=1.0)
        stop = threading.Event()

        def spin():
            n = 0
            while not stop.is_set():
                n += 1
                if n % 500 == 0:
                    runtime.checkpoint()

        tasks = [runtime.create(spin, job=fair) for _ in range(2)]
        tasks += [runtime.create(spin, job=rr) for _ in range(2)]
        max_tick_entries = 0
        deadline = time.monotonic() + 20 * tick_a
        while time.monotonic() < deadline:
            s = runtime.watchdog.tick_heap_stats()
            max_tick_entries = max(max_tick_entries, s["tick_entries"])
            time.sleep(0.005)
        assert max_tick_entries <= 2, (
            f"{max_tick_entries} tick entries for 2 interval classes")
        assert runtime.watchdog.ticks_fired > 0
        # a join timeout rides the same heap and still fires on time
        t0 = time.monotonic()
        assert runtime.join(tasks[0], timeout=2 * tick_a) is False
        assert time.monotonic() - t0 < 5.0
        stop.set()
        for t in tasks:
            assert runtime.join(t, timeout=10.0)
        # both interval classes delivered preemptions to their spinners
        assert sum(t.stats.preemptions for t in fair.tasks) >= 1
        assert sum(t.stats.preemptions for t in rr.tasks) >= 1
    finally:
        runtime.shutdown(timeout=5.0)


def test_affinity_hint_stored_and_returned(rt):
    """§4.3.2: setaffinity is a hint; getaffinity returns the stored hint."""
    job = Job("j")
    out = {}

    def body():
        t = rt.current_task()
        t.set_affinity_hint(frozenset({0}))
        out["hint"] = t.get_affinity()

    task = rt.create(body, job=job)
    _join_all(rt, [task])
    assert out["hint"] == frozenset({0})


def test_coop_mutex_lock_timeout_gated(rt):
    """CoopMutex.lock(timeout=...) returns bool — consistent with
    CoopEvent.wait(timeout) — for gated waiters: a held lock times the
    contender out; a timely handoff returns True."""
    job = Job("j")
    m = CoopMutex(rt)
    out = {}
    holder_locked = threading.Event()
    release = CoopEvent(rt)

    def holder():
        assert m.lock() is True
        holder_locked.set()
        release.wait()
        m.unlock()

    def contender():
        out["timed_out"] = m.lock(timeout=0.05)     # held: must time out
        release.set()
        out["acquired"] = m.lock(timeout=10.0)      # free soon: must win
        if out["acquired"]:
            m.unlock()

    t1 = rt.create(holder, job=job)
    assert holder_locked.wait(5.0)
    t2 = rt.create(contender, job=job)
    _join_all(rt, [t1, t2])
    assert out["timed_out"] is False
    assert out["acquired"] is True
    # the lock is fully released: an uncontended lock is immediate
    assert m.lock(timeout=0.0) is True
    m.unlock()


def test_coop_mutex_lock_timeout_plain_thread(rt):
    """Plain (non-USF) threads honor the same timeout via the embedded
    Event — mixed use against the SAME mutex state."""
    job = Job("j")
    m = CoopMutex(rt)
    locked = threading.Event()
    release = CoopEvent(rt)

    def gated_holder():
        m.lock()
        locked.set()
        release.wait()
        m.unlock()

    t = rt.create(gated_holder, job=job)
    assert locked.wait(5.0)
    out = {}

    def plain():
        out["timed_out"] = m.lock(timeout=0.05)
        release.set()
        out["acquired"] = m.lock(timeout=10.0)
        if out["acquired"]:
            m.unlock()

    th = threading.Thread(target=plain)
    th.start()
    th.join(30.0)
    assert not th.is_alive()
    _join_all(rt, [t])
    assert out["timed_out"] is False
    assert out["acquired"] is True


def test_coop_mutex_timeout_zero_is_trylock(rt):
    m = CoopMutex(rt)
    assert m.lock(timeout=0.0) is True   # uncontended: granted
    assert m.lock(timeout=0.0) is False  # held: immediate refusal...
    assert m.lock(timeout=-1.0) is False
    m.unlock()


def test_coop_mutex_timed_out_waiter_skipped_by_unlock(rt):
    """A waiter that timed out must be withdrawn from the FIFO: the next
    unlock hands off to the NEXT waiter (or frees the lock), it does not
    reserve ownership for a ghost."""
    job = Job("j")
    m = CoopMutex(rt)
    locked = threading.Event()
    release = CoopEvent(rt)
    order = []

    def holder():
        m.lock()
        locked.set()
        release.wait()
        m.unlock()

    def quitter():
        order.append(("quitter", m.lock(timeout=0.05)))

    def patient():
        order.append(("patient", m.lock(timeout=30.0)))
        m.unlock()

    t1 = rt.create(holder, job=job)
    assert locked.wait(5.0)
    t2 = rt.create(quitter, job=job)
    _join_all(rt, [t2])  # quitter gave up while the lock is still held
    t3 = rt.create(patient, job=job)
    time.sleep(0.02)  # patient is queued behind the (gone) quitter
    release.set()
    _join_all(rt, [t1, t3])
    assert ("quitter", False) in order
    assert ("patient", True) in order
