"""The extracted lease/quota machinery (repro.core.lease): the shared
apportionment + I5 borrow order both the in-process SlotArbiter and the
node-level broker consume. The extraction must be behaviour-identical to
the arbiter's previous inline implementation — property-tested here and
cross-checked against live SlotArbiter quotas."""

import random

import pytest

from repro.core.events import SimExecutor
from repro.core.lease import LeaseTable, apportion, borrow_order
from repro.core.policies import SchedCoop, SchedFair
from repro.core.task import Job
from repro.core.topology import Topology


class Entry:
    __slots__ = ("share", "quota", "in_use", "tag")

    def __init__(self, share, in_use=0, tag=""):
        self.share = share
        self.quota = 0
        self.in_use = in_use
        self.tag = tag


# --------------------------------------------------------------------- #
# apportion: largest remainder
# --------------------------------------------------------------------- #
def test_apportion_sums_to_capacity():
    rng = random.Random(7)
    for _ in range(200):
        n = rng.randrange(0, 257)
        k = rng.randrange(1, 9)
        shares = [rng.choice([0.0, 0.5, 1.0, 2.0, 7.0, 1024.0])
                  for _ in range(k)]
        quotas = apportion(n, shares)
        assert len(quotas) == k
        assert all(q >= 0 for q in quotas)
        if n > 0:
            assert sum(quotas) == n, (n, shares, quotas)


def test_apportion_proportionality():
    assert apportion(8, [1.0, 3.0]) == [2, 6]
    assert apportion(8, [1.0, 1.0]) == [4, 4]
    assert apportion(16, [1.0, 7.0]) == [2, 14]
    # largest remainder: 10 * [1,1,1]/3 = 3.33 each -> remainders break
    # the tie in entry order
    assert apportion(10, [1.0, 1.0, 1.0]) == [4, 3, 3]


def test_apportion_zero_shares_fall_back_to_equal():
    assert apportion(8, [0.0, 0.0]) == [4, 4]
    assert apportion(3, [0.0, 0.0]) == [2, 1]


def test_apportion_empty_and_zero_capacity():
    assert apportion(8, []) == []
    assert apportion(0, [1.0, 2.0]) == [0, 0]


def test_apportion_integer_exactness_never_loses_whole_quota():
    # a share entitled to an exact integer must get at least that floor
    for n, shares in ((8, [2.0, 6.0]), (112, [1.0] * 7), (56, [4.0, 4.0])):
        quotas = apportion(n, shares)
        total = sum(shares)
        for q, s in zip(quotas, shares):
            assert q >= int(n * s / total)


# --------------------------------------------------------------------- #
# borrow order: the I5 grant rule
# --------------------------------------------------------------------- #
def test_borrow_order_spare_lease_first():
    a = Entry(1.0, in_use=0, tag="spare-2")   # quota 2 below
    b = Entry(1.0, in_use=3, tag="over-1")
    c = Entry(1.0, in_use=1, tag="spare-1")
    for e, q in ((a, 2), (b, 2), (c, 2)):
        e.quota = q
    order = [e.tag for e in borrow_order([a, b, c])]
    # most spare first, borrowers (over quota) strictly last
    assert order == ["spare-2", "spare-1", "over-1"]


def test_borrow_order_ties_break_by_given_order():
    a, b = Entry(1.0, tag="first"), Entry(1.0, tag="second")
    a.quota = b.quota = 1
    assert [e.tag for e in borrow_order([a, b])] == ["first", "second"]
    assert [e.tag for e in borrow_order([b, a])] == ["second", "first"]


def test_borrow_order_least_over_first_among_borrowers():
    a = Entry(1.0, in_use=5, tag="over-3")
    b = Entry(1.0, in_use=3, tag="over-1")
    a.quota = b.quota = 2
    assert [e.tag for e in borrow_order([a, b])] == ["over-1", "over-3"]


# --------------------------------------------------------------------- #
# LeaseTable
# --------------------------------------------------------------------- #
def test_lease_table_recompute_writes_quotas():
    t = LeaseTable(8)
    a, b = Entry(1.0), Entry(3.0)
    t.add("a", a)
    t.add("b", b)
    t.recompute()
    assert (a.quota, b.quota) == (2, 6)
    b.share = 1.0
    t.recompute()
    assert (a.quota, b.quota) == (4, 4)
    t.pop("b")
    t.recompute()
    assert a.quota == 8


def test_lease_table_membership_and_spare():
    t = LeaseTable(4)
    a = Entry(1.0, in_use=1)
    t.add("a", a)
    assert "a" in t and len(t) == 1 and t.get("a") is a
    assert t.spare() == 3
    assert t.get("missing") is None


# --------------------------------------------------------------------- #
# equivalence: the arbiter's quotas ARE the table's quotas
# --------------------------------------------------------------------- #
def test_arbiter_quotas_match_standalone_table():
    """The extraction is behaviour-preserving: a SlotArbiter with K
    attached jobs computes exactly the quotas a standalone LeaseTable
    computes for the same shares over the same capacity."""
    rng = random.Random(11)
    for trial in range(20):
        n_slots = rng.choice([4, 8, 16, 112])
        sim = SimExecutor(Topology(n_slots, 1), SchedCoop(quantum=0.01),
                          max_time=1e9)
        shares = [rng.choice([0.5, 1.0, 2.0, 3.0, 7.0])
                  for _ in range(rng.randrange(1, 6))]
        leases = []
        for i, s in enumerate(shares):
            job = Job(f"j{trial}-{i}")
            policy = (SchedCoop(quantum=0.01) if i % 2 == 0
                      else SchedFair(slice_s=0.002))
            leases.append(sim.attach(job, policy=policy, share=s))
        table = LeaseTable(n_slots)
        entries = [Entry(s) for s in shares]
        for i, e in enumerate(entries):
            table.add(i, e)
        table.recompute()
        for lease, entry in zip(leases, entries):
            assert lease.quota == entry.quota, (
                trial, n_slots, shares, lease.share)


def test_pick_multi_candidate_order_is_borrow_order():
    """The arbiter inlines the I5 grant order into its per-pick filter
    pass (hot path); this locksteps that inline ordering against the
    shared ``lease.borrow_order`` over random lease states."""
    rng = random.Random(23)
    for _ in range(300):
        k = rng.randrange(1, 7)
        groups = []
        for i in range(k):
            e = Entry(1.0, in_use=rng.randrange(0, 6), tag=i)
            e.quota = rng.randrange(0, 6)
            groups.append(e)
        # the arbiter's inline construction (filter + tuple sort) ...
        candidates = [(g.in_use - g.quota, i, g)
                      for i, g in enumerate(groups)]
        candidates.sort()
        inline = [g for _, _, g in candidates]
        # ... must equal the shared borrow order
        assert inline == borrow_order(groups)


def test_arbiter_capacity_tracks_slot_target():
    """Elastic slot parking re-apportions the in-process leases over the
    ACTIVE pool: shrinking the target shrinks quotas proportionally."""
    sim = SimExecutor(Topology(8, 1), SchedCoop(quantum=0.01), max_time=1e9)
    la = sim.attach(Job("a"), policy=SchedCoop(quantum=0.01), share=1.0)
    lb = sim.attach(Job("b"), policy=SchedCoop(quantum=0.01), share=3.0)
    assert (la.quota, lb.quota) == (2, 6)
    sim.set_slot_target(4)
    assert (la.quota, lb.quota) == (1, 3)
    sim.set_slot_target(None)
    assert (la.quota, lb.quota) == (2, 6)
