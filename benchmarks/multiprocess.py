"""Cross-process co-location under oversubscription — the paper's headline
multi-process claim, on real OS processes.

Two CPU-hungry worker *processes* (numpy compute phases meeting at a
per-process barrier each iteration — the nested-BLAS shape of §5.2/§5.3)
share one node:

* **free** — the Linux baseline: both processes run ``gating=False`` with
  unmodified busy-wait barriers, each sized to the whole node. 2x
  oversubscription: spinners burn cores (and the interpreter) while the
  sibling process fights for the same CPUs.
* **usf** — broker-coordinated: one ``NodeBroker`` (in the benchmark
  driver, the designated process) apportions the node across the worker
  processes; each worker's ``BrokerClient`` lands its grant on elastic
  slot parking (``UsfRuntime.set_slot_target``) and its threads meet at a
  cooperative barrier. Total running threads == node slots, no spin.

Scenarios:

* ``spin_colocate``: equal work, free vs broker-coordinated. Target:
  the co-location makespan (max across processes) improves **≥ 1.5x**.
* ``elastic_handoff``: unequal work, broker-coordinated vs *static*
  half-node caps (the bl-eq analogue at process level). When the small
  process finishes, the broker reclaims its lease and regrants the node
  to the survivor mid-run — work conservation a static partition cannot
  express.
* ``demand_feedback``: the idle/saturated phase shift. Two processes
  alternate busy bursts in antiphase (a baton of events serializes the
  turns); both stay alive and registered throughout. Bursts are
  *latency-bound*: each phase is a small matmul plus a blocking wait
  (the IO/RPC serving shape — the wait pins its slot, so granted width
  IS the achievable in-flight concurrency, independent of host core
  count). With static wants (``report_backlog=False`` — the
  pre-demand-feedback broker) each burst runs at half the node while
  the idle sibling pins its grant; with live backlog feedback the idle
  worker's effective want decays to zero within a few damped heartbeats
  and the saturated worker bursts at (nearly) full node width. Target:
  demand-aware beats static-want **≥ 1.3x** on makespan (asserted in
  full runs; smoke proves the machinery, including that demand-driven
  regrants actually fired).

Run:  PYTHONPATH=src python -m benchmarks.multiprocess [--smoke]
Writes BENCH_multiprocess.json (smoke: BENCH_multiprocess.smoke.json via
``make check``; the ratios are asserted only in full mode — CI smoke just
proves the machinery end-to-end).
"""

from __future__ import annotations

import argparse
import multiprocessing as mp
import os
import sys
import time

from benchmarks.common import default_out, write_artifact

_CTX = mp.get_context("spawn")

N_PROCS = 2


def _node_slots() -> int:
    return max(2, min(os.cpu_count() or 2, 8))


def _colocate_worker(mode: str, broker_path, slots: int, threads: int,
                     phases: int, n: int, slot_cap, go, result_q,
                     name: str) -> None:
    """One worker process: ``threads`` compute/barrier tasks on its own
    runtime. ``mode``: free (unmanaged + spin barrier) | usf (gated +
    coop barrier, broker-coordinated when ``broker_path`` is set, or
    statically capped at ``slot_cap``)."""
    # our runtime provides the parallelism: a BLAS-internal thread pool
    # (spinning between calls) would add *uncoordinated* oversubscription
    # to every mode and drown the comparison in noise
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS",
                "MKL_NUM_THREADS"):
        os.environ.setdefault(var, "1")
    import numpy as np

    from repro.core.policies import SchedCoop
    from repro.core.sync import BusyWaitBarrier, CoopBarrier
    from repro.core.task import Job
    from repro.core.threads import UsfRuntime
    from repro.core.topology import Topology

    gating = mode == "usf"
    rt = UsfRuntime(Topology(slots, 1), SchedCoop(), gating=gating)
    client = None
    if gating and broker_path:
        from repro.ipc import BrokerClient

        client = BrokerClient(broker_path, name=name,
                              share=1.0).bind(rt).start()
        client.wait_grant(5.0)
    elif gating and slot_cap:
        rt.set_slot_target(slot_cap)  # static partition (no broker)
    bar = (CoopBarrier(rt, threads) if gating
           else BusyWaitBarrier(rt, threads, yield_every=None))
    job = Job(name)
    a = np.random.default_rng(0).standard_normal((n, n), dtype=np.float64)

    def body():
        x = a.copy()
        for _ in range(phases):
            x = x @ a                       # GIL-releasing compute burst
            x *= 1.0 / np.abs(x).max()
            bar.wait()                      # the per-phase team barrier

    go.wait()
    t0 = time.monotonic()
    tasks = [rt.create(body, job=job) for _ in range(threads)]
    for t in tasks:
        if not rt.join(t, timeout=600.0):
            result_q.put({"name": name, "error": "join timeout"})
            return
    makespan = time.monotonic() - t0
    granted = None if client is None else client.granted
    if client is not None:
        client.stop()  # deregister: survivors inherit this lease
    result_q.put({"name": name, "makespan": makespan,
                  "final_grant": granted})
    rt.shutdown(timeout=5.0)


def _run_colocation(mode: str, *, phases_per_proc, n: int,
                    coordinate: bool, slot_cap=None) -> dict:
    """Launch N_PROCS co-located workers, release them simultaneously,
    gather per-process makespans."""
    slots = _node_slots()
    broker = None
    path = None
    if coordinate:
        from repro.ipc import NodeBroker

        broker = NodeBroker(capacity=slots, heartbeat_timeout=2.0)
        path = broker.start()
    go = _CTX.Event()
    result_q = _CTX.Queue()
    procs = []
    for i, phases in enumerate(phases_per_proc):
        p = _CTX.Process(
            target=_colocate_worker,
            args=(mode, path, slots, slots, phases, n, slot_cap, go,
                  result_q, f"proc{i}"),
            daemon=True)
        p.start()
        procs.append(p)
    try:
        time.sleep(1.0)  # runtimes (and broker registrations) come up
        go.set()
        results = [result_q.get(timeout=600.0) for _ in procs]
    finally:
        for p in procs:
            p.join(30.0)
            if p.is_alive():
                p.terminate()
        if broker is not None:
            broker.stop()
    errs = [r for r in results if "error" in r]
    if errs:
        raise RuntimeError(f"worker failure: {errs}")
    by_name = {r["name"]: r for r in results}
    return {
        "mode": mode,
        "coordinated": coordinate,
        "node_slots": slots,
        "per_proc_makespan": {k: round(v["makespan"], 4)
                              for k, v in sorted(by_name.items())},
        "makespan": round(max(r["makespan"] for r in results), 4),
    }


def _phase_worker(broker_path, slots: int, threads: int, phases: int,
                  n: int, wait_s: float, batons, parity: int, go, result_q,
                  name: str, report_backlog: bool, hb: float) -> None:
    """One phase-shift worker: takes every other baton, bursts ``threads``
    latency-bound tasks (matmul + a blocking ``wait_s`` per phase — the
    blocking wait holds its slot, so burst time scales with
    ``threads / granted_width``). Between its turns the main thread
    blocks on a plain mp Event — the runtime is truly idle, so a
    demand-reporting heartbeat sees backlog 0 and the broker can drain
    this worker's lease to the busy sibling. ``report_backlog=False``
    replays the static-want (v1) broker contract as the A/B baseline."""
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS",
                "MKL_NUM_THREADS"):
        os.environ.setdefault(var, "1")
    import numpy as np

    from repro.core.policies import SchedCoop
    from repro.core.sync import CoopBarrier
    from repro.core.task import Job
    from repro.core.threads import UsfRuntime
    from repro.core.topology import Topology
    from repro.ipc import BrokerClient

    rt = UsfRuntime(Topology(slots, 1), SchedCoop())
    client = BrokerClient(broker_path, name=name, share=1.0,
                          heartbeat_interval=hb,
                          report_backlog=report_backlog).bind(rt).start()
    client.wait_grant(5.0)
    job = Job(name)
    a = np.random.default_rng(0).standard_normal((n, n), dtype=np.float64)
    go.wait()
    t0 = time.monotonic()
    for k in range(parity, len(batons) - 1, 2):
        batons[k].wait()                    # idle until it is our turn
        bar = CoopBarrier(rt, threads)

        def body():
            x = a.copy()
            for _ in range(phases):
                x = x @ a
                x *= 1.0 / np.abs(x).max()
                time.sleep(wait_s)          # blocking wait: pins the slot
                bar.wait()

        tasks = [rt.create(body, job=job) for _ in range(threads)]
        for t in tasks:
            if not rt.join(t, timeout=600.0):
                result_q.put({"name": name, "error": "join timeout"})
                return
        batons[k + 1].set()                 # sibling's turn
    makespan = time.monotonic() - t0
    result_q.put({"name": name, "makespan": makespan,
                  "final_grant": client.granted})
    client.stop()
    rt.shutdown(timeout=5.0)


def _run_phase_shift(*, report_backlog: bool, bursts_per_proc: int,
                     phases: int, n: int, wait_s: float) -> dict:
    """Antiphase busy/idle workers under one broker. The baton chain
    serializes the bursts, so the whole run is a sequence of
    (one saturated, one idle) intervals — the exact shape where live
    demand pays and static wants strand half the node."""
    slots = _node_slots()
    from repro.ipc import NodeBroker

    # fast demand knobs: the benchmark measures steady-burst throughput,
    # not damping latency, so keep the regrant reaction well under a
    # burst length (the same knobs are used for the static baseline,
    # where they are inert)
    broker = NodeBroker(capacity=slots, heartbeat_timeout=2.0,
                        demand_beats=2, min_regrant_interval=0.02)
    path = broker.start()
    n_bursts = bursts_per_proc * N_PROCS
    batons = [_CTX.Event() for _ in range(n_bursts + 1)]
    go = _CTX.Event()
    result_q = _CTX.Queue()
    procs = []
    for i in range(N_PROCS):
        p = _CTX.Process(
            target=_phase_worker,
            args=(path, slots, slots, phases, n, wait_s, batons, i, go,
                  result_q, f"proc{i}", report_backlog, 0.02),
            daemon=True)
        p.start()
        procs.append(p)
    try:
        time.sleep(1.0)  # runtimes and registrations come up
        go.set()
        batons[0].set()
        results = [result_q.get(timeout=600.0) for _ in procs]
        counters = {k: v for k, v in broker.snapshot().items()
                    if k in ("regrants", "demand_regrants", "grants_pushed",
                             "grants_suppressed")}
    finally:
        for p in procs:
            p.join(30.0)
            if p.is_alive():
                p.terminate()
        broker.stop()
    errs = [r for r in results if "error" in r]
    if errs:
        raise RuntimeError(f"worker failure: {errs}")
    by_name = {r["name"]: r for r in results}
    return {
        "mode": "demand" if report_backlog else "static_want",
        "node_slots": slots,
        "bursts_per_proc": bursts_per_proc,
        "per_proc_makespan": {k: round(v["makespan"], 4)
                              for k, v in sorted(by_name.items())},
        "makespan": round(max(r["makespan"] for r in results), 4),
        "broker_counters": counters,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_multiprocess.json, "
                         "or BENCH_multiprocess.smoke.json with --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny work: proves the machinery, skips the "
                         "ratio assertion (CI hosts are noisy)")
    args = ap.parse_args(argv)
    phases = 12 if args.smoke else 80
    n = 96 if args.smoke else 128

    # -- scenario 1: equal co-location, free vs broker-coordinated ------- #
    free = _run_colocation("free", phases_per_proc=[phases] * N_PROCS,
                           n=n, coordinate=False)
    usf = _run_colocation("usf", phases_per_proc=[phases] * N_PROCS,
                          n=n, coordinate=True)
    speedup = free["makespan"] / usf["makespan"]
    print(f"spin_colocate ({N_PROCS} procs x {free['node_slots']} threads, "
          f"{phases} phases):")
    print(f"  free-running (oversubscribed busy-wait): "
          f"{free['makespan']:.3f}s  {free['per_proc_makespan']}")
    print(f"  broker-coordinated:                      "
          f"{usf['makespan']:.3f}s  {usf['per_proc_makespan']}")
    print(f"  speedup: {speedup:.2f}x (target >= 1.5x)")

    # -- scenario 2: unequal work — elastic handoff vs static split ------ #
    slots = _node_slots()
    # the small process exits early; the survivor's long tail is where the
    # reclaimed lease pays (the tail must dominate its pre-handoff phase,
    # and each phase must be coarse enough that extra width beats the
    # cross-thread barrier cost — hence the bigger matmul)
    uneven = [max(2, phases // 16), phases]
    n_handoff = 192 if args.smoke else 256
    static = _run_colocation("usf", phases_per_proc=uneven, n=n_handoff,
                             coordinate=False, slot_cap=max(1, slots // 2))
    elastic = _run_colocation("usf", phases_per_proc=uneven, n=n_handoff,
                              coordinate=True)
    handoff = static["makespan"] / elastic["makespan"]
    print(f"elastic_handoff (uneven work {uneven}):")
    print(f"  static half-node caps: {static['makespan']:.3f}s  "
          f"{static['per_proc_makespan']}")
    print(f"  broker (lease reclaimed at exit): {elastic['makespan']:.3f}s  "
          f"{elastic['per_proc_makespan']}")
    print(f"  work-conservation gain: {handoff:.2f}x")

    # -- scenario 3: idle/saturated phase shift — live demand vs static -- #
    # each burst must dwarf the demand-damping latency (a few heartbeats
    # + min-regrant interval, ~0.1s with the bench knobs) or the regrant
    # reaction time eats the concurrency gain — hence the coarse full-run
    # burst (~1s at the static half-node width)
    bursts = 1 if args.smoke else 2
    ps_phases = 10 if args.smoke else 60
    ps_wait = 0.005 if args.smoke else 0.008
    static_ps = _run_phase_shift(report_backlog=False,
                                 bursts_per_proc=bursts,
                                 phases=ps_phases, n=n, wait_s=ps_wait)
    demand_ps = _run_phase_shift(report_backlog=True,
                                 bursts_per_proc=bursts,
                                 phases=ps_phases, n=n, wait_s=ps_wait)
    feedback = static_ps["makespan"] / demand_ps["makespan"]
    print(f"demand_feedback (antiphase bursts, {bursts} per proc, "
          f"{ps_phases} phases):")
    print(f"  static wants (idle sibling pins half): "
          f"{static_ps['makespan']:.3f}s  {static_ps['per_proc_makespan']}")
    print(f"  live backlog feedback:                 "
          f"{demand_ps['makespan']:.3f}s  {demand_ps['per_proc_makespan']}  "
          f"counters={demand_ps['broker_counters']}")
    print(f"  demand-feedback gain: {feedback:.2f}x (target >= 1.3x)")
    # machinery check, valid even in smoke: the demand run must have
    # actually moved leases on backlog feedback, and the static run must
    # not have (its clients beat without the field)
    if demand_ps["broker_counters"]["demand_regrants"] < 1:
        print("FAIL: demand run triggered no demand-driven regrants",
              file=sys.stderr)
        return 1
    if static_ps["broker_counters"]["demand_regrants"] != 0:
        print("FAIL: static-want run saw demand-driven regrants",
              file=sys.stderr)
        return 1

    payload = {
        "bench": "multiprocess",
        "smoke": args.smoke,
        "n_procs": N_PROCS,
        "node_slots": slots,
        "phases": phases,
        "matmul_n": n,
        "scenarios": {
            "spin_colocate": {
                "free": free,
                "usf": usf,
                "speedup": round(speedup, 3),
                "target": 1.5,
                "meets_target": speedup >= 1.5,
            },
            "elastic_handoff": {
                "static": static,
                "elastic": elastic,
                "gain": round(handoff, 3),
            },
            "demand_feedback": {
                "static": static_ps,
                "demand": demand_ps,
                "gain": round(feedback, 3),
                "target": 1.3,
                "meets_target": feedback >= 1.3,
            },
        },
    }
    write_artifact(default_out("multiprocess", args.smoke, args.out), payload)
    if not args.smoke and speedup < 1.5:
        print(f"FAIL: broker-coordinated speedup {speedup:.2f}x < 1.5x",
              file=sys.stderr)
        return 1
    if not args.smoke and feedback < 1.3:
        print(f"FAIL: demand-feedback gain {feedback:.2f}x < 1.3x",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
