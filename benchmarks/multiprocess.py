"""Cross-process co-location under oversubscription — the paper's headline
multi-process claim, on real OS processes.

Two CPU-hungry worker *processes* (numpy compute phases meeting at a
per-process barrier each iteration — the nested-BLAS shape of §5.2/§5.3)
share one node:

* **free** — the Linux baseline: both processes run ``gating=False`` with
  unmodified busy-wait barriers, each sized to the whole node. 2x
  oversubscription: spinners burn cores (and the interpreter) while the
  sibling process fights for the same CPUs.
* **usf** — broker-coordinated: one ``NodeBroker`` (in the benchmark
  driver, the designated process) apportions the node across the worker
  processes; each worker's ``BrokerClient`` lands its grant on elastic
  slot parking (``UsfRuntime.set_slot_target``) and its threads meet at a
  cooperative barrier. Total running threads == node slots, no spin.

Scenarios:

* ``spin_colocate``: equal work, free vs broker-coordinated. Target:
  the co-location makespan (max across processes) improves **≥ 1.5x**.
* ``elastic_handoff``: unequal work, broker-coordinated vs *static*
  half-node caps (the bl-eq analogue at process level). When the small
  process finishes, the broker reclaims its lease and regrants the node
  to the survivor mid-run — work conservation a static partition cannot
  express.
* ``demand_feedback``: the idle/saturated phase shift. Two processes
  alternate busy bursts in antiphase (a baton of events serializes the
  turns); both stay alive and registered throughout. Bursts are
  *latency-bound*: each phase is a small matmul plus a blocking wait
  (the IO/RPC serving shape — the wait pins its slot, so granted width
  IS the achievable in-flight concurrency, independent of host core
  count). With static wants (``report_backlog=False`` — the
  pre-demand-feedback broker) each burst runs at half the node while
  the idle sibling pins its grant; with live backlog feedback the idle
  worker's effective want decays to zero within a few damped heartbeats
  and the saturated worker bursts at (nearly) full node width. Target:
  demand-aware beats static-want **≥ 1.3x** on makespan (asserted in
  full runs; smoke proves the machinery, including that demand-driven
  regrants actually fired).
* ``real_model``: the auto-checkpoint story on REAL jitted compute.
  (a) revoke-to-park: a node-width fleet of greedy-decode streams (a
  smoke-size transformer behind ``jax.jit``, zero USF calls in the loop
  body, instrumented only by ``autockpt.wrap_jit``) is elastically
  shrunk to half width; the surplus slots must park within a few
  dispatch intervals (p99 asserted in full runs), where the same
  streams UNWRAPPED cannot park before a stream's end — the
  previously-unbounded case. (b) colocate: N real model-server
  processes under sustained decode traffic, free-running (spin
  barriers, 2x oversubscription) vs NodeBroker-coordinated; same
  ≥ 1.5x makespan target as ``spin_colocate``, plus phase-latency
  p50/p99. Both modes run the *identical instrumented step* — the
  checkpoint no-op contract keeps the baseline unmodified.

Run:  PYTHONPATH=src python -m benchmarks.multiprocess [--smoke]
Writes BENCH_multiprocess.json (smoke: BENCH_multiprocess.smoke.json via
``make check``; the ratios are asserted only in full mode — CI smoke just
proves the machinery end-to-end).
"""

from __future__ import annotations

import argparse
import multiprocessing as mp
import os
import sys
import time

from benchmarks.common import default_out, write_artifact

_CTX = mp.get_context("spawn")

N_PROCS = 2


def _node_slots() -> int:
    return max(2, min(os.cpu_count() or 2, 8))


def _colocate_worker(mode: str, broker_path, slots: int, threads: int,
                     phases: int, n: int, slot_cap, go, result_q,
                     name: str) -> None:
    """One worker process: ``threads`` compute/barrier tasks on its own
    runtime. ``mode``: free (unmanaged + spin barrier) | usf (gated +
    coop barrier, broker-coordinated when ``broker_path`` is set, or
    statically capped at ``slot_cap``)."""
    # our runtime provides the parallelism: a BLAS-internal thread pool
    # (spinning between calls) would add *uncoordinated* oversubscription
    # to every mode and drown the comparison in noise
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS",
                "MKL_NUM_THREADS"):
        os.environ.setdefault(var, "1")
    import numpy as np

    from repro.core.policies import SchedCoop
    from repro.core.sync import BusyWaitBarrier, CoopBarrier
    from repro.core.task import Job
    from repro.core.threads import UsfRuntime
    from repro.core.topology import Topology

    gating = mode == "usf"
    rt = UsfRuntime(Topology(slots, 1), SchedCoop(), gating=gating)
    client = None
    if gating and broker_path:
        from repro.ipc import BrokerClient

        client = BrokerClient(broker_path, name=name,
                              share=1.0).bind(rt).start()
        client.wait_grant(5.0)
    elif gating and slot_cap:
        rt.set_slot_target(slot_cap)  # static partition (no broker)
    bar = (CoopBarrier(rt, threads) if gating
           else BusyWaitBarrier(rt, threads, yield_every=None))
    job = Job(name)
    a = np.random.default_rng(0).standard_normal((n, n), dtype=np.float64)

    def body():
        x = a.copy()
        for _ in range(phases):
            x = x @ a                       # GIL-releasing compute burst
            x *= 1.0 / np.abs(x).max()
            bar.wait()                      # the per-phase team barrier

    go.wait()
    t0 = time.monotonic()
    tasks = [rt.create(body, job=job) for _ in range(threads)]
    for t in tasks:
        if not rt.join(t, timeout=600.0):
            result_q.put({"name": name, "error": "join timeout"})
            return
    makespan = time.monotonic() - t0
    granted = None if client is None else client.granted
    if client is not None:
        client.stop()  # deregister: survivors inherit this lease
    result_q.put({"name": name, "makespan": makespan,
                  "final_grant": granted})
    rt.shutdown(timeout=5.0)


def _run_colocation(mode: str, *, phases_per_proc, n: int,
                    coordinate: bool, slot_cap=None) -> dict:
    """Launch N_PROCS co-located workers, release them simultaneously,
    gather per-process makespans."""
    slots = _node_slots()
    broker = None
    path = None
    if coordinate:
        from repro.ipc import NodeBroker

        broker = NodeBroker(capacity=slots, heartbeat_timeout=2.0)
        path = broker.start()
    go = _CTX.Event()
    result_q = _CTX.Queue()
    procs = []
    for i, phases in enumerate(phases_per_proc):
        p = _CTX.Process(
            target=_colocate_worker,
            args=(mode, path, slots, slots, phases, n, slot_cap, go,
                  result_q, f"proc{i}"),
            daemon=True)
        p.start()
        procs.append(p)
    try:
        time.sleep(1.0)  # runtimes (and broker registrations) come up
        go.set()
        results = [result_q.get(timeout=600.0) for _ in procs]
    finally:
        for p in procs:
            p.join(30.0)
            if p.is_alive():
                p.terminate()
        if broker is not None:
            broker.stop()
    errs = [r for r in results if "error" in r]
    if errs:
        raise RuntimeError(f"worker failure: {errs}")
    by_name = {r["name"]: r for r in results}
    return {
        "mode": mode,
        "coordinated": coordinate,
        "node_slots": slots,
        "per_proc_makespan": {k: round(v["makespan"], 4)
                              for k, v in sorted(by_name.items())},
        "makespan": round(max(r["makespan"] for r in results), 4),
    }


def _phase_worker(broker_path, slots: int, threads: int, phases: int,
                  n: int, wait_s: float, batons, parity: int, go, result_q,
                  name: str, report_backlog: bool, hb: float) -> None:
    """One phase-shift worker: takes every other baton, bursts ``threads``
    latency-bound tasks (matmul + a blocking ``wait_s`` per phase — the
    blocking wait holds its slot, so burst time scales with
    ``threads / granted_width``). Between its turns the main thread
    blocks on a plain mp Event — the runtime is truly idle, so a
    demand-reporting heartbeat sees backlog 0 and the broker can drain
    this worker's lease to the busy sibling. ``report_backlog=False``
    replays the static-want (v1) broker contract as the A/B baseline."""
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS",
                "MKL_NUM_THREADS"):
        os.environ.setdefault(var, "1")
    import numpy as np

    from repro.core.policies import SchedCoop
    from repro.core.sync import CoopBarrier
    from repro.core.task import Job
    from repro.core.threads import UsfRuntime
    from repro.core.topology import Topology
    from repro.ipc import BrokerClient

    rt = UsfRuntime(Topology(slots, 1), SchedCoop())
    client = BrokerClient(broker_path, name=name, share=1.0,
                          heartbeat_interval=hb,
                          report_backlog=report_backlog).bind(rt).start()
    client.wait_grant(5.0)
    job = Job(name)
    a = np.random.default_rng(0).standard_normal((n, n), dtype=np.float64)
    go.wait()
    t0 = time.monotonic()
    for k in range(parity, len(batons) - 1, 2):
        batons[k].wait()                    # idle until it is our turn
        bar = CoopBarrier(rt, threads)

        def body():
            x = a.copy()
            for _ in range(phases):
                x = x @ a
                x *= 1.0 / np.abs(x).max()
                time.sleep(wait_s)          # blocking wait: pins the slot
                bar.wait()

        tasks = [rt.create(body, job=job) for _ in range(threads)]
        for t in tasks:
            if not rt.join(t, timeout=600.0):
                result_q.put({"name": name, "error": "join timeout"})
                return
        batons[k + 1].set()                 # sibling's turn
    makespan = time.monotonic() - t0
    result_q.put({"name": name, "makespan": makespan,
                  "final_grant": client.granted})
    client.stop()
    rt.shutdown(timeout=5.0)


def _run_phase_shift(*, report_backlog: bool, bursts_per_proc: int,
                     phases: int, n: int, wait_s: float) -> dict:
    """Antiphase busy/idle workers under one broker. The baton chain
    serializes the bursts, so the whole run is a sequence of
    (one saturated, one idle) intervals — the exact shape where live
    demand pays and static wants strand half the node."""
    slots = _node_slots()
    from repro.ipc import NodeBroker

    # fast demand knobs: the benchmark measures steady-burst throughput,
    # not damping latency, so keep the regrant reaction well under a
    # burst length (the same knobs are used for the static baseline,
    # where they are inert)
    broker = NodeBroker(capacity=slots, heartbeat_timeout=2.0,
                        demand_beats=2, min_regrant_interval=0.02)
    path = broker.start()
    n_bursts = bursts_per_proc * N_PROCS
    batons = [_CTX.Event() for _ in range(n_bursts + 1)]
    go = _CTX.Event()
    result_q = _CTX.Queue()
    procs = []
    for i in range(N_PROCS):
        p = _CTX.Process(
            target=_phase_worker,
            args=(path, slots, slots, phases, n, wait_s, batons, i, go,
                  result_q, f"proc{i}", report_backlog, 0.02),
            daemon=True)
        p.start()
        procs.append(p)
    try:
        time.sleep(1.0)  # runtimes and registrations come up
        go.set()
        batons[0].set()
        results = [result_q.get(timeout=600.0) for _ in procs]
        counters = {k: v for k, v in broker.snapshot().items()
                    if k in ("regrants", "demand_regrants", "grants_pushed",
                             "grants_suppressed")}
    finally:
        for p in procs:
            p.join(30.0)
            if p.is_alive():
                p.terminate()
        broker.stop()
    errs = [r for r in results if "error" in r]
    if errs:
        raise RuntimeError(f"worker failure: {errs}")
    by_name = {r["name"]: r for r in results}
    return {
        "mode": "demand" if report_backlog else "static_want",
        "node_slots": slots,
        "bursts_per_proc": bursts_per_proc,
        "per_proc_makespan": {k: round(v["makespan"], 4)
                              for k, v in sorted(by_name.items())},
        "makespan": round(max(r["makespan"] for r in results), 4),
        "broker_counters": counters,
    }


# --------------------------------------------------------------------------- #
# real_model: auto-checkpointed JAX decode under revocation + co-location
# --------------------------------------------------------------------------- #
def _pin_host_parallelism() -> None:
    """Single-threaded BLAS *and* XLA CPU backend (must run before the
    first ``import jax``): the USF runtime's streams are the only source
    of parallelism, so a slot grant maps 1:1 onto a busy core and the
    free-running baseline oversubscribes exactly N_PROCS x."""
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS",
                "MKL_NUM_THREADS"):
        os.environ.setdefault(var, "1")
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_cpu_multi_thread_eigen=false"
          " intra_op_parallelism_threads=1"
    ).strip()


def _real_model_setup(slots: int, *, gating: bool = True):
    """Shared worker prologue: smoke-size real model + ONE jitted decode
    step (compiled once per process, shared by every stream)."""
    import jax

    from repro.configs.base import get_smoke
    from repro.core.policies import SchedCoop
    from repro.core.threads import UsfRuntime
    from repro.core.topology import Topology
    from repro.models.base import init_tree
    from repro.models.registry import build_model
    from repro.runtime.sharding import Sharder
    from repro.train.step import make_serve_step

    cfg = get_smoke("smollm_360m")
    model = build_model(cfg)
    sharder = Sharder(None)
    params = init_tree(jax.random.PRNGKey(0), model.param_specs(),
                       cfg.param_dtype)
    step = jax.jit(make_serve_step(model, sharder))
    rt = UsfRuntime(Topology(slots, 1), SchedCoop(), gating=gating)
    return cfg, params, step, rt


def _real_revoke_worker(slots: int, revokes: int, ctrl_steps: int,
                        result_q) -> None:
    """Revoke-to-park latency against REAL jitted decode streams.

    ``slots`` streams run uninstrumented greedy-decode loops — each
    iteration is one jitted dispatch + ``block_until_ready`` with no
    USF call anywhere in the body — behind ``autockpt.wrap_jit``. Each
    revoke cycle shrinks the runtime to half width and times
    ``set_slot_target`` -> every surplus slot parked; the bound under
    test is a few *dispatch intervals*, the paper's blocking-point
    granularity argument applied to opaque compute. A control round runs
    the same streams UNWRAPPED: the revoke then lands only at a stream's
    end — the previously-unbounded case (docs/PREEMPTION.md tier 3)."""
    _pin_host_parallelism()
    import threading

    import jax
    import jax.numpy as jnp

    from repro.core.autockpt import wrap_jit
    from repro.core.task import Job
    from repro.launch.inputs import make_decode_inputs

    try:
        cfg, params, step, rt = _real_model_setup(slots)
        wstep = wrap_jit(step, runtime=rt)
        max_len = 32
        target = max(1, slots // 2)
        surplus = slots - target
        stop = threading.Event()
        measuring = threading.Event()  # full-width steady-state window only
        counts = [0] * slots
        intervals: list = []  # pre-revoke steady-state dispatch intervals

        def make_body(i, fn, n_steps=None):
            def body():
                cache, tok, p = make_decode_inputs(
                    cfg, 1, max_len, jax.random.PRNGKey(i))
                last = time.monotonic()
                k = 0
                while not stop.is_set() and (n_steps is None or k < n_steps):
                    logits, cache = fn(params, cache, tok, p)
                    logits.block_until_ready()  # the device wait
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    p = (p + 1) % (max_len - 1)
                    now = time.monotonic()
                    if measuring.is_set():
                        intervals.append(now - last)
                    last = now
                    counts[i] += 1
                    k += 1

            return body

        job = Job("real-decode")
        tasks = [rt.create(make_body(i, wstep), job=job)
                 for i in range(slots)]
        deadline = time.monotonic() + 300.0
        while min(counts) < 3 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert min(counts) >= 3, "streams never warmed up (compile stuck?)"
        measuring.set()
        time.sleep(0.25)  # steady-state interval sample at full width
        measuring.clear()

        park_lats = []
        steps_during = []
        for _ in range(revokes):
            before = sum(counts)
            t0 = time.monotonic()
            rt.set_slot_target(target)
            while len(rt.sched.parked_slot_ids()) < surplus \
                    and time.monotonic() < deadline:
                time.sleep(0.0002)
            lat = time.monotonic() - t0
            assert len(rt.sched.parked_slot_ids()) >= surplus, \
                "revoke never parked the surplus slots"
            park_lats.append(lat)
            steps_during.append(sum(counts) - before)
            rt.set_slot_target(None)   # regrant: parked slots resume
            time.sleep(0.05)
        stop.set()
        for t in tasks:
            assert rt.join(t, timeout=60.0)

        # control: identical streams, UNWRAPPED — no scheduling point
        # until a stream finishes, so the revoke waits for a task END
        stop.clear()
        ctrl_counts_before = sum(counts)
        ctrl = [rt.create(make_body(i, step, n_steps=ctrl_steps), job=job)
                for i in range(slots)]
        while sum(counts) - ctrl_counts_before < slots \
                and time.monotonic() < deadline:
            time.sleep(0.002)  # every stream mid-flight
        t0 = time.monotonic()
        rt.set_slot_target(target)
        while not rt.sched.parked_slot_ids() \
                and time.monotonic() < deadline:
            time.sleep(0.0005)
        control_lat = time.monotonic() - t0
        control_parked = bool(rt.sched.parked_slot_ids())
        rt.set_slot_target(None)
        for t in ctrl:
            assert rt.join(t, timeout=120.0)
        rt.shutdown(timeout=10.0)

        xs = sorted(park_lats)

        def pct(p: float) -> float:
            return xs[min(len(xs) - 1, int(round(p * (len(xs) - 1))))]

        step_mean = (sum(intervals) / len(intervals)) if intervals else 0.0
        result_q.put({
            "streams": slots, "slot_target": target,
            "revoke_cycles": len(xs),
            "park_p50_s": pct(0.50), "park_p99_s": pct(0.99),
            "park_max_s": xs[-1],
            "step_mean_s": step_mean,
            "steps_during_park_mean": sum(steps_during) / len(steps_during),
            "control_park_s": control_lat,
            "control_parked": control_parked,
            "control_steps": ctrl_steps,
        })
    except BaseException as e:  # noqa: BLE001 — surface to the driver
        result_q.put({"error": f"{type(e).__name__}: {e}"})


def _real_colocate_worker(mode: str, broker_path, slots: int, phases: int,
                          go, result_q, name: str) -> None:
    """One model-server process for the co-location A/B: ``slots``
    auto-wrapped decode streams meeting at a per-phase barrier.

    The step wrapper is UNCONDITIONAL in both modes — the satellite
    no-op contract means the free-running baseline executes the exact
    same instrumented code (checkpoints vanish without a gated task), so
    the A/B isolates coordination, not instrumentation."""
    _pin_host_parallelism()
    import jax
    import jax.numpy as jnp

    from repro.core.autockpt import wrap_jit
    from repro.core.sync import BusyWaitBarrier, CoopBarrier
    from repro.core.task import Job
    from repro.launch.inputs import make_decode_inputs

    try:
        gating = mode == "usf"
        cfg, params, step, rt = _real_model_setup(slots, gating=gating)
        wstep = wrap_jit(step, runtime=rt)
        client = None
        if gating and broker_path:
            from repro.ipc import BrokerClient

            client = BrokerClient(broker_path, name=name,
                                  share=1.0).bind(rt).start()
            client.wait_grant(5.0)
        bar = (CoopBarrier(rt, slots) if gating
               else BusyWaitBarrier(rt, slots, yield_every=None))
        max_len = 32
        phase_lats: list = []  # stream 0's inter-barrier times

        def make_body(i):
            def body():
                cache, tok, p = make_decode_inputs(
                    cfg, 1, max_len, jax.random.PRNGKey(i))
                last = time.monotonic()
                for _ in range(phases):
                    logits, cache = wstep(params, cache, tok, p)
                    logits.block_until_ready()
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    p = (p + 1) % (max_len - 1)
                    bar.wait()
                    if i == 0:
                        now = time.monotonic()
                        phase_lats.append(now - last)
                        last = now

            return body

        # compile before the gun so both modes time steady-state decode
        warm_cache, warm_tok, warm_p = make_decode_inputs(
            cfg, 1, max_len, jax.random.PRNGKey(99))
        step(params, warm_cache, warm_tok, warm_p)[0].block_until_ready()

        go.wait()
        t0 = time.monotonic()
        job = Job(name)
        tasks = [rt.create(make_body(i), job=job) for i in range(slots)]
        for t in tasks:
            if not rt.join(t, timeout=600.0):
                result_q.put({"name": name, "error": "join timeout"})
                return
        makespan = time.monotonic() - t0
        if client is not None:
            client.stop()
        # drop the first phase (it absorbs dispatch-path warmup jitter)
        result_q.put({"name": name, "makespan": makespan,
                      "phase_lats": phase_lats[1:]})
        rt.shutdown(timeout=10.0)
    except BaseException as e:  # noqa: BLE001 — surface to the driver
        result_q.put({"name": name, "error": f"{type(e).__name__}: {e}"})


def _run_real_colocation(mode: str, *, phases: int) -> dict:
    """N_PROCS real-model servers co-located on the node, free vs
    broker-coordinated — the spin_colocate A/B with jitted decode."""
    from benchmarks.common import summarize_latencies

    slots = _node_slots()
    broker = None
    path = None
    if mode == "usf":
        from repro.ipc import NodeBroker

        broker = NodeBroker(capacity=slots, heartbeat_timeout=2.0)
        path = broker.start()
    go = _CTX.Event()
    result_q = _CTX.Queue()
    procs = []
    for i in range(N_PROCS):
        p = _CTX.Process(
            target=_real_colocate_worker,
            args=(mode, path, slots, phases, go, result_q, f"proc{i}"),
            daemon=True)
        p.start()
        procs.append(p)
    try:
        time.sleep(1.0)  # runtimes, model compile, broker registrations
        go.set()
        results = [result_q.get(timeout=900.0) for _ in procs]
    finally:
        for p in procs:
            p.join(30.0)
            if p.is_alive():
                p.terminate()
        if broker is not None:
            broker.stop()
    errs = [r for r in results if "error" in r]
    if errs:
        raise RuntimeError(f"real-model worker failure: {errs}")
    by_name = {r["name"]: r for r in results}
    lats = [x for r in results for x in r["phase_lats"]]
    out = {
        "mode": mode,
        "node_slots": slots,
        "phases": phases,
        "per_proc_makespan": {k: round(v["makespan"], 4)
                              for k, v in sorted(by_name.items())},
        "makespan": round(max(r["makespan"] for r in results), 4),
    }
    out.update(summarize_latencies(lats, prefix="phase_", round_to=6))
    return out


def _run_real_model(*, smoke: bool) -> dict:
    """The real_model scenario: (a) revoke-to-park latency on live jitted
    decode streams, (b) coordinated-vs-free co-location makespan/p99."""
    slots = _node_slots()
    revokes = 5 if smoke else 20
    ctrl_steps = 60 if smoke else 200
    result_q = _CTX.Queue()
    p = _CTX.Process(target=_real_revoke_worker,
                     args=(slots, revokes, ctrl_steps, result_q),
                     daemon=True)
    p.start()
    try:
        revoke = result_q.get(timeout=900.0)
    finally:
        p.join(60.0)
        if p.is_alive():
            p.terminate()
    if "error" in revoke:
        raise RuntimeError(f"real-model revoke worker: {revoke['error']}")

    phases = 40 if smoke else 300
    free = _run_real_colocation("free", phases=phases)
    usf = _run_real_colocation("usf", phases=phases)
    speedup = free["makespan"] / usf["makespan"]
    return {
        "revoke_to_park": revoke,
        "colocate": {
            "free": free,
            "usf": usf,
            "speedup": round(speedup, 3),
            "target": 1.5,
            "meets_target": speedup >= 1.5,
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_multiprocess.json, "
                         "or BENCH_multiprocess.smoke.json with --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny work: proves the machinery, skips the "
                         "ratio assertion (CI hosts are noisy)")
    args = ap.parse_args(argv)
    phases = 12 if args.smoke else 80
    n = 96 if args.smoke else 128

    # -- scenario 1: equal co-location, free vs broker-coordinated ------- #
    free = _run_colocation("free", phases_per_proc=[phases] * N_PROCS,
                           n=n, coordinate=False)
    usf = _run_colocation("usf", phases_per_proc=[phases] * N_PROCS,
                          n=n, coordinate=True)
    speedup = free["makespan"] / usf["makespan"]
    print(f"spin_colocate ({N_PROCS} procs x {free['node_slots']} threads, "
          f"{phases} phases):")
    print(f"  free-running (oversubscribed busy-wait): "
          f"{free['makespan']:.3f}s  {free['per_proc_makespan']}")
    print(f"  broker-coordinated:                      "
          f"{usf['makespan']:.3f}s  {usf['per_proc_makespan']}")
    print(f"  speedup: {speedup:.2f}x (target >= 1.5x)")

    # -- scenario 2: unequal work — elastic handoff vs static split ------ #
    slots = _node_slots()
    # the small process exits early; the survivor's long tail is where the
    # reclaimed lease pays (the tail must dominate its pre-handoff phase,
    # and each phase must be coarse enough that extra width beats the
    # cross-thread barrier cost — hence the bigger matmul)
    uneven = [max(2, phases // 16), phases]
    n_handoff = 192 if args.smoke else 256
    static = _run_colocation("usf", phases_per_proc=uneven, n=n_handoff,
                             coordinate=False, slot_cap=max(1, slots // 2))
    elastic = _run_colocation("usf", phases_per_proc=uneven, n=n_handoff,
                              coordinate=True)
    handoff = static["makespan"] / elastic["makespan"]
    print(f"elastic_handoff (uneven work {uneven}):")
    print(f"  static half-node caps: {static['makespan']:.3f}s  "
          f"{static['per_proc_makespan']}")
    print(f"  broker (lease reclaimed at exit): {elastic['makespan']:.3f}s  "
          f"{elastic['per_proc_makespan']}")
    print(f"  work-conservation gain: {handoff:.2f}x")

    # -- scenario 3: idle/saturated phase shift — live demand vs static -- #
    # each burst must dwarf the demand-damping latency (a few heartbeats
    # + min-regrant interval, ~0.1s with the bench knobs) or the regrant
    # reaction time eats the concurrency gain — hence the coarse full-run
    # burst (~1s at the static half-node width)
    bursts = 1 if args.smoke else 2
    ps_phases = 10 if args.smoke else 60
    ps_wait = 0.005 if args.smoke else 0.008
    static_ps = _run_phase_shift(report_backlog=False,
                                 bursts_per_proc=bursts,
                                 phases=ps_phases, n=n, wait_s=ps_wait)
    demand_ps = _run_phase_shift(report_backlog=True,
                                 bursts_per_proc=bursts,
                                 phases=ps_phases, n=n, wait_s=ps_wait)
    feedback = static_ps["makespan"] / demand_ps["makespan"]
    print(f"demand_feedback (antiphase bursts, {bursts} per proc, "
          f"{ps_phases} phases):")
    print(f"  static wants (idle sibling pins half): "
          f"{static_ps['makespan']:.3f}s  {static_ps['per_proc_makespan']}")
    print(f"  live backlog feedback:                 "
          f"{demand_ps['makespan']:.3f}s  {demand_ps['per_proc_makespan']}  "
          f"counters={demand_ps['broker_counters']}")
    print(f"  demand-feedback gain: {feedback:.2f}x (target >= 1.3x)")
    # machinery check, valid even in smoke: the demand run must have
    # actually moved leases on backlog feedback, and the static run must
    # not have (its clients beat without the field)
    if demand_ps["broker_counters"]["demand_regrants"] < 1:
        print("FAIL: demand run triggered no demand-driven regrants",
              file=sys.stderr)
        return 1
    if static_ps["broker_counters"]["demand_regrants"] != 0:
        print("FAIL: static-want run saw demand-driven regrants",
              file=sys.stderr)
        return 1

    # -- scenario 4: real-model decode — bounded revocation + co-location #
    real = _run_real_model(smoke=args.smoke)
    rev = real["revoke_to_park"]
    col = real["colocate"]
    print(f"real_model (jitted decode, {rev['streams']} streams, "
          f"{rev['revoke_cycles']} revoke cycles):")
    print(f"  dispatch interval (steady state): "
          f"{rev['step_mean_s'] * 1e3:.2f}ms")
    print(f"  revoke-to-park: p50 {rev['park_p50_s'] * 1e3:.2f}ms "
          f"p99 {rev['park_p99_s'] * 1e3:.2f}ms "
          f"(~{rev['steps_during_park_mean']:.1f} node-wide dispatches)")
    print(f"  unwrapped control: parked after {rev['control_park_s']:.3f}s "
          f"(only at a stream's END, {rev['control_steps']} steps)")
    print(f"  colocate free: {col['free']['makespan']:.3f}s "
          f"(phase p99 {col['free']['phase_p99'] * 1e3:.1f}ms)  "
          f"usf: {col['usf']['makespan']:.3f}s "
          f"(phase p99 {col['usf']['phase_p99'] * 1e3:.1f}ms)")
    print(f"  speedup: {col['speedup']:.2f}x (target >= 1.5x)")
    # machinery checks, valid in smoke too: every revoke parked, and the
    # wrapped streams parked in bounded time while the unwrapped control
    # could not park before a stream boundary
    if not rev["control_parked"]:
        print("FAIL: real_model control round never parked", file=sys.stderr)
        return 1
    if rev["park_p99_s"] >= rev["control_park_s"]:
        print("FAIL: wrapped revoke-to-park not faster than the "
              "stream-boundary control", file=sys.stderr)
        return 1

    payload = {
        "bench": "multiprocess",
        "smoke": args.smoke,
        "n_procs": N_PROCS,
        "node_slots": slots,
        "phases": phases,
        "matmul_n": n,
        "scenarios": {
            "spin_colocate": {
                "free": free,
                "usf": usf,
                "speedup": round(speedup, 3),
                "target": 1.5,
                "meets_target": speedup >= 1.5,
            },
            "elastic_handoff": {
                "static": static,
                "elastic": elastic,
                "gain": round(handoff, 3),
            },
            "demand_feedback": {
                "static": static_ps,
                "demand": demand_ps,
                "gain": round(feedback, 3),
                "target": 1.3,
                "meets_target": feedback >= 1.3,
            },
            "real_model": real,
        },
    }
    write_artifact(default_out("multiprocess", args.smoke, args.out), payload)
    if not args.smoke and speedup < 1.5:
        print(f"FAIL: broker-coordinated speedup {speedup:.2f}x < 1.5x",
              file=sys.stderr)
        return 1
    if not args.smoke and feedback < 1.3:
        print(f"FAIL: demand-feedback gain {feedback:.2f}x < 1.3x",
              file=sys.stderr)
        return 1
    if not args.smoke:
        # bounded-latency claim: surplus slots park within a few dispatch
        # intervals (generous floor absorbs scheduler/poll granularity)
        bound = max(4.0 * rev["step_mean_s"], 0.025)
        if rev["park_p99_s"] > bound:
            print(f"FAIL: revoke-to-park p99 {rev['park_p99_s'] * 1e3:.1f}ms "
                  f"> bound {bound * 1e3:.1f}ms "
                  f"(~4 dispatch intervals)", file=sys.stderr)
            return 1
        if col["speedup"] < 1.5:
            print(f"FAIL: real-model coordinated speedup "
                  f"{col['speedup']:.2f}x < 1.5x", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
