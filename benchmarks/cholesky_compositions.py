"""Paper Table 2: tiled Cholesky across runtime compositions.

Right-looking tiled Cholesky task DAG (potrf / trsm / syrk / gemm) run by
an outer worker pool; each kernel call opens an inner BLAS team. The five
compositions of the paper map to behavioral knobs:

  out/inn/blas          knob
  gnu+llvm+openblas     inner teams reuse threads (cached), spin barriers
  tbb+llvm+openblas     as above (outer pool behaviour identical here)
  tbb+gnu+blis          as above, slightly different sync count
  tbb+pth+blis          inner threads CREATED/DESTROYED per call (pth!)
  gnu+pth+blis          as above

Oversubscription degrees (on 56 cores, like the paper's single socket):
  Mild   8x8    (1.14 threads/core)
  Medium 14x14  (3.5)
  High   28x28  (14)

Claims validated (paper): SCHED_COOP speedup grows with oversubscription;
pth rows (create/destroy per call) benefit most — the transparent thread
cache (§4.3.1) contributes ~4x on top of base SCHED_COOP.
"""

from __future__ import annotations

import sys

from benchmarks.common import (
    CORE_GFLOPS,
    STACKS,
    StackConfig,
    inner_region,
    make_executor,
    outer_runtime,
    warmup_scale_for,
)
from repro.core import simtask as st
from repro.core.task import Job, Task

N = 8192
TS = 1024
CORES = 56  # single socket, like Table 2

DEGREES = {"mild": (8, 8), "medium": (14, 14), "high": (28, 28)}

COMPOSITIONS = {
    "gnu+llvm+opb": dict(thread_cache=True, n_syncs=4),
    "tbb+llvm+opb": dict(thread_cache=True, n_syncs=3),
    "tbb+gnu+blis": dict(thread_cache=True, n_syncs=5),
    "tbb+pth+blis": dict(thread_cache=False, n_syncs=5),
    "gnu+pth+blis": dict(thread_cache=False, n_syncs=4),
}


def _dag_items(nb: int) -> list[tuple]:
    """Topologically-ordered task list with flop weights (fan-out via the
    outer pool models the runtime's ready-queue; true dependencies are
    approximated by wave ordering, adequate for scheduling behaviour)."""
    items = []
    for k in range(nb):
        items.append(("potrf", 1.0 / 3.0))
        for i in range(k + 1, nb):
            items.append(("trsm", 1.0))
        for i in range(k + 1, nb):
            for j in range(k + 1, i + 1):
                items.append(("syrk" if i == j else "gemm",
                              1.0 if i == j else 2.0))
    return items


def run_composition(comp: str, degree: str, stack_name: str) -> dict:
    knobs = COMPOSITIONS[comp]
    base = STACKS[stack_name]
    stack = StackConfig(
        name=f"{stack_name}:{comp}",
        policy=base.policy,
        yield_every=base.yield_every,
        coop_barriers=base.coop_barriers,
        thread_cache=knobs["thread_cache"] or (
            base.policy == "coop"  # USF caches threads transparently §4.3.1
        ),
        quantum=base.quantum,
    )
    outer_n, inner_n = DEGREES[degree]
    sim = make_executor(stack, cores=CORES)
    job = Job(f"chol-{comp}")
    unit = TS * TS * TS  # gemm-block flop unit (x2 for gemm weight)
    ws = 3.0 * TS * TS * 8

    def body(item):
        kind, weight = item
        flops = unit * weight
        return inner_region(sim, job, flops / (CORE_GFLOPS * 1e9), inner_n,
                            stack, n_syncs=knobs["n_syncs"], flops=flops,
                            ws_bytes=ws)

    items = _dag_items(N // TS)
    outer_runtime(sim, job, items, outer_n, stack, body)
    stats = sim.run()
    total_flops = sum(unit * w for _, w in items)
    return {
        "comp": comp,
        "degree": degree,
        "stack": stack_name,
        "mops": total_flops / stats.makespan / 1e6,
        "makespan": stats.makespan,
        "spin_frac": stats.total_spin_time
        / max(stats.total_run_time + stats.total_spin_time, 1e-12),
    }


def run_table(*, compositions=None, degrees=None, verbose=True) -> list[dict]:
    rows = []
    for comp in (compositions or COMPOSITIONS):
        for degree in (degrees or DEGREES):
            b = run_composition(comp, degree, "baseline")
            c = run_composition(comp, degree, "sched_coop")
            row = {
                "comp": comp,
                "degree": degree,
                "baseline_mops": b["mops"],
                "coop_mops": c["mops"],
                "speedup": c["mops"] / b["mops"],
            }
            rows.append(row)
            if verbose:
                print(f"{comp},{degree},{b['mops']:.0f},{c['mops']:.0f},"
                      f"{row['speedup']:.2f}", flush=True)
    return rows


def main() -> int:
    print("comp,degree,baseline_mops,coop_mops,speedup")
    rows = run_table()
    by_comp: dict[str, dict[str, float]] = {}
    for r in rows:
        by_comp.setdefault(r["comp"], {})[r["degree"]] = r["speedup"]
    pth = [c for c in by_comp if "pth" in c]
    cached = [c for c in by_comp if "pth" not in c]
    hi_pth = max(by_comp[c]["high"] for c in pth)
    hi_cached = max(by_comp[c]["high"] for c in cached)
    print(f"# high-oversubscription speedups: pth-max={hi_pth:.2f}x "
          f"cached-max={hi_cached:.2f}x")
    if hi_pth > hi_cached:
        print("# CLAIM OK: pth compositions (create/destroy per call) gain "
              "most from the transparent thread cache")
    return 0


if __name__ == "__main__":
    sys.exit(main())
