"""Paper Fig. 3: nested-runtime matmul heatmap.

Outer runtime (OmpSs-2-like worker pool) x inner runtime (BLIS/OpenMP
teams with busy-wait barriers), swept over (inner threads x task size) for
four software stacks:

  original    Linux scheduler, unmodified busy-wait barriers
  baseline    Linux scheduler + sched_yield in barriers (§5.2)
  sched_coop  USF/SCHED_COOP, seamless (same stack as baseline)
  manual      SCHED_COOP + ad-hoc nOS-V integration (blocking barriers)

Reduced from the paper's 32768^2/60s sweep to an 8192^2 single pass so the
whole grid runs on this 1-core container; the claims validated are the
RELATIVE ones (see tests/test_benchmarks.py):
  * manual >= sched_coop >= baseline >> original in the oversubscribed band
  * best sched_coop config (nested) beats best baseline config.

Output CSV: stack,n_threads,task_size,gflops,makespan,spin_frac
"""

from __future__ import annotations

import sys

from benchmarks.common import (
    CORE_GFLOPS,
    CORES,
    STACKS,
    StackConfig,
    inner_region,
    make_executor,
    outer_runtime,
)
from repro.core.task import Job

MATRIX = 8192
THREADS = [1, 4, 14, 28, 56]
TASK_SIZES = [512, 1024, 2048, 4096, 8192]


def run_cell(stack: StackConfig, n_threads: int, task_size: int,
             *, cores: int = CORES, matrix: int = MATRIX) -> dict:
    sim = make_executor(stack, cores=cores)
    job = Job("matmul")
    nb = matrix // task_size
    flops_per_block = 2.0 * task_size * task_size * matrix
    work_s = flops_per_block / (CORE_GFLOPS * 1e9)
    items = [(i, j) for i in range(nb) for j in range(nb)]
    n_workers = min(cores, len(items))

    ws_bytes = 3.0 * task_size * task_size * 8  # A,B,C block working set

    def body(item):
        return inner_region(sim, job, work_s, n_threads, stack,
                            n_syncs=4, flops=flops_per_block,
                            ws_bytes=ws_bytes)

    outer_runtime(sim, job, items, n_workers, stack, body)
    stats = sim.run()
    total_flops = 2.0 * matrix ** 3
    return {
        "stack": stack.name,
        "n_threads": n_threads,
        "task_size": task_size,
        "gflops": total_flops / stats.makespan / 1e9,
        "makespan": stats.makespan,
        "spin_frac": stats.total_spin_time
        / max(stats.total_run_time + stats.total_spin_time, 1e-12),
        "preemptions": stats.preemptions,
        "migrations": stats.migrations,
    }


def run_grid(stacks=None, threads=None, sizes=None, *, verbose=True):
    rows = []
    for sname in (stacks or STACKS):
        stack = STACKS[sname]
        for nt in (threads or THREADS):
            for ts in (sizes or TASK_SIZES):
                r = run_cell(stack, nt, ts)
                rows.append(r)
                if verbose:
                    print(f"{r['stack']},{nt},{ts},{r['gflops']:.1f},"
                          f"{r['makespan']:.3f},{r['spin_frac']:.3f}",
                          flush=True)
    return rows


def main() -> int:
    print("stack,n_threads,task_size,gflops,makespan,spin_frac")
    rows = run_grid()
    # headline claim: best nested coop vs best baseline
    best = {}
    for r in rows:
        best.setdefault(r["stack"], r)
        if r["gflops"] > best[r["stack"]]["gflops"]:
            best[r["stack"]] = r
    for k, r in best.items():
        print(f"# best[{k}]: {r['gflops']:.1f} GF/s at "
              f"(threads={r['n_threads']}, ts={r['task_size']})")
    if best["sched_coop"]["gflops"] > best["baseline"]["gflops"]:
        print("# CLAIM OK: best SCHED_COOP beats best baseline "
              f"({best['sched_coop']['gflops'] / best['baseline']['gflops']:.3f}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
