"""Paper Fig. 5: co-executed MD ensembles (LAMMPS + DeePMD-kit).

Two ensembles of 56 MPI ranks x 2 OpenMP threads each; per-step force
compute is imbalanced across ranks (interleaved dense/sparse domain
regions, 90%/10% of atoms), followed by an MPI neighbor sync (busy-wait in
MPICH, yield-adapted per §5.2). Per-ensemble sequential init must be paid
once per ensemble.

Scenarios (as in the paper):
  exclusive           ensembles run one after the other, 112 threads each
  colocation_node     28 ranks each, pinned to disjoint halves (no OS mix)
  colocation_socket   same, but each ensemble spread across both sockets
  coexecution_node    both full-size ensembles share the node (Linux)
  coexecution_socket  same, 2x cross-socket traffic
  schedcoop_node      both full-size ensembles under SCHED_COOP
  schedcoop_socket    same, 2x cross-socket traffic

Claims validated: exclusive has the best per-ensemble rate but the worst
aggregate (serial init + imbalance gaps unfilled); SCHED_COOP variants
reach the highest aggregate Katom-step/s (paper: ~4% over coexecution).
"""

from __future__ import annotations

import sys

from benchmarks.common import STACKS, StackConfig, make_executor
from repro.core import simtask as st
from repro.core.simtask import SimCosts
from repro.core.task import Job, Task

ATOMS = 100_000
STEPS = 40            # reduced from 100 for the 1-core container
RANKS = 56
OMP = 2
BASE_STEP = 0.020     # balanced per-rank step seconds at 2 threads
INIT_S = 3.0          # per-ensemble sequential initialization
REGIONS = 14


def _rank_factor(rank: int, n_ranks: int) -> float:
    """Dense/sparse interleaving along x: region r gets 90% or 10% of its
    pair's atoms -> per-rank work factor 1.8 / 0.2."""
    region = rank * REGIONS // n_ranks
    return 1.8 if region % 2 == 0 else 0.2


def _ensemble(sim, name: str, n_ranks: int, stack: StackConfig,
              *, steps: int = STEPS, at: float = 0.0,
              done_list: list = None, socket_sync: float = 0.0):
    job = Job(name)
    sync = st.SimSpinBarrier(n_ranks * OMP, spin_slice=200e-6,
                             yield_every=stack.yield_every)
    team_bars = [st.SimSpinBarrier(OMP, spin_slice=100e-6,
                                   yield_every=stack.yield_every)
                 for _ in range(n_ranks)]

    def init_task():
        yield st.compute(INIT_S)  # sequential init (the bandwidth valleys)
        for r in range(n_ranks):
            f = _rank_factor(r, n_ranks)
            for t in range(OMP):
                child = Task(job, body=thread_body(r, t, f),
                             name=f"{name}-r{r}t{t}")
                yield st.spawn(child)

    # per-rank work scales inversely with rank count (same physical domain)
    work_scale = RANKS / n_ranks

    def thread_body(rank: int, thr: int, factor: float):
        def gen():
            for _ in range(steps):
                yield st.compute(BASE_STEP * factor * work_scale)
                yield st.spin_barrier_wait(team_bars[rank])   # OMP join
                yield st.spin_barrier_wait(sync)              # MPI exchange
                if socket_sync:
                    yield st.compute(socket_sync)  # cross-socket exchange
            if done_list is not None:
                done_list.append(sim.now())

        return gen

    sim.spawn(job, init_task, name=f"{name}-init", at=at)
    return job


def run_scenario(scenario: str) -> dict:
    socket_variant = scenario.endswith("_socket")
    costs = SimCosts()
    if socket_variant:
        costs.migration_cross *= 2
        costs.cache_refill *= 2

    def mk(stack_name, cores):
        stack = STACKS[stack_name]
        sim = make_executor(stack, cores=cores, max_time=100_000.0)
        sim.costs = costs
        return sim, stack

    ss = 200e-6 if socket_variant else 0.0
    if scenario == "exclusive":
        total = 0.0
        for e in ("ens0", "ens1"):
            sim, stack = mk("baseline", 112)
            done = []
            _ensemble(sim, e, RANKS, stack, done_list=done)
            sim.run()
            total += max(done)
        makespan = total
    elif scenario.startswith("colocation"):
        # halved ensembles pinned to disjoint 56-core sets: two sims
        makespan = 0.0
        for e in ("ens0", "ens1"):
            sim, stack = mk("baseline", 56)
            done = []
            _ensemble(sim, e, RANKS // 2, stack, done_list=done,
                      socket_sync=ss)
            sim.run()
            makespan = max(makespan, max(done))
    elif scenario.startswith("coexecution") or scenario.startswith("schedcoop"):
        stack_name = ("sched_coop" if scenario.startswith("schedcoop")
                      else "baseline")
        sim, stack = mk(stack_name, 112)
        done = []
        _ensemble(sim, "ens0", RANKS, stack, done_list=done, socket_sync=ss)
        _ensemble(sim, "ens1", RANKS, stack, done_list=done, socket_sync=ss)
        sim.run()
        makespan = max(done)
    else:
        raise ValueError(scenario)

    # both scenarios run 2 ensembles x STEPS steps x ATOMS atoms total,
    # except colocation (half ranks -> same steps, same atoms)
    total_atom_steps = 2 * ATOMS * STEPS
    return {
        "scenario": scenario,
        "makespan": makespan,
        "katom_steps_per_s": total_atom_steps / makespan / 1e3,
    }


SCENARIOS = [
    "exclusive",
    "colocation_node",
    "colocation_socket",
    "coexecution_node",
    "coexecution_socket",
    "schedcoop_node",
    "schedcoop_socket",
]


def main() -> int:
    print("scenario,makespan,katom_steps_per_s")
    rows = []
    for sc in SCENARIOS:
        r = run_scenario(sc)
        rows.append(r)
        print(f"{sc},{r['makespan']:.2f},{r['katom_steps_per_s']:.1f}",
              flush=True)
    by = {r["scenario"]: r["katom_steps_per_s"] for r in rows}
    best_coop = max(by["schedcoop_node"], by["schedcoop_socket"])
    best_coex = max(by["coexecution_node"], by["coexecution_socket"])
    print(f"# schedcoop/coexecution aggregate: {best_coop / best_coex:.3f}x "
          f"(paper: ~1.04x)")
    if best_coop > best_coex and best_coop > by["exclusive"]:
        print("# CLAIM OK: SCHED_COOP attains the highest aggregate rate")
    return 0


if __name__ == "__main__":
    sys.exit(main())
