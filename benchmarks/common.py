"""Shared machinery for the paper-reproduction benchmarks.

All four experiments (matmul heatmap, Cholesky compositions, microservices,
MD ensembles) run on the discrete-event executor at full node scale
(112 slots / 2 sockets, the paper's Sapphire Rapids node), with workloads
expressed as nested-runtime task graphs:

  * an OUTER runtime = W worker tasks pulling work items from a channel
    (OmpSs-2/oneTBB worker-per-core model);
  * each work item opens an INNER parallel region: (n-1) spawned team
    tasks + the worker itself, all meeting at a BLAS-style busy-wait
    barrier (OpenBLAS/BLIS), optionally yield-adapted (§5.2);
  * per-call thread create/destroy cost models the BLIS pthread backend
    (Table 2's `pth` rows) vs thread caching.

Calibration constants are CPU-node ballparks; the experiments measure
RELATIVE policy effects (the paper's claims are ratios, not absolutes).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from typing import Iterable

from repro.core import simtask as st
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair
from repro.core.simtask import SimCosts
from repro.core.stats import latency_summary
from repro.core.task import Job, Task
from repro.core.topology import node_topology

CORES = 112          # 2 x 56 Sapphire Rapids
CORE_GFLOPS = 50.0   # effective per-core DGEMM throughput
SPIN_SLICE = 100e-6
THREAD_CREATE_COST = 150e-6   # pthread create+destroy round trip


@dataclasses.dataclass
class StackConfig:
    """One software-stack variant of §5.3 (Fig. 2)."""

    name: str
    policy: str = "fair"              # fair (Linux) | coop (SCHED_COOP)
    yield_every: Optional[int] = 8    # busy-wait barrier adaptation; None=off
    coop_barriers: bool = False       # Manual: nOS-V blocking barriers
    thread_cache: bool = True         # False: create/destroy per region
    quantum: float = 0.020


STACKS = {
    # unmodified busy-wait barriers under Linux
    "original": StackConfig("original", policy="fair", yield_every=None),
    # + sched_yield in the spin loop; Linux yield is weakly effective
    # ("Linux might not yield immediately", §5.3) — every ~8th works
    "baseline": StackConfig("baseline", policy="fair", yield_every=8),
    # same stack under glibcv: sched_yield -> nosv_yield, which ALWAYS
    # yields ("the matmul SCHED_COOP version always yields", §5.3)
    "sched_coop": StackConfig("sched_coop", policy="coop", yield_every=1),
    # + ad-hoc nOS-V integration: blocking barriers instead of spinning
    "manual": StackConfig("manual", policy="coop", yield_every=1,
                          coop_barriers=True),
}


def stack_policy(stack: StackConfig):
    """A fresh intra-job policy instance matching the stack's flavour (one
    mapping for the node executor AND per-job lease groups, so leased
    scenarios stay comparable to their flat twins)."""
    if stack.policy == "coop":
        return SchedCoop(quantum=stack.quantum)
    return SchedFair(slice_s=0.003)


def make_executor(stack: StackConfig, *, cores: int = CORES,
                  max_time: float = 3600.0) -> SimExecutor:
    domains = 2 if cores % 2 == 0 else 1
    return SimExecutor(node_topology(cores, domains), stack_policy(stack),
                       costs=SimCosts(), max_time=max_time)


def warmup_scale_for(ws_bytes: float, *, mem_bw: float = 10e9,
                     base: float = 20e-6) -> float:
    """Scale warm-up penalties by working-set size: refilling ws_bytes at
    mem_bw should cost ws/mem_bw seconds against a `base`-second constant."""
    return max(ws_bytes / mem_bw / base, 1.0)


def inner_region(sim: SimExecutor, job: Job, work_s: float, n_threads: int,
                 stack: StackConfig, *, n_syncs: int = 4, flops: float = 0.0,
                 ws_bytes: float = 0.0):
    """Generator: one BLAS call — fork an inner team, compute in n_syncs
    phases separated by team barriers, join. Runs inside an outer task."""
    if n_threads <= 1:
        yield st.compute(work_s, flops=flops)
        return

    share = work_s / n_threads
    phase = share / n_syncs
    scale = warmup_scale_for(ws_bytes / n_threads) if ws_bytes else 1.0
    if stack.coop_barriers:
        bar = st.SimBarrier(n_threads)
        bar_op = st.barrier_wait
    else:
        bar = st.SimSpinBarrier(n_threads, spin_slice=SPIN_SLICE,
                                yield_every=stack.yield_every)
        bar_op = st.spin_barrier_wait

    def member():
        if not stack.thread_cache:
            yield st.compute(THREAD_CREATE_COST)  # pthread create overhead
        for _ in range(n_syncs):
            yield st.compute(phase, flops=flops / n_threads / n_syncs)
            yield bar_op(bar)

    children = []
    for _ in range(n_threads - 1):
        child = Task(job, body=member, name="team")
        child._warmup_scale = scale  # cache working set per team member
        children.append(child)
        yield st.spawn(child)
    # the calling worker is the team leader
    for _ in range(n_syncs):
        yield st.compute(phase, flops=flops / n_threads / n_syncs)
        yield bar_op(bar)
    for c in children:
        yield st.join(c)


def outer_runtime(sim: SimExecutor, job: Job, work_items: list,
                  n_workers: int, stack: StackConfig, body_of_item):
    """Spawn an outer worker pool that drains `work_items` from a channel.
    `body_of_item(item)` returns a generator (usually an inner_region)."""
    ch = st.SimChannel()
    for it in work_items:
        ch.items.append(it)
    for _ in range(n_workers):
        ch.items.append(None)  # poison pill per worker

    def worker():
        while True:
            item = yield st.channel_get(ch)
            if item is None:
                return
            yield from body_of_item(item)

    return [sim.spawn(job, worker, name=f"{job.name}-w{i}")
            for i in range(n_workers)]


def summarize_latencies(latencies: Iterable[float], *, prefix: str = "",
                        round_to: Optional[int] = None) -> dict:
    """One uniform latency summary for every benchmark artifact.

    Every harness that reports a latency distribution (microservices,
    colocation, faults, the open-arrival SLO sweep) goes through here so
    the JSON artifacts carry one shape: n / mean / p50 / p95 / p99 / p999
    / max, nearest-rank percentiles from ``repro.core.stats``. ``prefix``
    is prepended to each key (``prefix="lat_"`` gives the microservices
    grid's ``lat_p99`` shape); ``round_to`` rounds every float to that
    many decimals (the faults harness's 4-decimal JSON)."""
    s = latency_summary(list(latencies))
    if round_to is not None:
        s = {k: (round(v, round_to) if isinstance(v, float) else v)
             for k, v in s.items()}
    if prefix:
        s = {f"{prefix}{k}": v for k, v in s.items()}
    return s


def default_out(bench: str, smoke: bool, override=None) -> str:
    """One naming convention for every benchmark artifact: the committed
    baseline is ``BENCH_<bench>.json``, smoke runs write the gitignored
    ``BENCH_<bench>.smoke.json`` (CI uploads both shapes by glob)."""
    if override:
        return override
    return f"BENCH_{bench}.smoke.json" if smoke else f"BENCH_{bench}.json"


def write_artifact(out: str, payload: dict) -> str:
    """Dump a benchmark payload the way every harness does: 2-space
    indent, trailing newline, a ``wrote <path>`` line for the CI log."""
    import json

    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")
    return out
