"""Benchmark entry point — CSV aggregator + unified ``--all`` runner.

Default mode prints ``name,us_per_call,derived`` CSV: us_per_call is the
representative cell's simulated makespan (µs of virtual time per workload
run — the quantity the paper measures), derived is the headline claim
metric.

``--all`` discovers every benchmark module in this package and runs each
module's ``main()`` in sequence (``--smoke`` forwards the smoke flag to
modules that take argv). This replaces per-bench ``__main__`` invocation
lists in the Makefile/CI with one entry point:

    python -m benchmarks.run                  # legacy CSV aggregator
    python -m benchmarks.run --all --smoke    # every bench, smoke-sized
    python -m benchmarks.run --all --only faults,trace_replay

Full sweeps still live in the individual modules:
    python -m benchmarks.matmul_heatmap          (Fig. 3)
    python -m benchmarks.cholesky_compositions   (Table 2)
    python -m benchmarks.microservices           (Fig. 4)
    python -m benchmarks.ensembles               (Fig. 5)
    python -m benchmarks.roofline                (§Roofline)
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import pkgutil
import sys
import time


def bench_matmul_fig3() -> list[tuple[str, float, str]]:
    from benchmarks.common import STACKS
    from benchmarks.matmul_heatmap import run_cell

    rows = []
    cells = {}
    for stack in ("original", "baseline", "sched_coop", "manual"):
        r = run_cell(STACKS[stack], 28, 1024)
        cells[stack] = r
        rows.append((f"fig3.matmul.{stack}.28tx1024",
                     r["makespan"] * 1e6,
                     f"{r['gflops']:.0f}GF/s"))
    sp = cells["sched_coop"]["gflops"] / cells["baseline"]["gflops"]
    rows.append(("fig3.claim.coop_vs_baseline", 0.0, f"{sp:.3f}x"))
    return rows


def bench_cholesky_table2() -> list[tuple[str, float, str]]:
    from benchmarks.cholesky_compositions import run_composition

    rows = []
    for comp in ("gnu+llvm+opb", "tbb+pth+blis"):
        for degree in ("mild", "high"):
            b = run_composition(comp, degree, "baseline")
            c = run_composition(comp, degree, "sched_coop")
            rows.append((f"table2.{comp}.{degree}",
                         b["makespan"] * 1e6,
                         f"{c['mops'] / b['mops']:.2f}x"))
    return rows


def bench_microservices_fig4() -> list[tuple[str, float, str]]:
    from benchmarks.microservices import run_scenario

    rows = []
    res = {}
    for sc in ("bl-none", "sched_coop"):
        r = run_scenario(sc, 0.5)
        res[sc] = r
        rows.append((f"fig4.{sc}.rate0.5",
                     r["lat_mean"] * 1e6,
                     f"thpt={r['throughput']:.3f}req/s"))
    ratio = res["bl-none"]["lat_mean"] / res["sched_coop"]["lat_mean"]
    rows.append(("fig4.claim.latency_ratio", 0.0, f"{ratio:.2f}x"))
    return rows


def bench_ensembles_fig5() -> list[tuple[str, float, str]]:
    from benchmarks.ensembles import run_scenario

    rows = []
    res = {}
    for sc in ("exclusive", "coexecution_node", "schedcoop_node"):
        r = run_scenario(sc)
        res[sc] = r
        rows.append((f"fig5.{sc}", r["makespan"] * 1e6,
                     f"{r['katom_steps_per_s']:.1f}Katom-step/s"))
    ratio = (res["schedcoop_node"]["katom_steps_per_s"]
             / res["coexecution_node"]["katom_steps_per_s"])
    rows.append(("fig5.claim.coop_vs_coexec", 0.0, f"{ratio:.3f}x"))
    return rows


def bench_kernels() -> list[tuple[str, float, str]]:
    """Pallas kernels in interpret mode (CPU correctness timing) vs oracle."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref

    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    t0 = time.perf_counter()
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    expect = ref.flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True)
    err = float(jnp.max(jnp.abs(jnp.swapaxes(out, 1, 2) - expect)))
    rows.append(("kernel.flash_attention.interpret", dt * 1e6,
                 f"maxerr={err:.2e}"))

    x = jax.random.normal(ks[0], (1, 64, 2, 16))
    dtv = jax.nn.softplus(jax.random.normal(ks[1], (1, 64, 2)))
    A = -jnp.exp(jax.random.normal(ks[2], (2,)) * 0.3)
    Bm = jax.random.normal(ks[1], (1, 64, 8)) * 0.5
    Cm = jax.random.normal(ks[2], (1, 64, 8)) * 0.5
    y, h = ops.ssd_scan(x, dtv, A, Bm, Cm, chunk=16, interpret=True)
    t0 = time.perf_counter()
    y, h = ops.ssd_scan(x, dtv, A, Bm, Cm, chunk=16, interpret=True)
    y.block_until_ready()
    dt = time.perf_counter() - t0
    y_ref, _ = ref.ssd_ref(x, dtv, A, Bm, Cm)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    rows.append(("kernel.ssd_scan.interpret", dt * 1e6, f"maxerr={err:.2e}"))
    return rows


def bench_roofline() -> list[tuple[str, float, str]]:
    from benchmarks.roofline import load_rows

    rows = []
    for r in load_rows():
        if r["status"] == "ok":
            rows.append((f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}",
                         max(r["compute_s"], r["memory_s"],
                             r["collective_s"]) * 1e6,
                         f"{r['dominant']};mfu<={r['mfu_bound']:.3f}"))
    return rows[:12]  # headline rows; full table via benchmarks.roofline


def run_csv() -> int:
    """Legacy aggregator: one CSV row per paper table/figure cell."""
    print("name,us_per_call,derived")
    for fn in (bench_matmul_fig3, bench_cholesky_table2,
               bench_microservices_fig4, bench_ensembles_fig5,
               bench_kernels, bench_roofline):
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},0,ERROR:{type(e).__name__}:{e}", flush=True)
    return 0


# Not benchmark modules: this runner and the shared helper library.
_SKIP = {"common", "run"}


def discover() -> list[str]:
    """All benchmark module names in this package, alphabetical."""
    import benchmarks

    return sorted(
        m.name for m in pkgutil.iter_modules(benchmarks.__path__)
        if m.name not in _SKIP and not m.name.startswith("_"))


def _takes_argv(main_fn) -> bool:
    try:
        return len(inspect.signature(main_fn).parameters) > 0
    except (TypeError, ValueError):
        return False


def run_all(*, smoke: bool, only: list[str] | None = None) -> int:
    """Run every discovered bench module's ``main()`` in sequence.

    Modules whose ``main`` takes argv get ``--smoke`` forwarded in smoke
    mode; bare-``main()`` modules (fixed-size paper sweeps) only run in
    full mode — smoke skips them, since they have no small shape.
    """
    names = discover()
    if only:
        missing = sorted(set(only) - set(names))
        if missing:
            print(f"unknown benchmarks: {', '.join(missing)} "
                  f"(have: {', '.join(names)})", file=sys.stderr)
            return 2
        names = [n for n in names if n in only]
    failures = []
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        main_fn = getattr(mod, "main", None)
        if main_fn is None:
            print(f"== {name}: skipped (no main())", flush=True)
            continue
        if not _takes_argv(main_fn):
            if smoke:
                print(f"== {name}: skipped in smoke mode (full-size "
                      f"sweep only)", flush=True)
                continue
            argv = None
        else:
            argv = ["--smoke"] if smoke else []
        t0 = time.monotonic()
        print(f"== {name} ==", flush=True)
        try:
            rc = main_fn() if argv is None else main_fn(argv)
        except Exception as e:  # noqa: BLE001
            print(f"== {name}: ERROR {type(e).__name__}: {e}",
                  file=sys.stderr, flush=True)
            failures.append(name)
            continue
        dt = time.monotonic() - t0
        if rc not in (0, None):
            failures.append(name)
        print(f"== {name}: {'FAIL' if rc not in (0, None) else 'ok'} "
              f"({dt:.1f}s)", flush=True)
    if failures:
        print(f"failed: {', '.join(failures)}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--all", action="store_true",
                    help="run every benchmark module (default: legacy "
                         "CSV aggregator)")
    ap.add_argument("--smoke", action="store_true",
                    help="with --all: forward --smoke to each bench")
    ap.add_argument("--only", default=None,
                    help="with --all: comma-separated subset of modules")
    args = ap.parse_args(argv)
    if not args.all:
        if args.smoke or args.only:
            ap.error("--smoke/--only require --all")
        return run_csv()
    only = args.only.split(",") if args.only else None
    return run_all(smoke=args.smoke, only=only)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
