"""Benchmark aggregator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV: us_per_call is the representative
cell's simulated makespan (µs of virtual time per workload run — the
quantity the paper measures), derived is the headline claim metric.

Full sweeps live in the individual modules:
    python -m benchmarks.matmul_heatmap          (Fig. 3)
    python -m benchmarks.cholesky_compositions   (Table 2)
    python -m benchmarks.microservices           (Fig. 4)
    python -m benchmarks.ensembles               (Fig. 5)
    python -m benchmarks.roofline                (§Roofline)
"""

from __future__ import annotations

import time


def bench_matmul_fig3() -> list[tuple[str, float, str]]:
    from benchmarks.common import STACKS
    from benchmarks.matmul_heatmap import run_cell

    rows = []
    cells = {}
    for stack in ("original", "baseline", "sched_coop", "manual"):
        r = run_cell(STACKS[stack], 28, 1024)
        cells[stack] = r
        rows.append((f"fig3.matmul.{stack}.28tx1024",
                     r["makespan"] * 1e6,
                     f"{r['gflops']:.0f}GF/s"))
    sp = cells["sched_coop"]["gflops"] / cells["baseline"]["gflops"]
    rows.append(("fig3.claim.coop_vs_baseline", 0.0, f"{sp:.3f}x"))
    return rows


def bench_cholesky_table2() -> list[tuple[str, float, str]]:
    from benchmarks.cholesky_compositions import run_composition

    rows = []
    for comp in ("gnu+llvm+opb", "tbb+pth+blis"):
        for degree in ("mild", "high"):
            b = run_composition(comp, degree, "baseline")
            c = run_composition(comp, degree, "sched_coop")
            rows.append((f"table2.{comp}.{degree}",
                         b["makespan"] * 1e6,
                         f"{c['mops'] / b['mops']:.2f}x"))
    return rows


def bench_microservices_fig4() -> list[tuple[str, float, str]]:
    from benchmarks.microservices import run_scenario

    rows = []
    res = {}
    for sc in ("bl-none", "sched_coop"):
        r = run_scenario(sc, 0.5)
        res[sc] = r
        rows.append((f"fig4.{sc}.rate0.5",
                     r["lat_mean"] * 1e6,
                     f"thpt={r['throughput']:.3f}req/s"))
    ratio = res["bl-none"]["lat_mean"] / res["sched_coop"]["lat_mean"]
    rows.append(("fig4.claim.latency_ratio", 0.0, f"{ratio:.2f}x"))
    return rows


def bench_ensembles_fig5() -> list[tuple[str, float, str]]:
    from benchmarks.ensembles import run_scenario

    rows = []
    res = {}
    for sc in ("exclusive", "coexecution_node", "schedcoop_node"):
        r = run_scenario(sc)
        res[sc] = r
        rows.append((f"fig5.{sc}", r["makespan"] * 1e6,
                     f"{r['katom_steps_per_s']:.1f}Katom-step/s"))
    ratio = (res["schedcoop_node"]["katom_steps_per_s"]
             / res["coexecution_node"]["katom_steps_per_s"])
    rows.append(("fig5.claim.coop_vs_coexec", 0.0, f"{ratio:.3f}x"))
    return rows


def bench_kernels() -> list[tuple[str, float, str]]:
    """Pallas kernels in interpret mode (CPU correctness timing) vs oracle."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref

    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    t0 = time.perf_counter()
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    expect = ref.flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True)
    err = float(jnp.max(jnp.abs(jnp.swapaxes(out, 1, 2) - expect)))
    rows.append(("kernel.flash_attention.interpret", dt * 1e6,
                 f"maxerr={err:.2e}"))

    x = jax.random.normal(ks[0], (1, 64, 2, 16))
    dtv = jax.nn.softplus(jax.random.normal(ks[1], (1, 64, 2)))
    A = -jnp.exp(jax.random.normal(ks[2], (2,)) * 0.3)
    Bm = jax.random.normal(ks[1], (1, 64, 8)) * 0.5
    Cm = jax.random.normal(ks[2], (1, 64, 8)) * 0.5
    y, h = ops.ssd_scan(x, dtv, A, Bm, Cm, chunk=16, interpret=True)
    t0 = time.perf_counter()
    y, h = ops.ssd_scan(x, dtv, A, Bm, Cm, chunk=16, interpret=True)
    y.block_until_ready()
    dt = time.perf_counter() - t0
    y_ref, _ = ref.ssd_ref(x, dtv, A, Bm, Cm)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    rows.append(("kernel.ssd_scan.interpret", dt * 1e6, f"maxerr={err:.2e}"))
    return rows


def bench_roofline() -> list[tuple[str, float, str]]:
    from benchmarks.roofline import load_rows

    rows = []
    for r in load_rows():
        if r["status"] == "ok":
            rows.append((f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}",
                         max(r["compute_s"], r["memory_s"],
                             r["collective_s"]) * 1e6,
                         f"{r['dominant']};mfu<={r['mfu_bound']:.3f}"))
    return rows[:12]  # headline rows; full table via benchmarks.roofline


def main() -> None:
    print("name,us_per_call,derived")
    for fn in (bench_matmul_fig3, bench_cholesky_table2,
               bench_microservices_fig4, bench_ensembles_fig5,
               bench_kernels, bench_roofline):
        t0 = time.time()
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},0,ERROR:{type(e).__name__}:{e}", flush=True)


if __name__ == "__main__":
    main()
