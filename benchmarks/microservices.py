"""Paper Fig. 4: oversubscribed multi-process AI microservices.

Poisson requests -> Gateway + three inference servers (LLaMA-3.2-1B,
GPT-2-124M, RoBERTa-355M; per-request costs from the paper's isolated
scalability runs: 5.4s@28c, 1.8s@8c, 1.2s@8c). Each request spawns one
thread per process; the three servers run BLAS teams with busy-wait
barriers -> oversubscription grows with request overlap.

Scenarios:
  bl-none      no partitioning, Linux scheduler (gateway nice 0, servers 20)
  bl-eq        equal static partitions (36/36/36 cores + 2 gateway)
  bl-opt       scalability-proportional partitions (71/23/16 + 2)
  bl-none-seq  no partitioning, inference without inner parallelism
  sched_coop   USF/SCHED_COOP, no partitioning, no nice needed

Claims validated: bl-eq worst; bl-none collapses as rate grows while
SCHED_COOP sustains latency+throughput (paper: up to 2.4x at 0.33 req/s);
bl-none-seq has flat latency but poor low-rate latency.
"""

from __future__ import annotations

import dataclasses
import sys

import numpy as np

from benchmarks.common import (
    STACKS,
    StackConfig,
    inner_region,
    make_executor,
)
from repro.core import simtask as st
from repro.core.stats import latency_summary
from repro.core.task import Job, Task

N_REQUESTS = 28
GATEWAY_COMPUTE = 0.010
N_SYNCS = 48  # per-inference BLAS sync points (layers x GEMMs per layer)

# (name, total core-seconds, ideal threads, working set MB)
MODELS = [
    ("llama", 5.4 * 28, 28, 2000.0),
    ("gpt2", 1.8 * 8, 8, 250.0),
    ("roberta", 1.2 * 8, 8, 700.0),
]


def _arrivals(rate: float, n: int, seed: int = 0) -> list[float]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return list(np.cumsum(gaps))


@dataclasses.dataclass
class RequestLog:
    arrival: float
    start: float = 0.0
    end: float = 0.0


def _run_shared(stack: StackConfig, rate: float, *, cores: int = 112,
                seq_inference: bool = False, seed: int = 0):
    """bl-none / bl-none-seq / sched_coop: all jobs share the node."""
    sim = make_executor(stack, cores=cores, max_time=10_000.0)
    gw_job = Job("gateway", nice=0)
    server_jobs = {name: Job(name, nice=20) for name, _, _, _ in MODELS}
    logs = [RequestLog(a) for a in _arrivals(rate, N_REQUESTS, seed)]

    def client(i: int):
        def gen():
            logs[i].start = sim.now()
            yield st.compute(GATEWAY_COMPUTE)  # planning logic
            children = []
            for name, work_cs, n_thr, ws_mb in MODELS:
                n = 1 if seq_inference else n_thr
                ws = min(ws_mb * 1e6 / max(n, 1), 20e6) * n

                def body(work_cs=work_cs, n=n, ws=ws, job=server_jobs[name]):
                    yield from inner_region(sim, job, work_cs, n, stack,
                                            n_syncs=N_SYNCS, ws_bytes=ws)

                child = Task(server_jobs[name], body=body, name=f"{name}-r{i}")
                children.append(child)
                yield st.spawn(child)
            for c in children:
                yield st.join(c)
            logs[i].end = sim.now()

        return gen

    for i, lg in enumerate(logs):
        sim.spawn(gw_job, client(i), name=f"req{i}", at=lg.arrival)
    sim.run()
    return logs


def _run_partitioned(rate: float, partitions: dict[str, int], *, seed: int = 0):
    """bl-eq / bl-opt: each server simulated on its own core partition; the
    gateway adds its planning compute; request latency = gateway + max over
    servers (the gateway blocks until all respond)."""
    per_server_latency: dict[str, list[float]] = {}
    ends: dict[str, list[float]] = {}
    arrivals = _arrivals(rate, N_REQUESTS, seed)
    for name, work_cs, n_thr, ws_mb in MODELS:
        cores = partitions[name]
        stack = STACKS["baseline"]
        sim = make_executor(stack, cores=cores, max_time=10_000.0)
        job = Job(name, nice=20)
        logs = [RequestLog(a) for a in arrivals]

        def client(i: int):
            def gen():
                n = min(n_thr, cores)
                ws = min(ws_mb * 1e6 / max(n, 1), 20e6) * n
                yield from inner_region(sim, job, work_cs, n, stack,
                                        n_syncs=N_SYNCS, ws_bytes=ws)
                logs[i].end = sim.now()

            return gen

        for i, lg in enumerate(logs):
            sim.spawn(job, client(i), name=f"{name}-r{i}", at=lg.arrival)
        sim.run()
        per_server_latency[name] = [lg.end - lg.arrival for lg in logs]
        ends[name] = [lg.end for lg in logs]

    logs = [RequestLog(a) for a in arrivals]
    for i in range(N_REQUESTS):
        logs[i].end = (
            max(ends[name][i] for name, *_ in MODELS) + GATEWAY_COMPUTE
        )
        logs[i].start = arrivals[i]
    return logs


def run_scenario(scenario: str, rate: float, *, seed: int = 0):
    if scenario == "bl-none":
        logs = _run_shared(STACKS["baseline"], rate, seed=seed)
    elif scenario == "bl-none-seq":
        logs = _run_shared(STACKS["baseline"], rate, seq_inference=True,
                           seed=seed)
    elif scenario == "sched_coop":
        logs = _run_shared(STACKS["sched_coop"], rate, seed=seed)
    elif scenario == "bl-eq":
        logs = _run_partitioned(rate, {"llama": 36, "gpt2": 37, "roberta": 37},
                                seed=seed)
    elif scenario == "bl-opt":
        logs = _run_partitioned(rate, {"llama": 71, "gpt2": 23, "roberta": 16},
                                seed=seed)
    else:
        raise ValueError(scenario)
    lats = [lg.end - lg.arrival for lg in logs]
    makespan = max(lg.end for lg in logs) - min(lg.arrival for lg in logs)
    return {
        "scenario": scenario,
        "rate": rate,
        "throughput": len(logs) / makespan,
        **{f"lat_{k}": v for k, v in latency_summary(lats).items()},
        "logs": [(lg.arrival, lg.end) for lg in logs],
    }


SCENARIOS = ["bl-none", "bl-eq", "bl-opt", "bl-none-seq", "sched_coop"]
RATES = [0.1, 0.2, 0.33, 0.5]


def main() -> int:
    print("scenario,rate,throughput,lat_mean,lat_p95")
    rows = []
    for rate in RATES:
        for sc in SCENARIOS:
            r = run_scenario(sc, rate)
            rows.append(r)
            print(f"{sc},{rate},{r['throughput']:.4f},{r['lat_mean']:.2f},"
                  f"{r['lat_p95']:.2f}", flush=True)
    # headline: collapse avoidance at 0.33
    at = {r["scenario"]: r for r in rows if r["rate"] == 0.33}
    ratio = at["bl-none"]["lat_mean"] / at["sched_coop"]["lat_mean"]
    print(f"# bl-none/sched_coop mean-latency ratio at 0.33: {ratio:.2f}x "
          f"(paper: up to 2.4x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
