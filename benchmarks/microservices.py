"""Paper Fig. 4: oversubscribed multi-process AI microservices.

Poisson requests -> Gateway + three inference servers (LLaMA-3.2-1B,
GPT-2-124M, RoBERTa-355M; per-request costs from the paper's isolated
scalability runs: 5.4s@28c, 1.8s@8c, 1.2s@8c). Each request spawns one
thread per process; the three servers run BLAS teams with busy-wait
barriers -> oversubscription grows with request overlap.

Scenarios:
  bl-none      no partitioning, Linux scheduler (gateway nice 0, servers 20)
  bl-eq        equal static partitions (36/36/36 cores + 2 gateway)
  bl-opt       scalability-proportional partitions (71/23/16 + 2)
  bl-none-seq  no partitioning, inference without inner parallelism
  sched_coop   USF/SCHED_COOP, no partitioning, no nice needed
  lease-eq     bl-eq's split as arbiter slot LEASES: every process is its
               own fixed-share group on ONE shared node (36:37:37 + 2)
  lease-opt    bl-opt's split as leases (71:23:16 + 2)

The lease scenarios port the §5.5 static-partition baselines onto the
two-level scheduler: same capacity split, but quotas are work-conserving
(a group may borrow siblings' idle slots, invariant I5) instead of hard
core fences — the quota-based-vs-static comparison the arbiter exists
for. ``python -m benchmarks.microservices`` writes
``BENCH_microservices.json`` with the full sweep.

Claims validated: bl-eq worst; bl-none collapses as rate grows while
SCHED_COOP sustains latency+throughput (paper: up to 2.4x at 0.33 req/s);
bl-none-seq has flat latency but poor low-rate latency; lease-X dominates
its static bl-X twin (borrowing reclaims the partitions' idle cores).
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Optional

import numpy as np

from benchmarks.common import (
    STACKS,
    StackConfig,
    default_out,
    inner_region,
    make_executor,
    stack_policy,
    summarize_latencies,
    write_artifact,
)
from repro.core import simtask as st
from repro.core.deadline import DeadlineArbiter
from repro.core.events import SimExecutor, SimLivelock, SimTimeout
from repro.core.policies import SchedFair
from repro.core.stats import latency_summary
from repro.core.task import Job, Task
from repro.core.topology import node_topology

N_REQUESTS = 28
GATEWAY_COMPUTE = 0.010
N_SYNCS = 48  # per-inference BLAS sync points (layers x GEMMs per layer)

# (name, total core-seconds, ideal threads, working set MB)
MODELS = [
    ("llama", 5.4 * 28, 28, 2000.0),
    ("gpt2", 1.8 * 8, 8, 250.0),
    ("roberta", 1.2 * 8, 8, 700.0),
]


def _arrivals(rate: float, n: int, seed: int = 0) -> list[float]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return list(np.cumsum(gaps))


@dataclasses.dataclass
class RequestLog:
    arrival: float
    start: float = 0.0
    end: float = 0.0


def _drain(sim) -> bool:
    """Run the cell to completion; returns False if it blew the event
    budget (an oversubscription collapse — e.g. the static partitions at
    high rates drown in busy-wait churn). Completed requests keep their
    logs; the cell is then reported as collapsed instead of crashing the
    sweep."""
    try:
        sim.run()
        return True
    except (SimTimeout, SimLivelock):
        return False


def _run_shared(stack: StackConfig, rate: float, *, cores: int = 112,
                seq_inference: bool = False, seed: int = 0,
                shares: Optional[dict[str, float]] = None,
                max_events: Optional[int] = None):
    """bl-none / bl-none-seq / sched_coop: all jobs share the node.

    With ``shares`` the same workload runs under the two-level scheduler:
    the gateway and every server attach as their own fixed-share arbiter
    group (static-partition capacity split expressed as work-conserving
    slot leases — the lease-eq / lease-opt scenarios)."""
    sim = make_executor(stack, cores=cores, max_time=10_000.0)
    if max_events is not None:
        sim.max_events = max_events
    gw_job = Job("gateway", nice=0)
    server_jobs = {name: Job(name, nice=20) for name, _, _, _ in MODELS}
    if shares is not None:
        sim.attach(gw_job, policy=stack_policy(stack),
                   share=shares.get("gateway", 2.0))
        for name, job in server_jobs.items():
            sim.attach(job, policy=stack_policy(stack), share=shares[name])
    logs = [RequestLog(a) for a in _arrivals(rate, N_REQUESTS, seed)]

    def client(i: int):
        def gen():
            logs[i].start = sim.now()
            yield st.compute(GATEWAY_COMPUTE)  # planning logic
            children = []
            for name, work_cs, n_thr, ws_mb in MODELS:
                n = 1 if seq_inference else n_thr
                ws = min(ws_mb * 1e6 / max(n, 1), 20e6) * n

                def body(work_cs=work_cs, n=n, ws=ws, job=server_jobs[name]):
                    yield from inner_region(sim, job, work_cs, n, stack,
                                            n_syncs=N_SYNCS, ws_bytes=ws)

                child = Task(server_jobs[name], body=body, name=f"{name}-r{i}")
                children.append(child)
                yield st.spawn(child)
            for c in children:
                yield st.join(c)
            logs[i].end = sim.now()

        return gen

    for i, lg in enumerate(logs):
        sim.spawn(gw_job, client(i), name=f"req{i}", at=lg.arrival)
    _drain(sim)
    return logs


def _run_partitioned(rate: float, partitions: dict[str, int], *,
                     seed: int = 0, max_events: Optional[int] = None):
    """bl-eq / bl-opt: each server simulated on its own core partition; the
    gateway adds its planning compute; request latency = gateway + max over
    servers (the gateway blocks until all respond)."""
    per_server_latency: dict[str, list[float]] = {}
    ends: dict[str, list[float]] = {}
    arrivals = _arrivals(rate, N_REQUESTS, seed)
    for name, work_cs, n_thr, ws_mb in MODELS:
        cores = partitions[name]
        stack = STACKS["baseline"]
        sim = make_executor(stack, cores=cores, max_time=10_000.0)
        if max_events is not None:
            sim.max_events = max_events
        job = Job(name, nice=20)
        logs = [RequestLog(a) for a in arrivals]

        def client(i: int):
            def gen():
                n = min(n_thr, cores)
                ws = min(ws_mb * 1e6 / max(n, 1), 20e6) * n
                yield from inner_region(sim, job, work_cs, n, stack,
                                        n_syncs=N_SYNCS, ws_bytes=ws)
                logs[i].end = sim.now()

            return gen

        for i, lg in enumerate(logs):
            sim.spawn(job, client(i), name=f"{name}-r{i}", at=lg.arrival)
        _drain(sim)
        per_server_latency[name] = [lg.end - lg.arrival for lg in logs]
        ends[name] = [lg.end for lg in logs]

    logs = [RequestLog(a) for a in arrivals]
    for i in range(N_REQUESTS):
        server_ends = [ends[name][i] for name, *_ in MODELS]
        # a request is complete only if every partition finished its leg
        logs[i].end = (max(server_ends) + GATEWAY_COMPUTE
                       if all(e > 0.0 for e in server_ends) else 0.0)
        logs[i].start = arrivals[i]
    return logs


#: the §5.5 capacity splits, shared by the static and the leased variants
EQ_SPLIT = {"llama": 36.0, "gpt2": 37.0, "roberta": 37.0, "gateway": 2.0}
OPT_SPLIT = {"llama": 71.0, "gpt2": 23.0, "roberta": 16.0, "gateway": 2.0}


def run_scenario(scenario: str, rate: float, *, seed: int = 0,
                 max_events: Optional[int] = None):
    if scenario == "bl-none":
        logs = _run_shared(STACKS["baseline"], rate, seed=seed,
                           max_events=max_events)
    elif scenario == "bl-none-seq":
        logs = _run_shared(STACKS["baseline"], rate, seq_inference=True,
                           seed=seed, max_events=max_events)
    elif scenario == "sched_coop":
        logs = _run_shared(STACKS["sched_coop"], rate, seed=seed,
                           max_events=max_events)
    elif scenario == "bl-eq":
        logs = _run_partitioned(rate, {k: int(v) for k, v in EQ_SPLIT.items()
                                       if k != "gateway"}, seed=seed,
                                max_events=max_events)
    elif scenario == "bl-opt":
        logs = _run_partitioned(rate, {k: int(v) for k, v in OPT_SPLIT.items()
                                       if k != "gateway"}, seed=seed,
                                max_events=max_events)
    elif scenario == "lease-eq":
        logs = _run_shared(STACKS["baseline"], rate, seed=seed,
                           shares=EQ_SPLIT, max_events=max_events)
    elif scenario == "lease-opt":
        logs = _run_shared(STACKS["baseline"], rate, seed=seed,
                           shares=OPT_SPLIT, max_events=max_events)
    else:
        raise ValueError(scenario)
    done = [lg for lg in logs if lg.end > 0.0]
    collapsed = len(done) < len(logs)  # blew the event budget mid-cell
    lats = [lg.end - lg.arrival for lg in done]
    t0 = min(lg.arrival for lg in logs)
    makespan = (max(lg.end for lg in done) - t0) if done else 0.0
    return {
        "scenario": scenario,
        "rate": rate,
        "throughput": len(done) / makespan if makespan else 0.0,
        "completed": len(done),
        "requests": len(logs),
        "collapsed": collapsed,
        **{f"lat_{k}": v for k, v in
           latency_summary(lats or [0.0]).items()},
        "logs": [(lg.arrival, lg.end) for lg in logs],
    }


SCENARIOS = ["bl-none", "bl-eq", "bl-opt", "lease-eq", "lease-opt",
             "bl-none-seq", "sched_coop"]
RATES = [0.1, 0.2, 0.33, 0.5]


# --------------------------------------------------------------------- #
# open-arrival SLO sweep: deadline-aware vs share-only arbitration
# --------------------------------------------------------------------- #
#: serving node for the closed-loop generator: a small shared node where a
#: latency-bound serve job (half the lease) is co-located with a
#: best-effort batch job that borrows every idle slot (I5) — the
#: configuration where grant ORDER, not capacity, decides the tail
SLO_SLOTS = 8
SLO_SERVE_SHARE = 4.0
SLO_BATCH_SHARE = 4.0
SLO_SERVICE_S = 0.008       # per-request service demand
SLO_CHUNK_S = 0.001         # scheduling granularity inside a request
SLO_BATCH_CHUNK_S = 0.005   # batch compute between scheduling points
#: two request classes: EDF has something to reorder only when tight-SLO
#: requests queue behind loose-SLO ones
SLO_CLASSES = [("tight", 0.030, 0.5), ("loose", 0.400, 0.5)]
SLO_LOADS = [0.6, 0.8, 0.95, 1.1]


def _slo_arrivals(rate: float, n: int, seed: int) -> list[float]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    # plain floats: these flow into latencies and then into the JSON
    return [float(a) for a in 0.05 + np.cumsum(gaps)]


def run_slo_cell(load: float, *, deadline_aware: bool, n_requests: int = 800,
                 seed: int = 0) -> dict:
    """One (offered load, arbiter) cell of the open-arrival sweep.

    Poisson arrivals at ``load × serve-lease capacity / service time``
    into a serve job (dedicated preemptive group, every request carries an
    absolute deadline) co-located with a slot-hungry batch job. The ONLY
    independent variable is the arbiter class: ``DeadlineArbiter`` (EDF
    grant order + negative-laxity urgent grants) vs the share-only
    ``SlotArbiter`` baseline — capacity, policies, arrivals and service
    times are bit-identical across the pair."""
    default_pol = SchedFair(slice_s=0.003)
    arb = DeadlineArbiter(default_pol) if deadline_aware else None
    sim = SimExecutor(node_topology(SLO_SLOTS, 2), default_pol,
                      max_time=10_000.0, arbiter=arb)
    serve = Job("serve")
    batch = Job("batch")
    sim.attach(serve, policy=SchedFair(slice_s=0.003),
               share=SLO_SERVE_SHARE)
    sim.attach(batch, policy=SchedFair(slice_s=0.020),
               share=SLO_BATCH_SHARE)

    rate = load * SLO_SERVE_SHARE / SLO_SERVICE_S
    arrivals = _slo_arrivals(rate, n_requests, seed)
    rng = np.random.default_rng(seed + 1)
    classes = rng.choice(len(SLO_CLASSES), size=n_requests,
                         p=[w for _, _, w in SLO_CLASSES])
    horizon = arrivals[-1] + 2.0
    n_chunks = max(1, round(SLO_SERVICE_S / SLO_CHUNK_S))

    def batch_body():
        while sim.now() < horizon:
            yield st.compute(SLO_BATCH_CHUNK_S)
            yield st.checkpoint()

    for i in range(SLO_SLOTS):
        sim.spawn(batch, batch_body, name=f"batch{i}")

    done: list[tuple[int, float, float]] = []  # (class, latency, miss)

    def request(i: int, cls: int, arr: float, dl: float):
        def gen():
            for _ in range(n_chunks):
                yield st.compute(SLO_CHUNK_S)
            end = sim.now()
            done.append((cls, end - arr, float(end > dl)))

        return gen

    for i, arr in enumerate(arrivals):
        cls = int(classes[i])
        dl = arr + SLO_CLASSES[cls][1]
        # the deadline rides on the task itself: a DeadlineArbiter folds
        # it into its EDF grant order at on_ready time, the base arbiter
        # ignores it (the A/B's only difference)
        t = sim.spawn(serve, request(i, cls, arr, dl), name=f"req{i}",
                      at=arr, deadline=dl)
        t.cost_hint = SLO_SERVICE_S

    sim.run(until=horizon + 5.0)
    lats = [lat for _, lat, _ in done]
    row = {
        "arbiter": "deadline" if deadline_aware else "share",
        "load": load,
        "rate_rps": round(rate, 2),
        "requests": n_requests,
        "completed": len(done),
        "miss_rate": (sum(m for _, _, m in done) / len(done)
                      if done else 1.0),
        **summarize_latencies(lats, prefix="lat_"),
    }
    for ci, (cname, slo, _) in enumerate(SLO_CLASSES):
        cl = [(lat, m) for c, lat, m in done if c == ci]
        row[f"{cname}_slo_s"] = slo
        row[f"{cname}_miss_rate"] = (sum(m for _, m in cl) / len(cl)
                                     if cl else 1.0)
        row.update(summarize_latencies([lat for lat, _ in cl],
                                       prefix=f"{cname}_lat_"))
    if deadline_aware:
        row["urgent_grants"] = sim.sched.arbiter.urgent_grants
    return row


def run_slo_sweep(loads=None, *, n_requests: int = 800,
                  seed: int = 0) -> dict:
    """A/B the two arbiters across offered loads; returns rows plus a
    headline counting the loads where deadline-aware wins BOTH p99 and
    miss rate (the PR's acceptance bar: ≥ 2)."""
    loads = loads if loads is not None else SLO_LOADS
    rows = []
    wins = []
    print("arbiter,load,rate_rps,lat_p99,lat_p999,miss_rate,tight_miss")
    for load in loads:
        pair = {}
        for aware in (False, True):
            r = run_slo_cell(load, deadline_aware=aware,
                             n_requests=n_requests, seed=seed)
            rows.append(r)
            pair[r["arbiter"]] = r
            print(f"{r['arbiter']},{load},{r['rate_rps']},"
                  f"{r['lat_p99']:.4f},{r['lat_p999']:.4f},"
                  f"{r['miss_rate']:.4f},{r['tight_miss_rate']:.4f}",
                  flush=True)
        d, s = pair["deadline"], pair["share"]
        wins.append({
            "load": load,
            "p99_ratio": (round(s["lat_p99"] / d["lat_p99"], 3)
                          if d["lat_p99"] > 0 else None),
            "deadline_wins_p99": bool(d["lat_p99"] < s["lat_p99"]),
            "deadline_wins_miss": bool(d["miss_rate"] < s["miss_rate"]),
        })
    n_wins = sum(1 for w in wins
                 if w["deadline_wins_p99"] and w["deadline_wins_miss"])
    print(f"# deadline-aware wins p99 AND miss rate at {n_wins}/"
          f"{len(loads)} offered-load points")
    return {
        "loads": list(loads),
        "n_requests": n_requests,
        "service_s": SLO_SERVICE_S,
        "classes": [{"name": n, "slo_s": s, "weight": w}
                    for n, s, w in SLO_CLASSES],
        "rows": rows,
        "per_load": wins,
        "deadline_wins_both": n_wins,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_microservices.json, "
                         "or BENCH_microservices.smoke.json with --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="single mid-load rate; checks the sweep runs")
    ap.add_argument("--rates", type=float, nargs="*", default=None)
    ap.add_argument("--slo-only", action="store_true",
                    help="run only the open-arrival SLO sweep (skip the "
                         "Fig. 4 scenario grid)")
    args = ap.parse_args(argv)
    out = default_out("microservices", args.smoke, args.out)
    rates = args.rates if args.rates else ([0.33] if args.smoke else RATES)

    if args.slo_only:
        slo = run_slo_sweep(loads=[0.8, 1.1] if args.smoke else None,
                            n_requests=150 if args.smoke else 800)
        payload = {"bench": "microservices", "smoke": args.smoke,
                   "slo_only": True, "slo_sweep": slo}
        write_artifact(out, payload)
        return 0

    print("scenario,rate,throughput,lat_mean,lat_p95,completed")
    rows = []
    for rate in rates:
        for sc in SCENARIOS:
            # budget per cell: collapsing cells (static partitions at high
            # rates drowning in busy-wait churn) report partial results
            # instead of running the full 50M-event cap
            r = run_scenario(sc, rate, max_events=12_000_000)
            rows.append(r)
            tag = " COLLAPSED" if r["collapsed"] else ""
            print(f"{sc},{rate},{r['throughput']:.4f},{r['lat_mean']:.2f},"
                  f"{r['lat_p95']:.2f},{r['completed']}/{r['requests']}"
                  f"{tag}", flush=True)
    by = {(r["scenario"], r["rate"]): r for r in rows}
    headline = {}
    mid = 0.33 if 0.33 in rates else rates[len(rates) // 2]
    at = {sc: by[(sc, mid)] for sc in SCENARIOS if (sc, mid) in by}
    def _ratio(num_sc: str, den_sc: str):
        num, den = at.get(num_sc), at.get(den_sc)
        # collapsed/empty denominator -> no meaningful ratio; a collapsed
        # NUMERATOR keeps its (under-estimated: only the cheap early
        # requests finished) mean and is flagged as partial
        if (not num or not den or den["collapsed"] or den["lat_mean"] <= 0
                or num["lat_mean"] <= 0):
            return None, False
        return round(num["lat_mean"] / den["lat_mean"], 3), num["collapsed"]

    r, partial = _ratio("bl-none", "sched_coop")
    if r is not None:
        headline["coop_vs_blnone_latency"] = r
        headline["coop_vs_blnone_partial"] = partial
        note = (" [bl-none cell collapsed: ratio is a LOWER bound]"
                if partial else "")
        print(f"# bl-none/sched_coop mean-latency ratio at {mid}: "
              f"{r:.2f}x (paper: up to 2.4x){note}")
    for split in ("eq", "opt"):
        r, partial = _ratio(f"bl-{split}", f"lease-{split}")
        if r is not None:
            headline[f"lease_vs_static_{split}_latency"] = r
            headline[f"lease_vs_static_{split}_partial"] = partial
            note = (" [static cell collapsed: ratio is a LOWER bound]"
                    if partial else "")
            print(f"# bl-{split}/lease-{split} mean-latency ratio at {mid}: "
                  f"{r:.2f}x (work-conserving leases vs static cores)"
                  f"{note}")
    slo = run_slo_sweep(loads=[0.8, 1.1] if args.smoke else None,
                        n_requests=150 if args.smoke else 800)
    payload = {
        "bench": "microservices",
        "smoke": args.smoke,
        "rates": rates,
        "n_requests": N_REQUESTS,
        "headline": headline,
        "rows": [{k: v for k, v in r.items() if k != "logs"} for r in rows],
        "slo_sweep": slo,
    }
    write_artifact(out, payload)
    return 0


if __name__ == "__main__":
    sys.exit(main())
