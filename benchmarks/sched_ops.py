"""Scheduler-ops microbenchmark — the perf gate for the USF hot path.

Measures, in isolation from any workload semantics:

  * **scheduler-ops/sec per policy**: one "op" is a full
    ``pick -> on_run -> on_stop -> on_ready`` cycle against a ready pool
    held at a constant size (default 256 tasks, the oversubscription
    regime the paper's Fig. 3 heatmap stresses). Single-policy cycles run
    through the ``SlotArbiter`` front, so the numbers cover the two-level
    fast path (which rebinds to the bare policy methods — the PR 1
    baseline stays directly comparable);
  * **arbiter cycle** (``policy.arbiter2.pick_cycle``): the same churn
    against a *two-group* arbiter (SCHED_COOP + SCHED_FAIR co-located,
    equal shares) with slots held occupied, i.e. the multi-runtime
    lease-arbitration path;
  * **sim-events/sec**: events drained per wall second by ``SimExecutor``
    on two representative event mixes (cooperative yield churn and a
    preemptive tick-heavy compute load).

Run it from the repo root:

    PYTHONPATH=src python -m benchmarks.sched_ops            # full
    PYTHONPATH=src python -m benchmarks.sched_ops --smoke    # CI smoke
    PYTHONPATH=src python -m benchmarks.sched_ops --smoke \
        --gate BENCH_sched_ops.json                          # CI perf gate

``--gate BASELINE.json`` re-runs the SCHED_FAIR/SCHED_COOP pick-cycle
benches at the baseline's pool size and exits non-zero if either drops
more than ``--gate-drop`` (default 30%) below the committed numbers —
``make check`` wires this up so two-level regressions fail CI.

Writes ``BENCH_sched_ops.json`` (override with ``--out``) so the perf
trajectory is machine-tracked PR over PR. Numbers are wall-clock and thus
machine-dependent; compare ratios on the same host, not absolutes.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from collections import deque
from types import SimpleNamespace

from benchmarks.common import default_out, write_artifact

from repro.core.arbiter import SlotArbiter
from repro.core.policies import SchedCoop, SchedFair, SchedRR
from repro.core.policies.base import StopReason
from repro.core.task import Job, Task, TaskState
from repro.core.topology import Topology

MIN_SAMPLE_S = 0.5  # keep timing chunks above this to dampen jitter

GATED_KEYS = ("policy.fair.pick_cycle", "policy.coop.pick_cycle",
              "sched.preempt_cycle", "sched.auto_ckpt_overhead",
              "sched.urgent_preempt_latency")
#: per-key max-drop overrides (fraction below baseline that still passes).
#: sched.preempt_cycle's committed baseline is the POST-fast-path number
#: (self-ticking checkpoints, ~2 orders of magnitude above the watchdog-
#: driven cycle): a 0.6 floor still pins the 10x-over-the-old-path claim
#: with a wide margin while absorbing shared-host scheduling noise.
GATE_DROP_OVERRIDES = {"sched.preempt_cycle": 0.60}
#: sched.auto_ckpt_overhead gates on an ABSOLUTE ceiling, not a baseline
#: ratio: the whole point of the dispatch-boundary wrapper is that its
#: cost is a fixed, tiny fraction of a step — if the fraction itself
#: creeps toward the ceiling the instrumentation story is broken no
#: matter what the previous commit measured. Target ~2%, ceiling 5%.
AUTO_CKPT_OVERHEAD_CEILING = 0.05
#: sched.urgent_preempt_latency gates on p50 (latency, lower-is-better)
#: with a generous floor: 10x the committed baseline p50 or 2ms,
#: whichever is larger — wide enough for shared-host noise, tight enough
#: that a lost urgent-grant fast path (which would land at the watchdog
#: period, ~10ms+) fails loudly.
URGENT_LATENCY_FLOOR_S = 2e-3
URGENT_LATENCY_RATIO = 10.0


def _ops_per_sec(cycle, iters_hint: int, repeat: int = 1) -> tuple[float, int]:
    """Run ``cycle(i)`` until MIN_SAMPLE_S elapsed, ``repeat`` samples;
    return (best ops/sec, total iterations). The cycle state is steady, so
    run-to-run spread is host noise and the max is the least-noisy
    estimate (same reasoning as bench_sim_events)."""
    best = 0.0
    done = 0
    for _ in range(max(1, repeat)):
        sample_done = 0
        t0 = time.perf_counter()
        while True:
            for _ in range(iters_hint):
                cycle(done)
                done += 1
                sample_done += 1
            dt = time.perf_counter() - t0
            if dt >= MIN_SAMPLE_S:
                break
        best = max(best, sample_done / dt)
    return best, done


def _make_policy(name: str):
    if name == "coop":
        return SchedCoop(quantum=0.02)
    if name == "fair":
        return SchedFair(slice_s=0.003)
    if name == "rr":
        return SchedRR(quantum=0.01)
    raise ValueError(name)


def bench_policy(name: str, *, n_ready: int, n_slots: int,
                 iters_hint: int, repeat: int = 1) -> dict:
    """Steady-state pick/requeue churn with the pool held at ``n_ready``,
    driven through the SlotArbiter front (single-group fast path)."""
    topo = Topology(n_slots, 2 if n_slots % 2 == 0 else 1)
    policy = _make_policy(name)
    front = SlotArbiter(policy)
    # the arbiter/policies only need `.topology` off the scheduler
    front.attach(SimpleNamespace(topology=topo))
    jobs = [Job(f"bench-j{i}") for i in range(4)]
    tasks = [Task(jobs[i % len(jobs)], name=f"b{i}") for i in range(n_ready)]
    for i, t in enumerate(tasks):
        # mix of affine / unaffine tasks, spread over slots like a real pool
        t.last_slot = None if i % 7 == 0 else i % n_slots
    for t in tasks:
        front.on_ready(t)

    state = {"now": 0.0}

    def cycle(i: int) -> None:
        slot = i % n_slots
        task = front.pick(slot)
        now = state["now"]
        front.on_run(task, slot, now)
        state["now"] = now = now + 0.0005
        task.last_slot = slot
        front.on_stop(task, slot, now, 0.0005, StopReason.BLOCK)
        front.on_ready(task)

    ops, iters = _ops_per_sec(cycle, iters_hint, repeat=repeat)
    assert front.ready_count() == n_ready, "pool size drifted"
    return {"ops_per_sec": ops, "iterations": iters,
            "n_ready": n_ready, "n_slots": n_slots}


def bench_arbiter_cycle(*, n_ready: int, n_slots: int,
                        iters_hint: int, repeat: int = 1) -> dict:
    """Two-level pick churn: a SCHED_COOP job co-located with a SCHED_FAIR
    job at equal shares, slots held occupied so lease accounting (in_use /
    quota deficits) is exercised on every grant."""
    topo = Topology(n_slots, 2 if n_slots % 2 == 0 else 1)
    front = SlotArbiter(SchedCoop(quantum=0.02))
    front.attach(SimpleNamespace(topology=topo))
    job_a = Job("bench-coop")
    job_b = Job("bench-fair")
    front.attach_job(job_a, policy=SchedCoop(quantum=0.02), share=1.0)
    front.attach_job(job_b, policy=SchedFair(slice_s=0.003), share=1.0)
    tasks = [Task(job_a if i % 2 == 0 else job_b, name=f"a{i}")
             for i in range(n_ready)]
    for i, t in enumerate(tasks):
        t.last_slot = None if i % 7 == 0 else i % n_slots
    for t in tasks:
        front.on_ready(t)

    state = {"now": 0.0}
    running: deque = deque()  # (task, slot) ring keeps all slots occupied

    def cycle(i: int) -> None:
        now = state["now"]
        if len(running) == n_slots:
            task, slot = running.popleft()
            task.last_slot = slot
            front.on_stop(task, slot, now, 0.0005, StopReason.BLOCK)
            front.on_ready(task)
        slot = i % n_slots
        task = front.pick(slot)
        front.on_run(task, slot, now)
        state["now"] = now + 0.0005
        running.append((task, slot))

    ops, iters = _ops_per_sec(cycle, iters_hint, repeat=repeat)
    assert front.ready_count() + len(running) == n_ready, "pool drifted"
    groups = front.groups()
    assert len(groups) == 3 and front.multi, "two-level path not exercised"
    return {"ops_per_sec": ops, "iterations": iters,
            "n_ready": n_ready, "n_slots": n_slots}


def bench_migration_churn(*, n_ready: int, n_slots: int,
                          iters_hint: int, repeat: int = 1) -> dict:
    """Live-migration throughput: one op = a full any↔any re-home of a
    busy job — promote (default→dedicated), live policy swap
    (dedicated→dedicated), demote (dedicated→default) in rotation — each
    withdrawing the job's entire READY pool from the old policy
    (``Policy.remove``) and re-queueing it exactly once in the new one.
    This is the path the serving engine's rescale-driven policy changes
    ride; cost scales with the migrated pool, so ``tasks_migrated_per_sec``
    is the size-normalized number."""
    topo = Topology(n_slots, 2 if n_slots % 2 == 0 else 1)
    front = SlotArbiter(SchedCoop(quantum=0.02))
    front.attach(SimpleNamespace(topology=topo))
    bg = Job("bench-bg")  # keeps the arbiter in multi-group mode throughout
    front.attach_job(bg, policy=SchedCoop(quantum=0.02), share=1.0)
    mover = Job("bench-mover")
    tasks = [Task(mover, name=f"m{i}") for i in range(n_ready)]
    for i, t in enumerate(tasks):
        t.last_slot = None if i % 7 == 0 else i % n_slots
        # the bare-arbiter harness stands in for the Scheduler, which
        # marks tasks READY before queueing them — withdraw selects on it
        t.state = TaskState.READY
    for t in tasks:
        front.on_ready(t)  # implicit registration into the default group

    def cycle(i: int) -> None:
        k = i % 3
        if k == 0:    # promote out of the default group
            front.attach_job(mover, policy=SchedFair(slice_s=0.003),
                             share=1.0)
        elif k == 1:  # live policy swap between dedicated groups
            front.attach_job(mover, policy=SchedCoop(quantum=0.02),
                             share=1.0)
        else:         # demote back into the default group
            front.demote_job(mover)

    ops, iters = _ops_per_sec(cycle, iters_hint, repeat=repeat)
    # leave the mover wherever the last op put it; pool must be intact
    pol = front.policy_of(mover)
    assert pol.ready_count_of(mover) == n_ready, "tasks lost in migration"
    return {"ops_per_sec": ops, "iterations": iters,
            "tasks_migrated_per_sec": ops * n_ready,
            "n_ready": n_ready, "n_slots": n_slots}


# --------------------------------------------------------------------------- #
# real-thread tick driver (watchdog)
# --------------------------------------------------------------------------- #
def bench_tick_driver(*, n_timers: int, repeat: int = 1) -> dict:
    """Watchdog timer-heap throughput: one op = an armed timed wakeup
    fired through the single tick-driver thread (the ``threading.Timer``
    replacement behind ``sleep()``/timeouts/preemption ticks)."""
    import threading
    import time as _time
    from types import SimpleNamespace

    from repro.core.threads import _Watchdog

    best = 0.0
    total = 0
    for _ in range(max(1, repeat)):
        wd = _Watchdog(SimpleNamespace(sched=None))
        done = threading.Event()
        count = [0]

        def cb():
            count[0] += 1
            if count[0] == n_timers:
                done.set()

        t0 = time.perf_counter()
        now = _time.monotonic()  # all due immediately: measures heap+fire
        for _i in range(n_timers):
            wd.call_at(now, cb)
        assert done.wait(60.0), "watchdog never drained the timer heap"
        dt = time.perf_counter() - t0
        wd.stop()
        best = max(best, count[0] / dt)
        total += count[0]
    return {"ops_per_sec": best, "iterations": total, "n_timers": n_timers}


def bench_preempt_cycle(*, duration: float = 1.0, repeat: int = 1) -> dict:
    """End-to-end real-thread preemption rate, best of ``repeat`` runs:
    two CPU-bound SCHED_FAIR tasks share ONE slot; one op = a delivered
    preemption. Since the self-ticking checkpoint fast path this is
    checkpoint-latency bound (slice-expiry poll -> yield -> redispatch of
    the sibling) with the watchdog tick as backstop; repeat samples are
    fresh runtimes, so the max is the least-noisy estimate on a shared
    host."""
    best = None
    for _ in range(max(1, repeat)):
        r = _bench_preempt_cycle_once(duration=duration)
        if best is None or r["ops_per_sec"] > best["ops_per_sec"]:
            best = r
    best["repeat"] = max(1, repeat)
    return best


def _bench_preempt_cycle_once(*, duration: float) -> dict:
    import threading

    from repro.core.threads import UsfRuntime

    rt = UsfRuntime(Topology(1, 1), SchedFair(slice_s=0.002))
    stop = threading.Event()

    def spin():
        n = 0
        while not stop.is_set():
            n += 1
            if n % 200 == 0:
                rt.checkpoint()

    job = Job("bench-preempt")
    tasks = [rt.create(spin, job=job) for _ in range(2)]
    time.sleep(duration)
    stop.set()
    for t in tasks:
        assert rt.join(t, timeout=10.0)
    preempts = sum(t.stats.preemptions for t in tasks)
    ticks = rt.watchdog.ticks_fired
    polls = rt.sched.poll_preempts
    rt.shutdown(timeout=5.0)
    return {"ops_per_sec": preempts / duration, "iterations": preempts,
            "ticks_fired": ticks, "poll_preempts": polls,
            "duration_s": duration}


def bench_urgent_preempt_latency(*, trials: int = 50) -> dict:
    """Request-to-core-acquired latency of the urgent-grant path.

    A best-effort SCHED_FAIR spinner BORROWS the only slot (its lease
    quota is 0; the serve job owns the slot but sits idle). Each trial
    submits one serve task whose deadline is already past: the
    ``DeadlineArbiter`` fires ``urgent_preempt`` at on-ready time — CV
    kick, checkpoint-consumed flag, successor-hinted redispatch — and the
    trial measures submit() -> first instruction of the task body. This
    is the latency the SLO story rides on — gated in ``check_gate`` on
    p50 with a generous ceiling (see ``URGENT_LATENCY_FLOOR_S``): losing
    the urgent-grant fast path would push p50 to the watchdog period and
    fail loudly, while host noise stays well inside the margin."""
    import threading

    from repro.core.deadline import DeadlineArbiter
    from repro.core.threads import UsfRuntime

    default_pol = SchedCoop(quantum=0.02)
    rt = UsfRuntime(Topology(1, 1), default_pol,
                    arbiter=DeadlineArbiter(default_pol))
    serve = Job("bench-serve")
    batch = Job("bench-batch")
    # 3:1 shares over ONE slot -> serve quota 1, batch quota 0: the
    # spinner only ever runs on borrowed capacity (the urgent victim)
    rt.attach(serve, policy=SchedFair(slice_s=0.003), share=3.0)
    rt.attach(batch, policy=SchedFair(slice_s=0.050), share=1.0)
    stop = threading.Event()

    def spin():
        n = 0
        while not stop.is_set():
            n += 1
            if n % 64 == 0:
                rt.checkpoint()

    spinner = rt.create(spin, job=batch)
    time.sleep(0.05)  # let the spinner borrow the slot

    lats = []
    try:
        for _ in range(max(1, trials)):
            got = []

            def body():
                got.append(time.monotonic())

            t0 = time.monotonic()
            t = rt.create(body, job=serve, deadline=t0 - 1e-3)
            assert rt.join(t, timeout=10.0), "urgent task never ran"
            lats.append(got[0] - t0)
            time.sleep(0.002)  # let the spinner re-borrow the slot
    finally:
        stop.set()
        rt.join(spinner, timeout=10.0)
        urgents = rt.sched.arbiter.urgent_grants
        kicks = rt.watchdog.kicks
        rt.shutdown(timeout=5.0)
    xs = sorted(lats)

    def pct(p: float) -> float:
        return xs[min(len(xs) - 1, int(round(p * (len(xs) - 1))))]

    return {"trials": len(xs), "mean_s": sum(xs) / len(xs),
            "p50_s": pct(0.50), "p99_s": pct(0.99), "max_s": xs[-1],
            "urgent_grants": urgents, "watchdog_kicks": kicks}


def bench_auto_ckpt_overhead(*, step_s: float = 50e-6, steps: int = 2000,
                             repeat: int = 3) -> dict:
    """Per-dispatch cost of the auto-checkpoint wrapper, interleaved A/B.

    One gated USF task times ``steps`` calls of a CPU-bound step function
    bare, then the same function behind ``autockpt.preemptible`` (which
    runs ``usf.checkpoint()`` — the real two-read fast path — before every
    call), alternating the two modes ``repeat`` times in the same task so
    both see identical host conditions. ``overhead_frac`` is the relative
    per-step cost of the wrapped mode over bare, best-of-``repeat`` per
    mode (min per-step time is the least-noisy estimate). Gated in
    ``check_gate`` against the ABSOLUTE ceiling
    ``AUTO_CKPT_OVERHEAD_CEILING`` — see the constant's comment."""
    from repro.core.autockpt import preemptible
    from repro.core.threads import UsfRuntime

    rt = UsfRuntime(Topology(1, 1), SchedCoop())

    def step():
        t_end = time.perf_counter() + step_s
        while time.perf_counter() < t_end:
            pass

    wstep = preemptible(step, runtime=rt)
    samples: dict = {"bare": [], "wrapped": []}
    ckpt_ns = [0.0]

    def body():
        # warm both paths (bytecode caches, the checkpoint fast path)
        for _ in range(50):
            step()
            wstep()
        for _ in range(max(1, repeat)):
            for name, fn in (("bare", step), ("wrapped", wstep)):
                t0 = time.perf_counter()
                for _ in range(steps):
                    fn()
                samples[name].append((time.perf_counter() - t0) / steps)
        # raw checkpoint cost in the same gated-task context, for context
        n = 20_000
        ckpt = rt.checkpoint
        t0 = time.perf_counter()
        for _ in range(n):
            ckpt()
        ckpt_ns[0] = (time.perf_counter() - t0) / n * 1e9

    task = rt.create(body, job=Job("bench-ackpt"))
    assert rt.join(task, timeout=600.0), "overhead bench task never finished"
    rt.shutdown(timeout=5.0)
    bare = min(samples["bare"])
    wrapped = min(samples["wrapped"])
    return {
        "overhead_frac": max(0.0, wrapped / bare - 1.0),
        "bare_step_us": bare * 1e6,
        "wrapped_step_us": wrapped * 1e6,
        "checkpoint_ns": ckpt_ns[0],
        "step_s": step_s, "steps": steps, "repeat": max(1, repeat),
    }


# --------------------------------------------------------------------------- #
# sim-event engine throughput
# --------------------------------------------------------------------------- #
def _count_events(sim) -> SimpleNamespace:
    """Event counter: use the engine's native counter when present, else
    count heap posts (every drained event was posted exactly once)."""
    if hasattr(sim, "events_processed"):
        return SimpleNamespace(value=lambda: sim.events_processed)
    posted = [0]
    orig = sim._post

    def post(t, fn):
        posted[0] += 1
        orig(t, fn)

    sim._post = post
    return SimpleNamespace(value=lambda: posted[0])


def bench_sim_events(kind: str, *, scale: float, repeat: int = 2) -> dict:
    """Best-of-``repeat`` samples: the sim is deterministic, so run-to-run
    spread is host noise and the max is the least-noisy estimate."""
    best = None
    for _ in range(max(1, repeat)):
        r = _bench_sim_events_once(kind, scale=scale)
        if best is None or r["events_per_sec"] > best["events_per_sec"]:
            best = r
    return best


def _bench_sim_events_once(kind: str, *, scale: float) -> dict:
    from repro.core import simtask as st
    from repro.core.events import SimExecutor

    n_tasks = max(8, int(64 * scale))
    n_iters = max(20, int(200 * scale))
    if kind == "yield_churn":
        sim = SimExecutor(Topology(16, 2), SchedCoop(quantum=0.02),
                          max_time=1e9)
    elif kind == "fair_ticks":
        sim = SimExecutor(Topology(16, 2), SchedFair(slice_s=0.003),
                          max_time=1e9)
    else:
        raise ValueError(kind)
    counter = _count_events(sim)
    jobs = [Job(f"ev-{kind}-{i}") for i in range(4)]

    def body():
        if kind == "yield_churn":
            for _ in range(n_iters):
                yield st.compute(1e-4)
                yield st.yield_()
        else:  # fair_ticks: long compute segments => tick/preempt traffic
            for _ in range(n_iters):
                yield st.compute(5e-3)
                yield st.sleep(1e-4)

    for i in range(n_tasks):
        sim.spawn(jobs[i % len(jobs)], body)
    t0 = time.perf_counter()
    stats = sim.run()
    wall = time.perf_counter() - t0
    events = counter.value()
    return {
        "events": events,
        "wall_s": wall,
        "events_per_sec": events / wall if wall else 0.0,
        "sim_makespan": stats.makespan,
        "dispatches": stats.dispatches,
    }


def check_gate(results: dict, baseline_path: str, max_drop: float) -> list[str]:
    """Compare the gated metrics against a committed baseline; returns a
    list of failure messages (empty = gate passed). Three gate shapes:

    * throughput keys (the default): ops/sec must stay within
      ``max_drop`` (or the per-key override) of the baseline;
    * ``sched.auto_ckpt_overhead``: overhead_frac must stay under the
      ABSOLUTE ``AUTO_CKPT_OVERHEAD_CEILING`` — baseline-independent;
    * ``sched.urgent_preempt_latency``: p50 must stay under
      max(RATIO x baseline p50, FLOOR) — latency, lower-is-better."""
    with open(baseline_path) as f:
        baseline = json.load(f)["results"]
    failures = []
    for key in GATED_KEYS:
        base = baseline.get(key)
        cur = results.get(key)
        if cur is None:
            continue
        if key == "sched.auto_ckpt_overhead":
            frac = cur["overhead_frac"]
            ceiling = AUTO_CKPT_OVERHEAD_CEILING
            verdict = "ok" if frac <= ceiling else "FAIL"
            print(f"gate {key}: wrapped-step overhead {frac:.2%} "
                  f"(absolute ceiling {ceiling:.0%}) {verdict}")
            if frac > ceiling:
                failures.append(
                    f"{key} over ceiling: {frac:.2%} > {ceiling:.0%} "
                    f"(bare {cur['bare_step_us']:.1f}us vs wrapped "
                    f"{cur['wrapped_step_us']:.1f}us per step)")
            continue
        if base is None:
            continue
        if key == "sched.urgent_preempt_latency":
            ceiling = max(URGENT_LATENCY_RATIO * base["p50_s"],
                          URGENT_LATENCY_FLOOR_S)
            verdict = "ok" if cur["p50_s"] <= ceiling else "FAIL"
            print(f"gate {key}: p50 {cur['p50_s'] * 1e6:,.0f}us vs baseline "
                  f"{base['p50_s'] * 1e6:,.0f}us "
                  f"(ceiling {ceiling * 1e6:,.0f}us) {verdict}")
            if cur["p50_s"] > ceiling:
                failures.append(
                    f"{key} regressed: p50 {cur['p50_s'] * 1e6:,.0f}us > "
                    f"ceiling {ceiling * 1e6:,.0f}us "
                    f"(baseline {base['p50_s'] * 1e6:,.0f}us)")
            continue
        drop = GATE_DROP_OVERRIDES.get(key, max_drop)
        floor = (1.0 - drop) * base["ops_per_sec"]
        verdict = "ok" if cur["ops_per_sec"] >= floor else "FAIL"
        print(f"gate {key}: {cur['ops_per_sec']:,.0f} ops/s vs baseline "
              f"{base['ops_per_sec']:,.0f} (floor {floor:,.0f}) {verdict}")
        if cur["ops_per_sec"] < floor:
            failures.append(
                f"{key} dropped >{drop:.0%}: {cur['ops_per_sec']:,.0f} "
                f"< {floor:,.0f} ops/s (baseline {base['ops_per_sec']:,.0f})"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_sched_ops.json, "
                         "or BENCH_sched_ops.smoke.json with --smoke)")
    ap.add_argument("--ready", type=int, default=256,
                    help="ready-pool size for the policy-op benchmarks")
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes; checks the bench runs, not the perf")
    ap.add_argument("--gate", metavar="BASELINE_JSON", default=None,
                    help="fail (exit 1) if SCHED_FAIR/SCHED_COOP pick-cycle "
                         "throughput drops more than --gate-drop below this "
                         "baseline (gated benches run at the baseline's "
                         "pool size even with --smoke)")
    ap.add_argument("--gate-drop", type=float, default=0.30,
                    help="max allowed fractional drop vs the baseline")
    args = ap.parse_args(argv)

    scale = 0.25 if args.smoke else 1.0
    n_ready = max(16, int(args.ready * (0.25 if args.smoke else 1.0)))
    iters_hint = 50 if args.smoke else 500

    gate_baseline = None
    if args.gate:
        with open(args.gate) as f:
            gate_baseline = json.load(f)["results"]

    repeat = 1 if args.smoke else 3
    results: dict = {}
    for pol in ("fair", "coop", "rr"):
        key = f"policy.{pol}.pick_cycle"
        pol_ready, pol_iters, pol_repeat = n_ready, iters_hint, repeat
        if gate_baseline is not None and key in GATED_KEYS:
            # gated benches are measured at the baseline's pool size with
            # best-of-3 sampling even in smoke mode: the gate compares
            # best-of-N against best-of-N on a noisy shared host
            base = gate_baseline.get(key)
            if base is not None and "n_ready" in base:
                pol_ready, pol_iters, pol_repeat = base["n_ready"], 500, 3
        r = bench_policy(pol, n_ready=pol_ready, n_slots=args.slots,
                         iters_hint=pol_iters, repeat=pol_repeat)
        results[key] = r
        print(f"{key}: {r['ops_per_sec']:,.0f} ops/s "
              f"(ready={r['n_ready']})")
    r = bench_arbiter_cycle(n_ready=n_ready, n_slots=args.slots,
                            iters_hint=iters_hint, repeat=repeat)
    results["policy.arbiter2.pick_cycle"] = r
    print(f"policy.arbiter2.pick_cycle: {r['ops_per_sec']:,.0f} ops/s "
          f"(ready={r['n_ready']}, coop+fair two-level)")
    r = bench_migration_churn(n_ready=n_ready, n_slots=args.slots,
                              iters_hint=max(3, iters_hint // 10),
                              repeat=repeat)
    results["sched.migration_churn"] = r
    print(f"sched.migration_churn: {r['ops_per_sec']:,.0f} re-homes/s "
          f"({r['tasks_migrated_per_sec']:,.0f} task-migrations/s at "
          f"pool {r['n_ready']})")
    r = bench_tick_driver(n_timers=500 if args.smoke else 5000,
                          repeat=1 if args.smoke else 3)
    results["sched.tick_driver"] = r
    print(f"sched.tick_driver: {r['ops_per_sec']:,.0f} timer-fires/s "
          f"({r['n_timers']} timers, one watchdog thread)")
    # gated even in smoke mode: best-of-3 against a best-of-3 baseline
    r = bench_preempt_cycle(
        duration=0.3 if args.smoke else 1.0,
        repeat=3 if (args.gate or not args.smoke) else 1)
    results["sched.preempt_cycle"] = r
    print(f"sched.preempt_cycle: {r['ops_per_sec']:,.0f} preemptions/s "
          f"(real threads, 1 slot, slice {0.002}s, best of "
          f"{r['repeat']})")
    r = bench_urgent_preempt_latency(trials=10 if args.smoke else 50)
    results["sched.urgent_preempt_latency"] = r
    print(f"sched.urgent_preempt_latency: p50 {r['p50_s'] * 1e6:,.0f}us "
          f"p99 {r['p99_s'] * 1e6:,.0f}us max {r['max_s'] * 1e6:,.0f}us "
          f"({r['trials']} trials, {r['urgent_grants']} urgent grants)")
    # gated even in smoke mode: absolute ceiling, best-of-3 when gating
    r = bench_auto_ckpt_overhead(
        steps=500 if args.smoke else 2000,
        repeat=3 if (args.gate or not args.smoke) else 1)
    results["sched.auto_ckpt_overhead"] = r
    print(f"sched.auto_ckpt_overhead: {r['overhead_frac']:.2%} per step "
          f"(bare {r['bare_step_us']:.1f}us -> wrapped "
          f"{r['wrapped_step_us']:.1f}us, checkpoint "
          f"{r['checkpoint_ns']:,.0f}ns, best of {r['repeat']})")
    for kind in ("yield_churn", "fair_ticks"):
        r = bench_sim_events(kind, scale=scale,
                             repeat=1 if args.smoke else 2)
        results[f"sim.{kind}"] = r
        print(f"sim.{kind}: {r['events_per_sec']:,.0f} events/s "
              f"({r['events']} events in {r['wall_s']:.2f}s)")

    payload = {
        "bench": "sched_ops",
        "smoke": args.smoke,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "results": results,
    }
    write_artifact(default_out("sched_ops", args.smoke, args.out), payload)

    if args.gate:
        failures = check_gate(results, args.gate, args.gate_drop)
        if failures:
            for msg in failures:
                print(f"PERF GATE FAILURE: {msg}", file=sys.stderr)
            return 1
        print("perf gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
