"""Scheduler-ops microbenchmark — the perf gate for the USF hot path.

Measures, in isolation from any workload semantics:

  * **scheduler-ops/sec per policy**: one "op" is a full
    ``pick -> on_run -> on_stop -> on_ready`` cycle against a ready pool
    held at a constant size (default 256 tasks, the oversubscription
    regime the paper's Fig. 3 heatmap stresses);
  * **sim-events/sec**: events drained per wall second by ``SimExecutor``
    on two representative event mixes (cooperative yield churn and a
    preemptive tick-heavy compute load).

Run it from the repo root:

    PYTHONPATH=src python -m benchmarks.sched_ops            # full
    PYTHONPATH=src python -m benchmarks.sched_ops --smoke    # CI smoke

Writes ``BENCH_sched_ops.json`` (override with ``--out``) so the perf
trajectory is machine-tracked PR over PR. Numbers are wall-clock and thus
machine-dependent; compare ratios on the same host, not absolutes.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from types import SimpleNamespace

from repro.core.policies import SchedCoop, SchedFair, SchedRR
from repro.core.policies.base import StopReason
from repro.core.task import Job, Task
from repro.core.topology import Topology

MIN_SAMPLE_S = 0.5  # keep timing chunks above this to dampen jitter


def _ops_per_sec(cycle, iters_hint: int) -> tuple[float, int]:
    """Run ``cycle(i)`` repeatedly until MIN_SAMPLE_S elapsed; return
    (ops/sec, total iterations)."""
    done = 0
    t0 = time.perf_counter()
    while True:
        for _ in range(iters_hint):
            cycle(done)
            done += 1
        dt = time.perf_counter() - t0
        if dt >= MIN_SAMPLE_S:
            return done / dt, done


def _make_policy(name: str):
    if name == "coop":
        return SchedCoop(quantum=0.02)
    if name == "fair":
        return SchedFair(slice_s=0.003)
    if name == "rr":
        return SchedRR(quantum=0.01)
    raise ValueError(name)


def bench_policy(name: str, *, n_ready: int, n_slots: int,
                 iters_hint: int) -> dict:
    """Steady-state pick/requeue churn with the pool held at ``n_ready``."""
    topo = Topology(n_slots, 2 if n_slots % 2 == 0 else 1)
    policy = _make_policy(name)
    # policies only need `.topology` off the scheduler at pick time
    policy.attach(SimpleNamespace(topology=topo))
    jobs = [Job(f"bench-j{i}") for i in range(4)]
    tasks = [Task(jobs[i % len(jobs)], name=f"b{i}") for i in range(n_ready)]
    for i, t in enumerate(tasks):
        # mix of affine / unaffine tasks, spread over slots like a real pool
        t.last_slot = None if i % 7 == 0 else i % n_slots
    for t in tasks:
        policy.on_ready(t)

    state = {"now": 0.0}

    def cycle(i: int) -> None:
        slot = i % n_slots
        task = policy.pick(slot)
        now = state["now"]
        policy.on_run(task, slot, now)
        state["now"] = now = now + 0.0005
        task.last_slot = slot
        policy.on_stop(task, slot, now, 0.0005, StopReason.BLOCK)
        policy.on_ready(task)

    ops, iters = _ops_per_sec(cycle, iters_hint)
    assert policy.ready_count() == n_ready, "pool size drifted"
    return {"ops_per_sec": ops, "iterations": iters,
            "n_ready": n_ready, "n_slots": n_slots}


# --------------------------------------------------------------------------- #
# sim-event engine throughput
# --------------------------------------------------------------------------- #
def _count_events(sim) -> SimpleNamespace:
    """Event counter: use the engine's native counter when present, else
    count heap posts (every drained event was posted exactly once)."""
    if hasattr(sim, "events_processed"):
        return SimpleNamespace(value=lambda: sim.events_processed)
    posted = [0]
    orig = sim._post

    def post(t, fn):
        posted[0] += 1
        orig(t, fn)

    sim._post = post
    return SimpleNamespace(value=lambda: posted[0])


def bench_sim_events(kind: str, *, scale: float, repeat: int = 2) -> dict:
    """Best-of-``repeat`` samples: the sim is deterministic, so run-to-run
    spread is host noise and the max is the least-noisy estimate."""
    best = None
    for _ in range(max(1, repeat)):
        r = _bench_sim_events_once(kind, scale=scale)
        if best is None or r["events_per_sec"] > best["events_per_sec"]:
            best = r
    return best


def _bench_sim_events_once(kind: str, *, scale: float) -> dict:
    from repro.core import simtask as st
    from repro.core.events import SimExecutor

    n_tasks = max(8, int(64 * scale))
    n_iters = max(20, int(200 * scale))
    if kind == "yield_churn":
        sim = SimExecutor(Topology(16, 2), SchedCoop(quantum=0.02),
                          max_time=1e9)
    elif kind == "fair_ticks":
        sim = SimExecutor(Topology(16, 2), SchedFair(slice_s=0.003),
                          max_time=1e9)
    else:
        raise ValueError(kind)
    counter = _count_events(sim)
    jobs = [Job(f"ev-{kind}-{i}") for i in range(4)]

    def body():
        if kind == "yield_churn":
            for _ in range(n_iters):
                yield st.compute(1e-4)
                yield st.yield_()
        else:  # fair_ticks: long compute segments => tick/preempt traffic
            for _ in range(n_iters):
                yield st.compute(5e-3)
                yield st.sleep(1e-4)

    for i in range(n_tasks):
        sim.spawn(jobs[i % len(jobs)], body)
    t0 = time.perf_counter()
    stats = sim.run()
    wall = time.perf_counter() - t0
    events = counter.value()
    return {
        "events": events,
        "wall_s": wall,
        "events_per_sec": events / wall if wall else 0.0,
        "sim_makespan": stats.makespan,
        "dispatches": stats.dispatches,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_sched_ops.json")
    ap.add_argument("--ready", type=int, default=256,
                    help="ready-pool size for the policy-op benchmarks")
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes; checks the bench runs, not the perf")
    args = ap.parse_args(argv)

    scale = 0.25 if args.smoke else 1.0
    n_ready = max(16, int(args.ready * (0.25 if args.smoke else 1.0)))
    iters_hint = 50 if args.smoke else 500

    results: dict = {}
    for pol in ("fair", "coop", "rr"):
        r = bench_policy(pol, n_ready=n_ready, n_slots=args.slots,
                         iters_hint=iters_hint)
        results[f"policy.{pol}.pick_cycle"] = r
        print(f"policy.{pol}.pick_cycle: {r['ops_per_sec']:,.0f} ops/s "
              f"(ready={r['n_ready']})")
    for kind in ("yield_churn", "fair_ticks"):
        r = bench_sim_events(kind, scale=scale,
                             repeat=1 if args.smoke else 2)
        results[f"sim.{kind}"] = r
        print(f"sim.{kind}: {r['events_per_sec']:,.0f} events/s "
              f"({r['events']} events in {r['wall_s']:.2f}s)")

    payload = {
        "bench": "sched_ops",
        "smoke": args.smoke,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
