"""Co-located-job share sweep — the two-level scheduler's headline demo.

Two jobs share one node through the SlotArbiter while running *different*
intra-job policies (true multi-runtime mixing, the paper's §5 co-location
scenarios): job A is a SCHED_COOP runtime (nested-BLAS-style cooperative
tasks), job B a SCHED_FAIR runtime (the preemptive Linux-baseline stand-in,
e.g. a co-located multi-process inference fleet). Both are kept saturated
(more ready tasks than slots) and the sweep varies the lease share split,
measuring each job's realized service-time fraction over a fixed virtual
horizon.

Claims demonstrated:

  * **share enforcement**: realized service fractions track the lease
    quotas across the sweep (I5: neither job is granted slots beyond its
    lease while the sibling has ready work and spare lease);
  * **I2 per job**: the SCHED_COOP job is never preempted even though the
    co-located SCHED_FAIR job takes preemption ticks on its own slots;
  * **work-conserving borrowing**: when one job goes idle, the other's
    throughput expands to the whole node (no static-partition waste);
  * **elastic leases**: a mid-run ``lease.resize()`` shifts the split.

Run:  PYTHONPATH=src python -m benchmarks.colocation [--smoke]
Writes BENCH_colocation.json.
"""

from __future__ import annotations

import argparse
import json
import sys

from benchmarks.common import default_out, summarize_latencies, write_artifact
from repro.core import simtask as st
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair
from repro.core.task import Job
from repro.core.topology import Topology

N_SLOTS = 16
N_DOMAINS = 2
HORIZON = 2.0          # virtual seconds per cell
TASKS_PER_JOB = 32     # > n_slots: both jobs stay saturated


def _churn_body(compute: float, pause: float):
    """Endless compute/sleep churn: frequent scheduling points, always
    re-ready — the saturated co-location regime."""

    def gen():
        while True:
            yield st.compute(compute)
            yield st.sleep(pause)

    return gen


def _run_cell(share_a: float, share_b: float, *, horizon: float,
              idle_b: bool = False) -> dict:
    sim = SimExecutor(Topology(N_SLOTS, N_DOMAINS), SchedCoop(quantum=0.02),
                      max_time=1e9)
    job_a = Job("coop-blas")
    job_b = Job("fair-procs")
    lease_a = sim.attach(job_a, policy=SchedCoop(quantum=0.02), share=share_a)
    lease_b = sim.attach(job_b, policy=SchedFair(slice_s=0.003), share=share_b)
    for _ in range(TASKS_PER_JOB):
        sim.spawn(job_a, _churn_body(0.002, 0.0005))
        if not idle_b:
            sim.spawn(job_b, _churn_body(0.002, 0.0005))
    sim.run(until=horizon)
    total = job_a.service_time + job_b.service_time
    preempt_a = sum(t.stats.preemptions for t in job_a.tasks)
    preempt_b = sum(t.stats.preemptions for t in job_b.tasks)
    # per-task mean ready->dispatch wait: the grant-order latency each
    # job's tasks actually saw under this split (same summary shape as
    # the microservices / faults artifacts)
    waits = {
        name: summarize_latencies(
            [t.stats.wait_time / t.stats.dispatches
             for t in job.tasks if t.stats.dispatches],
            prefix="wait_", round_to=6)
        for name, job in (("coop", job_a), ("fair", job_b))
    }
    return {
        **{f"{name}_{k}": v for name, s in waits.items()
           for k, v in s.items()},
        "share_a": share_a,
        "share_b": share_b,
        "quota_a": lease_a.quota,
        "quota_b": lease_b.quota,
        "service_a": round(job_a.service_time, 6),
        "service_b": round(job_b.service_time, 6),
        "frac_a": round(job_a.service_time / total, 4) if total else 0.0,
        "frac_b": round(job_b.service_time / total, 4) if total else 0.0,
        "preemptions_coop": preempt_a,
        "preemptions_fair": preempt_b,
        "busy_fraction": round(total / (horizon * N_SLOTS), 4),
    }


def _run_resize_cell(*, horizon: float) -> dict:
    """Elastic lease demo: start 1:1, resize to 3:1 at the half-way point;
    the per-window service split follows the lease."""
    sim = SimExecutor(Topology(N_SLOTS, N_DOMAINS), SchedCoop(quantum=0.02),
                      max_time=1e9)
    job_a = Job("coop-blas")
    job_b = Job("fair-procs")
    lease_a = sim.attach(job_a, policy=SchedCoop(quantum=0.02), share=1.0)
    sim.attach(job_b, policy=SchedFair(slice_s=0.003), share=1.0)
    for _ in range(TASKS_PER_JOB):
        sim.spawn(job_a, _churn_body(0.002, 0.0005))
        sim.spawn(job_b, _churn_body(0.002, 0.0005))
    sim.run(until=horizon / 2)
    w1 = (job_a.service_time, job_b.service_time)
    lease_a.resize(3.0)  # elastic grant: reclaim from B at sched points
    sim.run(until=horizon)
    w2 = (job_a.service_time - w1[0], job_b.service_time - w1[1])
    return {
        "window1_frac_a": round(w1[0] / (w1[0] + w1[1]), 4),
        "window2_frac_a": round(w2[0] / (w2[0] + w2[1]), 4),
        "resized_share_a": 3.0,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_colocation.json, "
                         "or BENCH_colocation.smoke.json with --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="short horizon; checks the bench runs")
    args = ap.parse_args(argv)
    horizon = 0.5 if args.smoke else HORIZON

    sweep = []
    print(f"{'shares':>8} {'quotas':>7} {'frac A':>7} {'frac B':>7} "
          f"{'pre(coop)':>9} {'pre(fair)':>9} {'busy':>6}")
    for share_a, share_b in ((1, 7), (1, 3), (1, 1), (3, 1), (7, 1)):
        cell = _run_cell(float(share_a), float(share_b), horizon=horizon)
        sweep.append(cell)
        print(f"{share_a}:{share_b:>6} {cell['quota_a']:>3}:{cell['quota_b']:<3} "
              f"{cell['frac_a']:>7.3f} {cell['frac_b']:>7.3f} "
              f"{cell['preemptions_coop']:>9} {cell['preemptions_fair']:>9} "
              f"{cell['busy_fraction']:>6.3f}")
        assert cell["preemptions_coop"] == 0, "I2: coop job was preempted"

    borrow = _run_cell(1.0, 7.0, horizon=horizon, idle_b=True)
    print(f"borrowing (B idle, A share 1/8): A busy-fraction "
          f"{borrow['service_a'] / (horizon * N_SLOTS):.3f} "
          f"(lease quota only {borrow['quota_a']}/{N_SLOTS} slots)")

    resize = _run_resize_cell(horizon=horizon)
    print(f"elastic resize 1:1 -> 3:1 mid-run: frac A "
          f"{resize['window1_frac_a']:.3f} -> {resize['window2_frac_a']:.3f}")

    payload = {
        "bench": "colocation",
        "smoke": args.smoke,
        "n_slots": N_SLOTS,
        "horizon_s": horizon,
        "sweep": sweep,
        "borrowing": borrow,
        "elastic_resize": resize,
    }
    write_artifact(default_out("colocation", args.smoke, args.out), payload)
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
