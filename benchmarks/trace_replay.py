"""Trace record/replay benchmark — the simulation-substrate perf gate.

Measures the four properties the trace subsystem claims:

  * **replay throughput** (``trace.replay_events_per_sec``, GATED): engine
    events drained per wall second replaying the synthesized ≈1.36M-event
    colocation trace under the default SCHED_COOP config — the
    ≥500k events/s substrate number. Best-of-N on an otherwise-idle host;
    the CI gate compares against the committed baseline at a 30% band.
    The same trace under SCHED_FAIR is reported ungated (tick/EEVDF
    overhead makes it a different regime, tracked not gated).
  * **decode throughput**: records/s loading a saved workload trace from
    JSONL back into replayable form (the batch-decode path).
  * **recorder overhead**: interleaved A/B ratios — disarmed-vs-disarmed
    (the noise floor, ~1.0x by construction: disarmed runs carry no
    recorder code on the op path at all), armed-vs-disarmed on a
    dispatch-heavy live sim (the decision-hook cost on the pick/dispatch
    cycle — the <5% criterion), and armed-vs-disarmed on the full replay
    (op recording included; informational).
  * **determinism**: same trace + same config replayed twice ⇒
    bit-identical decision streams, and record→reconstruct→replay is a
    fixed point. Asserted on every run, including smoke.

Plus the **policy A/B**: the PR 7 open-arrival SLO sweep rebuilt on the
replayer — one workload per offered load, replayed under deadline-aware
vs share-only arbitration (the only changed variable), 10⁵ requests per
cell in the full run — reproducing the deadline-aware-wins headline from
replayed traces.

    PYTHONPATH=src python -m benchmarks.trace_replay            # full
    PYTHONPATH=src python -m benchmarks.trace_replay --smoke    # CI smoke
    PYTHONPATH=src python -m benchmarks.trace_replay --smoke \
        --gate BENCH_trace_replay.json                          # perf gate

Writes ``BENCH_trace_replay.json`` (``--out`` overrides). Wall-clock
numbers are machine-dependent; compare ratios on the same host.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time

from benchmarks.common import default_out, summarize_latencies, write_artifact
from repro.trace import ReplayConfig, Replayer, TraceRecorder, reconstruct
from repro.trace import synth
from repro.trace.ab import measure_side, slo_ab_configs
from repro.trace.replayer import Workload, diff_streams

GATED_KEYS = ("trace.replay_events_per_sec",)
GATE_DROP_OVERRIDES: dict = {}

#: smoke-sized colocation trace (~88k events, sub-second replay)
SMOKE_SHAPE = dict(n_requests=2_000, rate=250.0, batch_segments=600)


def _colo(smoke: bool) -> Workload:
    return synth.colocation_workload(**(SMOKE_SHAPE if smoke else {}))


# --------------------------------------------------------------------- #
# replay throughput
# --------------------------------------------------------------------- #
def bench_replay(workload: Workload, config: ReplayConfig,
                 *, repeat: int = 3) -> dict:
    """Best-of-``repeat``: replay is deterministic, so run-to-run spread
    is host noise and the max is the least-noisy estimate (same
    reasoning as sched_ops.bench_sim_events)."""
    best = None
    for _ in range(max(1, repeat)):
        res = Replayer(workload, config).run()
        if best is None or res.events_per_sec > best.events_per_sec:
            best = res
    return {"events_per_sec": best.events_per_sec, "events": best.events,
            "wall_s": round(best.wall_s, 4), "tasks": len(workload.tasks),
            "ops": workload.n_ops(), "repeat": repeat,
            "policy": config.default_policy[0]}


# --------------------------------------------------------------------- #
# decode throughput
# --------------------------------------------------------------------- #
def bench_decode(workload: Workload) -> dict:
    """Save the workload to JSONL, then time the load (parse + batch
    decode into replayable op tuples)."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "trace.jsonl")
        n = workload.save(path)
        size = os.path.getsize(path)
        t0 = time.perf_counter()
        loaded = Workload.load(path)
        dt = time.perf_counter() - t0
    assert len(loaded.tasks) == len(workload.tasks)
    return {"records": n, "ops": loaded.n_ops(),
            "records_per_sec": n / dt, "ops_per_sec": loaded.n_ops() / dt,
            "bytes": size, "wall_s": round(dt, 4)}


# --------------------------------------------------------------------- #
# recorder overhead
# --------------------------------------------------------------------- #
def _decisions_only(rep: Replayer):
    """Replay with decision hooks armed but op recording off (the live
    monitoring configuration)."""
    rec = TraceRecorder()
    # mirror Replayer.run(record=True) but arm decisions only
    orig_attach = rec.attach_sim
    rec.attach_sim = lambda sim, ops=True: orig_attach(sim, ops=False)
    try:
        return rep.run(recorder=rec)
    finally:
        rec.attach_sim = orig_attach


def bench_recorder_overhead(workload: Workload, config: ReplayConfig,
                            *, rounds: int = 3) -> dict:
    """Interleaved A/B: alternate configurations round by round so slow
    host drift hits both sides equally; compare best-of-rounds."""
    disarmed_a, disarmed_b, decisions, full = [], [], [], []
    rep = Replayer(workload, config)
    for _ in range(max(1, rounds)):
        disarmed_a.append(rep.run().events_per_sec)
        decisions.append(_decisions_only(rep).events_per_sec)
        full.append(rep.run(record=True).events_per_sec)
        disarmed_b.append(rep.run().events_per_sec)
    da, db = max(disarmed_a), max(disarmed_b)
    dec, fl = max(decisions), max(full)
    return {
        # disarmed vs disarmed: the noise floor (~1.0 by construction —
        # the disarmed op path carries no recorder code at all)
        "disarmed_ab_ratio": round(da / db, 4),
        "events_per_sec_disarmed": max(da, db),
        # decision hooks only: the armed cost on the pick/dispatch cycle
        "events_per_sec_decisions": dec,
        "decision_overhead_frac": round(1.0 - dec / max(da, db), 4),
        # full op recording: the replayable-trace configuration
        "events_per_sec_armed_full": fl,
        "full_overhead_frac": round(1.0 - fl / max(da, db), 4),
        "rounds": rounds,
    }


def _emit_ns_per_record(*, n: int = 1_000_000, repeat: int = 5) -> float:
    """Tight-loop cost of one armed decision record — tuple build + the
    memory-mode ``emit`` (a bare C-level ``deque.append``). This is the
    per-record cost the hot paths actually pay, and unlike the live A/B it
    is measurable to a few ns on a noisy host (best-of-``repeat``)."""
    rec = TraceRecorder()
    emit = rec.emit
    best = float("inf")
    for _ in range(max(1, repeat)):
        rec._ring.clear()
        t0 = time.process_time()
        for i in range(n):
            emit((0.5, 2, i, 7))
        best = min(best, time.process_time() - t0)
    rec._ring.clear()
    return best / n * 1e9


def bench_armed_pick_cycle(*, duration_s: float = 0.25,
                           repeat: int = 10) -> dict:
    """The <5% criterion, measured where the hook lives: a dispatch-heavy
    yield-churn sim (every event crosses ``_run_on``/``_stop_running``,
    the recorded pick cycle — 2 decision records per event, the worst
    case) armed with decision hooks vs disarmed.

    The armed side runs the real streaming configuration — a file-backed
    recorder whose background writer drains the ring — so the producer
    pays exactly the hot-path cost (tuple + C-level append) and drained
    tuples are recycled by the allocator, as in a live monitored run
    (memory mode retains every record, which measurably inflates armed
    allocation cost and is NOT how monitoring deployments run).

    The effect is a few percent and this host's A/B jitter is ±5-10%
    even with scheduler-thread CPU time (``time.thread_time`` — charges
    the hot path, not the background flusher on its own core), GC paused
    across each timed region, alternating back-to-back pairs, and a
    SUM-over-SUM aggregate ratio — the live A/B cannot resolve a 4%
    effect under that floor, so it is reported raw (with its per-pair
    spread) as corroboration. The headline ``overhead_frac`` is instead
    the DECOMPOSITION, every factor of which is directly measured and
    stable to a few tenths of a percent:

        records/event (counted in the armed runs)
          x ns/record  (tight-loop cost of the actual armed emit)
          x disarmed events/s

    i.e. exactly the extra scheduler-thread CPU the armed hooks add per
    event, at the rate the disarmed hot path actually runs."""
    import gc
    import os
    import statistics
    import tempfile

    from repro.core import simtask as st
    from repro.core.events import SimExecutor
    from repro.core.policies import SchedCoop
    from repro.core.task import Job
    from repro.core.topology import Topology

    def build(n_iters: int):
        sim = SimExecutor(Topology(8, 2), SchedCoop(quantum=0.02),
                          max_time=1e9)
        job = Job("churn")

        def body():
            for _ in range(n_iters):
                yield st.compute(0.0005)
                yield st.yield_()

        for _ in range(32):
            sim.spawn(job, body)
        return sim

    def timed_run(n_iters: int, armed: bool):
        sim = build(n_iters)
        rec = tmp = None
        if armed:
            fd, tmp = tempfile.mkstemp(suffix=".jsonl")
            os.close(fd)
            rec = TraceRecorder(tmp).attach_sim(sim, ops=False)
        gc_was_on = gc.isenabled()
        gc.collect()
        gc.disable()
        try:
            t0 = time.thread_time()
            sim.run()
            dt = time.thread_time() - t0
        finally:
            if gc_was_on:
                gc.enable()
            n_rec = 0
            if rec is not None:
                rec.close()  # flushes: emitted == total records
                n_rec = rec.emitted
                os.unlink(tmp)
        return sim.events_processed, dt, n_rec

    # size each timed region to ~duration_s from a quick probe
    probe = build(50)
    t0 = time.perf_counter()
    probe.run()
    dt = time.perf_counter() - t0
    n_iters = max(100, int(50 * duration_s / dt))

    timed_run(n_iters, False)  # warm caches/allocator before measuring
    timed_run(n_iters, True)
    ev = {False: 0, True: 0}
    cpu = {False: 0.0, True: 0.0}
    records = 0
    ratios = []
    for rnd in range(max(1, repeat)):
        order = (False, True) if rnd % 2 == 0 else (True, False)
        pair = {}
        for is_armed in order:
            n, dt, n_rec = timed_run(n_iters, is_armed)
            ev[is_armed] += n
            cpu[is_armed] += dt
            records += n_rec
            pair[is_armed] = n / dt
        ratios.append(pair[True] / pair[False])
    d = ev[False] / cpu[False]
    a = ev[True] / cpu[True]
    ns_rec = _emit_ns_per_record()
    rec_per_ev = records / ev[True]
    return {"events_per_sec_disarmed": d, "events_per_sec_armed": a,
            # headline: the measured decomposition (see docstring)
            "overhead_frac": round(rec_per_ev * ns_rec * 1e-9 * d, 4),
            "ns_per_record": round(ns_rec, 1),
            "records_per_event": round(rec_per_ev, 3),
            # the raw live A/B, for corroboration — noise floor ±5-10%
            # on this host, so do not gate on it
            "live_ab_overhead_frac": round(1.0 - a / d, 4),
            "round_ratios": [round(x, 4) for x in ratios],
            "ratio_spread": round(statistics.pstdev(ratios), 4),
            "repeat": repeat}


# --------------------------------------------------------------------- #
# determinism
# --------------------------------------------------------------------- #
def bench_determinism(workload: Workload, config: ReplayConfig) -> dict:
    """Replay twice and diff; then reconstruct the re-recording into a
    workload, replay THAT, and check the fixed point. Raises on any
    divergence — determinism is an assertion, not a statistic."""
    r1 = Replayer(workload, config).run(record=True)
    r2 = Replayer(workload, config).run(record=True)
    d = diff_streams(r1.normalized_records(), r2.normalized_records())
    if d is not None:
        raise AssertionError(f"replay-replay divergence: {d}")

    wl2 = reconstruct(r1.recorder.records())
    r3 = Replayer(wl2, config).run(record=True)
    # r3's trace ids are r1's live ids; fold back into workload id space
    from repro.trace.replayer import normalize_stream
    rec3 = normalize_stream(r3.normalized_records(), r1.tid_of, r1.jid_of)
    d = diff_streams(r1.normalized_records(), rec3)
    if d is not None:
        raise AssertionError(f"record->reconstruct->replay diverged: {d}")
    from repro.trace.replayer import decision_stream
    return {"decisions": len(decision_stream(r1.normalized_records())),
            "events": r1.events, "fixed_point": True}


# --------------------------------------------------------------------- #
# policy A/B: the SLO sweep, replayed
# --------------------------------------------------------------------- #
def run_slo_ab(loads, *, n_requests: int, seed: int = 0) -> dict:
    """The PR 7 sweep on the replayer: per offered load, ONE workload
    replayed under deadline-aware vs share-only arbitration."""
    cfg_dl, cfg_sh = slo_ab_configs()
    rows, wins = [], []
    print("arbiter,load,requests,lat_p99,miss_rate,events,kev_s")
    for load in loads:
        wl = synth.slo_workload(load, n_requests=n_requests, seed=seed)
        horizon = wl.meta["horizon"]
        pair = {}
        for name, cfg in (("deadline", cfg_dl), ("share", cfg_sh)):
            side = measure_side(name, wl, cfg, until=horizon + 5.0)
            lat = summarize_latencies(side.latencies, prefix="lat_")
            row = {"arbiter": name, "load": load,
                   "requests": side.deadline_tasks,
                   "completed": side.completed,
                   "miss_rate": round(side.miss_rate, 5),
                   "preemptions": side.preemptions,
                   "urgent_grants": side.urgent_grants,
                   "makespan": round(side.makespan, 3),
                   "events": side.events,
                   "replay_events_per_sec": round(
                       side.events / side.wall_s if side.wall_s else 0.0),
                   **lat}
            rows.append(row)
            pair[name] = row
            print(f"{name},{load},{n_requests},{row['lat_p99']:.4f},"
                  f"{row['miss_rate']:.4f},{row['events']},"
                  f"{row['replay_events_per_sec'] / 1000:.0f}",
                  flush=True)
        d, s = pair["deadline"], pair["share"]
        wins.append({
            "load": load,
            "p99_ratio": (round(s["lat_p99"] / d["lat_p99"], 3)
                          if d["lat_p99"] > 0 else None),
            "deadline_wins_p99": bool(d["lat_p99"] < s["lat_p99"]),
            "deadline_wins_miss": bool(d["miss_rate"] <= s["miss_rate"]),
        })
    n_wins = sum(1 for w in wins
                 if w["deadline_wins_p99"] and w["deadline_wins_miss"])
    print(f"# deadline-aware wins p99 AND miss rate at {n_wins}/"
          f"{len(loads)} replayed offered-load points")
    return {"loads": list(loads), "n_requests": n_requests,
            "rows": rows, "per_load": wins, "deadline_wins_both": n_wins}


# --------------------------------------------------------------------- #
# gate + main
# --------------------------------------------------------------------- #
def load_baseline(baseline_path: str) -> dict:
    """Read the committed baseline up front — a full run's default out
    path IS the baseline path, so reading after write_artifact would
    gate the run against itself."""
    with open(baseline_path) as f:
        return json.load(f)["results"]


def check_gate(results: dict, baseline: dict, max_drop: float) -> list:
    failures = []
    for key in GATED_KEYS:
        base, cur = baseline.get(key), results.get(key)
        if base is None or cur is None:
            continue
        drop = GATE_DROP_OVERRIDES.get(key, max_drop)
        floor = (1.0 - drop) * base["events_per_sec"]
        verdict = "ok" if cur["events_per_sec"] >= floor else "FAIL"
        print(f"gate {key}: {cur['events_per_sec']:,.0f} ev/s vs baseline "
              f"{base['events_per_sec']:,.0f} (floor {floor:,.0f}) {verdict}")
        if cur["events_per_sec"] < floor:
            failures.append(
                f"{key} dropped >{drop:.0%}: {cur['events_per_sec']:,.0f} "
                f"< {floor:,.0f} ev/s (baseline {base['events_per_sec']:,.0f})"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_trace_replay.json, or "
                         "BENCH_trace_replay.smoke.json with --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="small trace + tiny SLO cell; checks everything "
                         "runs and the gate, not absolute perf")
    ap.add_argument("--gate", metavar="BASELINE_JSON", default=None,
                    help="fail (exit 1) if replay throughput drops more "
                         "than --gate-drop below this baseline (the gated "
                         "bench runs the FULL trace even with --smoke)")
    ap.add_argument("--gate-drop", type=float, default=0.30)
    ap.add_argument("--slo-requests", type=int, default=None,
                    help="requests per SLO cell (default 100000 full, "
                         "300 smoke)")
    args = ap.parse_args(argv)
    out = default_out("trace_replay", args.smoke, args.out)
    baseline = load_baseline(args.gate) if args.gate else None

    results: dict = {}
    coop = ReplayConfig(slots=8, domains=2)

    # gated replay throughput: ALWAYS the full trace (a gate on the smoke
    # trace would measure startup, not the substrate)
    gated_full = not args.smoke or args.gate is not None
    wl_gate = _colo(smoke=not gated_full)
    r = bench_replay(wl_gate, coop, repeat=3 if gated_full else 1)
    results["trace.replay_events_per_sec"] = r
    print(f"trace.replay_events_per_sec: {r['events_per_sec']:,.0f} ev/s "
          f"({r['events']:,} events, best of {r['repeat']}, SCHED_COOP)")

    wl_small = _colo(smoke=True) if args.smoke else wl_gate
    if not args.smoke:
        fair = ReplayConfig(slots=8, domains=2,
                            default_policy=("SCHED_FAIR", 0.003))
        r = bench_replay(wl_small, fair, repeat=2)
        results["trace.replay_events_per_sec_fair"] = r
        print(f"trace.replay_events_per_sec_fair: "
              f"{r['events_per_sec']:,.0f} ev/s (ungated: tick/EEVDF "
              f"regime)")

    r = bench_decode(wl_small)
    results["trace.decode_records_per_sec"] = r
    print(f"trace.decode_records_per_sec: {r['records_per_sec']:,.0f} "
          f"records/s ({r['ops']:,} ops, {r['bytes'] / 1e6:.1f} MB)")

    r = bench_recorder_overhead(wl_small, coop,
                                rounds=1 if args.smoke else 3)
    results["trace.recorder_overhead"] = r
    print(f"trace.recorder_overhead: disarmed A/B "
          f"{r['disarmed_ab_ratio']:.3f}x, decisions "
          f"{r['decision_overhead_frac']:+.1%}, full op recording "
          f"{r['full_overhead_frac']:+.1%}")

    r = bench_armed_pick_cycle(duration_s=0.1 if args.smoke else 0.25,
                               repeat=3 if args.smoke else 10)
    results["trace.armed_pick_cycle"] = r
    print(f"trace.armed_pick_cycle: armed decision hooks cost "
          f"{r['overhead_frac']:+.1%} on a dispatch-heavy live sim "
          f"({r['records_per_event']:.1f} rec/event x "
          f"{r['ns_per_record']:.0f} ns/rec; <5% criterion; live A/B "
          f"{r['live_ab_overhead_frac']:+.1%} +/- "
          f"{r['ratio_spread']:.1%} noise)")

    r = bench_determinism(_colo(smoke=True), coop)
    results["trace.determinism"] = r
    print(f"trace.determinism: replay-replay and record->reconstruct->"
          f"replay bit-identical ({r['decisions']:,} decisions)")

    n_req = args.slo_requests or (300 if args.smoke else 100_000)
    loads = [0.8] if args.smoke else [0.6, 0.8, 0.95, 1.1]
    results["trace.slo_ab"] = run_slo_ab(loads, n_requests=n_req)

    payload = {
        "bench": "trace_replay",
        "smoke": args.smoke,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "results": results,
    }
    write_artifact(out, payload)

    if baseline is not None:
        failures = check_gate(results, baseline, args.gate_drop)
        if failures:
            for msg in failures:
                print(f"PERF GATE FAILURE: {msg}", file=sys.stderr)
            return 1
        print("perf gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
