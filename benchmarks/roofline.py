"""Roofline table: reads results/dryrun/*.json (produced by
``python -m repro.launch.dryrun --all``) and prints the per-cell roofline
terms, dominant bottleneck, usefulness ratio and MFU bound — the §Roofline
deliverable, consumed verbatim by EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import pathlib
import sys

DEFAULT_DIR = "results/dryrun"

COLUMNS = ("arch,shape,mesh,status,compute_s,memory_s,collective_s,"
           "dominant,useful_ratio,mfu_bound,peak_GiB,fits")


def load_rows(directory: str = DEFAULT_DIR) -> list[dict]:
    rows = []
    for p in sorted(pathlib.Path(directory).glob("*.json")):
        d = json.loads(p.read_text())
        row = {
            "arch": d["arch"],
            "shape": d["shape"],
            "mesh": d["mesh"],
            "status": d["status"],
        }
        if d["status"] == "skip":
            row["reason"] = d.get("reason", "")
        elif d["status"] == "ok" and "roofline" in d:
            r = d["roofline"]
            mem = d["full"]["memory"]
            row.update(
                compute_s=r["compute_s"],
                memory_s=r["memory_s"],
                collective_s=r["collective_s"],
                dominant=r["dominant"],
                useful_ratio=r["useful_flops_ratio"],
                mfu_bound=r["mfu_bound"],
                peak_gib=mem["peak_bytes_est"] / 2**30,
                fits=mem["peak_bytes_est"] <= mem["hbm_capacity"],
            )
        else:
            row["error"] = d.get("error", "")
        rows.append(row)
    return rows


def print_table(rows: list[dict]) -> None:
    print(COLUMNS)
    for r in rows:
        if r["status"] == "ok":
            print(f"{r['arch']},{r['shape']},{r['mesh']},ok,"
                  f"{r['compute_s']:.4f},{r['memory_s']:.4f},"
                  f"{r['collective_s']:.4f},{r['dominant']},"
                  f"{r['useful_ratio']:.3f},{r['mfu_bound']:.4f},"
                  f"{r['peak_gib']:.2f},{int(r['fits'])}")
        elif r["status"] == "skip":
            print(f"{r['arch']},{r['shape']},{r['mesh']},skip"
                  f",,,,,,,,  # {r.get('reason','')}")
        else:
            print(f"{r['arch']},{r['shape']},{r['mesh']},error"
                  f",,,,,,,,  # {r.get('error','')[:120]}")


def main() -> int:
    directory = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_DIR
    rows = load_rows(directory)
    if not rows:
        print(f"# no dry-run results under {directory}; run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all` first")
        return 1
    print_table(rows)
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["mfu_bound"])
        collb = max(ok, key=lambda r: r["collective_s"]
                    / max(r["compute_s"], 1e-12))
        print(f"# worst mfu_bound: {worst['arch']} x {worst['shape']} "
              f"@ {worst['mesh']} ({worst['mfu_bound']:.4f})")
        print(f"# most collective-bound: {collb['arch']} x {collb['shape']} "
              f"@ {collb['mesh']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
