"""Self-healing under faults — recovery latency, measured.

Two scenarios quantify the PR 6 robustness layer:

* ``broker_mttr`` — a broker *process* (real OS process, SIGKILLed) dies
  under N coordinated workers and is restarted on the same rendezvous
  path. Measured per repetition:

  - **detect**: kill → every client degraded to free-running (the outage
    is noticed; the workers are already safe — degrade is immediate, so
    this is the only window where a worker might briefly run a stale cap);
  - **rejoin**: new broker ready → every client re-registered,
    re-coordinated, grants summing to capacity under the new incarnation;
  - **MTTR**: kill → fully re-coordinated (detect + restart gap + rejoin).

* ``grant_convergence`` — lease churn against a live broker: resizes and
  worker join/leave events, each timed until every client's applied grant
  agrees with the broker and grants sum to node capacity again.

Run:  PYTHONPATH=src python -m benchmarks.faults [--smoke]
Writes BENCH_faults.json (smoke: BENCH_faults.smoke.json via
``make check``). Latency distributions are reported, not asserted — CI
hosts are noisy; the chaos suite (tests/test_chaos.py) owns the
pass/fail invariants.
"""

from __future__ import annotations

import argparse
import multiprocessing as mp
import os
import sys
import tempfile
import time

from benchmarks.common import default_out, summarize_latencies, write_artifact

_CTX = mp.get_context("spawn")

CAPACITY = 4
N_WORKERS = 4


def _path() -> str:
    return os.path.join(tempfile.mkdtemp(prefix="usf-faults-"), "broker.sock")


def _wait_until(cond, timeout, what, step=0.005):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return
        time.sleep(step)
    if not cond():
        raise RuntimeError(f"bench hung: {what} not reached in {timeout}s")


def _stats(xs) -> dict:
    # the shared benchmark summary (adds p95/p99/p999 over the old local
    # n/mean/p50/max shape, same 4-decimal rounding)
    return summarize_latencies(xs, round_to=4)


# --------------------------------------------------------------------- #
# scenario 1: broker killed + restarted — MTTR
# --------------------------------------------------------------------- #
def _broker_main(path: str, capacity: int, ready) -> None:
    """Standalone broker process (the SIGKILL victim)."""
    from repro.ipc import NodeBroker

    broker = NodeBroker(path, capacity=capacity, heartbeat_timeout=1.0)
    broker.start()
    ready.set()
    while True:  # killed, never stopped
        time.sleep(3600.0)


def _spawn_broker(path: str):
    ready = _CTX.Event()
    proc = _CTX.Process(target=_broker_main, args=(path, CAPACITY, ready),
                        daemon=True)
    proc.start()
    if not ready.wait(60.0):
        proc.kill()
        raise RuntimeError("broker process failed to come up")
    return proc


def _coordinated(clients) -> bool:
    from repro.ipc import BrokerClient

    return (all(c.state == BrokerClient.COORDINATED for c in clients)
            and sum(c.granted or 0 for c in clients) == CAPACITY
            and len({c.incarnation for c in clients}) == 1)


def run_broker_mttr(reps: int) -> dict:
    from repro.ipc import BrokerClient

    path = _path()
    proc = _spawn_broker(path)
    clients = [
        BrokerClient(path, name=f"w{i}", share=1.0, slots=CAPACITY,
                     heartbeat_interval=0.05,
                     reconnect_backoff=(0.02, 0.25)).start(
                         connect_timeout=15.0)
        for i in range(N_WORKERS)
    ]
    detect, rejoin, mttr = [], [], []
    try:
        _wait_until(lambda: _coordinated(clients), 30.0, "initial grants")
        for _ in range(reps):
            incarnation = clients[0].incarnation
            t_kill = time.monotonic()
            proc.kill()
            proc.join(30.0)
            _wait_until(lambda: all(c.degraded for c in clients), 30.0,
                        "outage detection")
            detect.append(time.monotonic() - t_kill)
            proc = _spawn_broker(path)  # restart on the same path
            t_ready = time.monotonic()
            _wait_until(
                lambda: _coordinated(clients)
                and clients[0].incarnation != incarnation,
                30.0, "re-coordination")
            t_conv = time.monotonic()
            rejoin.append(t_conv - t_ready)
            mttr.append(t_conv - t_kill)
    finally:
        for c in clients:
            c.stop()
        proc.kill()
        proc.join(10.0)
    return {
        "reps": reps,
        "n_workers": N_WORKERS,
        "capacity": CAPACITY,
        "detect_s": _stats(detect),
        "rejoin_s": _stats(rejoin),
        "mttr_s": _stats(mttr),
        "reconnects": {c.name: c.reconnects for c in clients},
    }


# --------------------------------------------------------------------- #
# scenario 2: lease churn — grant convergence latency
# --------------------------------------------------------------------- #
def run_grant_convergence(events: int) -> dict:
    import random

    from repro.ipc import BrokerClient, NodeBroker

    rng = random.Random(0)
    path = _path()
    broker = NodeBroker(path, capacity=CAPACITY, heartbeat_timeout=1.0)
    broker.start()
    clients = [
        BrokerClient(path, name=f"w{i}", share=1.0, slots=CAPACITY,
                     heartbeat_interval=0.05).start()
        for i in range(N_WORKERS)
    ]
    extra = None  # the join/leave churn worker
    settle = []

    def _settled() -> bool:
        live = clients + ([extra] if extra is not None else [])
        snap = broker.snapshot()["workers"]
        return (sorted(snap) == sorted(c.name for c in live)
                and all(snap[c.name]["granted"] == c.granted for c in live)
                and sum(c.granted or 0 for c in live) == CAPACITY)

    try:
        _wait_until(_settled, 30.0, "initial grants")
        for i in range(events):
            kind = rng.choice(["resize", "churn"])
            t0 = time.monotonic()
            if kind == "resize":
                rng.choice(clients).resize(0.5 + 2.5 * rng.random())
            elif extra is None:
                extra = BrokerClient(
                    path, name="churn", share=2.0, slots=CAPACITY,
                    heartbeat_interval=0.05).start()
            else:
                extra.stop()
                extra = None
            _wait_until(_settled, 30.0, f"convergence after event {i}")
            settle.append(time.monotonic() - t0)
    finally:
        for c in clients:
            c.stop()
        if extra is not None:
            extra.stop()
        broker.stop()
    return {
        "events": events,
        "n_workers": N_WORKERS,
        "capacity": CAPACITY,
        "settle_s": _stats(settle),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_faults.json, or "
                         "BENCH_faults.smoke.json with --smoke so a smoke "
                         "run never clobbers the committed artifact)")
    ap.add_argument("--smoke", action="store_true",
                    help="fewer repetitions: proves the machinery")
    args = ap.parse_args(argv)
    out = default_out("faults", args.smoke, args.out)
    reps = 2 if args.smoke else 5
    events = 6 if args.smoke else 20

    mttr = run_broker_mttr(reps)
    print(f"broker_mttr ({reps} kills, {N_WORKERS} workers):")
    print(f"  detect (kill -> all degraded):        {mttr['detect_s']}")
    print(f"  rejoin (broker up -> re-coordinated): {mttr['rejoin_s']}")
    print(f"  MTTR   (kill -> re-coordinated):      {mttr['mttr_s']}")

    conv = run_grant_convergence(events)
    print(f"grant_convergence ({events} churn events): {conv['settle_s']}")

    payload = {
        "bench": "faults",
        "smoke": args.smoke,
        "scenarios": {
            "broker_mttr": mttr,
            "grant_convergence": conv,
        },
    }
    write_artifact(out, payload)
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
