"""Oversubscribed multi-model serving (paper §5.5) — REAL JAX inference.

Three model servers (different smoke-size architectures) + a gateway share
a 2-slot USF runtime. Clients fan requests through the gateway; every wait
(request queue, batch formation, device step) is a USF blocking point.

Run:  PYTHONPATH=src python examples/oversubscribed_serving.py
"""

import time

from repro.configs.base import get_smoke
from repro.core.policies import SchedCoop
from repro.core.task import Job
from repro.core.threads import UsfRuntime
from repro.core.topology import Topology
from repro.serve.engine import Gateway, InferenceServer


def main():
    usf = UsfRuntime(Topology(2, 1), SchedCoop(quantum=0.05))
    servers = [
        InferenceServer("llama-ish", get_smoke("smollm_360m"), usf,
                        max_batch=2, max_len=48, nice=10),
        InferenceServer("moe-ish", get_smoke("deepseek_moe_16b"), usf,
                        max_batch=2, max_len=48, nice=10),
        InferenceServer("ssm-ish", get_smoke("mamba2_2_7b"), usf,
                        max_batch=2, max_len=48, nice=10),
    ]
    for s in servers:
        s.start()
    gw = Gateway(usf, servers)

    t0 = time.monotonic()
    clients = [
        usf.create(lambda i=i: gw.handle([1 + i, 2 + i, 3 + i], max_new=4),
                   job=gw.job, name=f"client{i}")
        for i in range(6)
    ]
    for c in clients:
        ok = usf.join(c, timeout=300.0)
        assert ok, "request timed out"
    dt = time.monotonic() - t0

    lats = sorted(r["latency"] for r in gw.responses)
    print(f"served {len(gw.responses)} fan-out requests over "
          f"{len(servers)} models in {dt:.1f}s on 2 slots")
    print(f"latency p50={lats[len(lats) // 2] * 1e3:.0f}ms "
          f"max={lats[-1] * 1e3:.0f}ms")
    for s in servers:
        print(f"  {s.name}: served={s.served}")
        s.stop()
    usf.shutdown()


if __name__ == "__main__":
    main()
