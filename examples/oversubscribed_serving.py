"""Oversubscribed multi-model serving (paper §5.5) — REAL JAX inference.

Three model servers (different smoke-size architectures) + a gateway share
a 2-slot USF runtime. Clients fan requests through the gateway; every wait
(request queue, batch formation, device step) is a USF blocking point.
Servers start through the default group and are re-homed LIVE into their
own lease groups (no drain).

Phase 2 demos preemptive co-location on real threads: a CPU-bound
SCHED_FAIR batch job shares the node under its own lease — the watchdog
tick driver time-slices it at ``usf.checkpoint()`` preemption points and a
mid-run ``lease.resize()`` reclaims its slots within a tick period, while
the SCHED_COOP servers take zero preemptions (I2 per job).

Run:  PYTHONPATH=src python examples/oversubscribed_serving.py
"""

import threading
import time

from repro.configs.base import get_smoke
from repro.core.policies import SchedCoop, SchedFair
from repro.core.task import Job
from repro.core.threads import UsfRuntime
from repro.core.topology import Topology
from repro.serve.engine import Gateway, InferenceServer


def preemptive_colocation_demo(usf, servers, gw):
    """Phase 2: a preemptive batch job co-located with the live servers."""
    batch = Job("batch-analytics")
    lease = usf.attach(batch, policy=SchedFair(slice_s=0.02), share=600.0)
    stop = threading.Event()

    def crunch():
        n = 0
        while not stop.is_set():  # CPU-bound: never blocks voluntarily
            n += 1
            if n % 2000 == 0:
                usf.checkpoint()  # the only preemption points it has

    workers = [usf.create(crunch, job=batch, name=f"batch{i}")
               for i in range(3)]
    r1 = gw.handle([5, 6, 7], max_new=2, timeout=300.0)
    lease.resize(60.0)  # elastic reclaim: hand slots back to the servers
    r2 = gw.handle([8, 9, 10], max_new=2, timeout=300.0)
    stop.set()
    for w in workers:
        assert usf.join(w, timeout=30.0)
    batch_preempts = sum(t.stats.preemptions for t in batch.tasks)
    coop_preempts = sum(
        sum(t.stats.preemptions for t in s.job.tasks) for s in servers
    )
    print(f"phase 2 (preemptive co-location on real threads):")
    print(f"  fan-out latency with batch job pinned: {r1['latency']*1e3:.0f}ms,"
          f" after lease.resize reclaim: {r2['latency']*1e3:.0f}ms")
    print(f"  batch preemptions={batch_preempts} (watchdog-delivered), "
          f"coop-server preemptions={coop_preempts} (I2: must be 0)")
    print(f"  watchdog ticks={usf.watchdog.ticks_fired}, "
          f"preempt requests={usf.watchdog.preempts_requested}")
    assert coop_preempts == 0
    usf.detach(batch)


def main():
    usf = UsfRuntime(Topology(2, 1), SchedCoop(quantum=0.05))
    servers = [
        InferenceServer("llama-ish", get_smoke("smollm_360m"), usf,
                        max_batch=2, max_len=48, nice=10),
        InferenceServer("moe-ish", get_smoke("deepseek_moe_16b"), usf,
                        max_batch=2, max_len=48, nice=10),
        InferenceServer("ssm-ish", get_smoke("mamba2_2_7b"), usf,
                        max_batch=2, max_len=48, nice=10),
    ]
    for s in servers:
        s.start()
    gw = Gateway(usf, servers)

    t0 = time.monotonic()
    clients = [
        usf.create(lambda i=i: gw.handle([1 + i, 2 + i, 3 + i], max_new=4),
                   job=gw.job, name=f"client{i}")
        for i in range(6)
    ]
    for c in clients:
        ok = usf.join(c, timeout=300.0)
        assert ok, "request timed out"
    dt = time.monotonic() - t0

    lats = sorted(r["latency"] for r in gw.responses)
    print(f"served {len(gw.responses)} fan-out requests over "
          f"{len(servers)} models in {dt:.1f}s on 2 slots")
    print(f"latency p50={lats[len(lats) // 2] * 1e3:.0f}ms "
          f"max={lats[-1] * 1e3:.0f}ms")

    preemptive_colocation_demo(usf, servers, gw)

    for s in servers:
        print(f"  {s.name}: served={s.served}")
        s.stop()
    usf.shutdown()


if __name__ == "__main__":
    main()
