"""Co-executed training jobs (paper §5.6 analogue) — REAL training, e2e.

Two Trainer jobs (different smoke architectures) share a USF runtime:
each trains a ~100-step run with checkpointing; blocking points (data
prefetch, inter-step yields) let the scheduler interleave them per the
per-job quantum. This is the end-to-end driver deliverable: a real model
trained a few hundred steps with loss decreasing and checkpoint/restart.

Run:  PYTHONPATH=src python examples/co_execution_training.py [--steps N]
"""

import argparse
import tempfile

import numpy as np

from repro.configs.base import get_smoke
from repro.core.policies import SchedCoop
from repro.core.task import Job
from repro.core.threads import UsfRuntime
from repro.core.topology import Topology
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    usf = UsfRuntime(Topology(1, 1), SchedCoop(quantum=0.25))
    results = {}

    def train_job(name, arch, steps, seed):
        def body():
            with tempfile.TemporaryDirectory() as d:
                cfg = get_smoke(arch)
                t = Trainer(
                    cfg,
                    TrainerConfig(steps=steps, global_batch=4, seq_len=64,
                                  ckpt_dir=d, ckpt_every=50, peak_lr=1e-2,
                                  warmup=10, seed=seed),
                    usf=usf,
                )
                t.run(resume=False)
                losses = [m["loss"] for m in t.metrics_log]
                results[name] = losses

        return body

    jobs = [Job("job-a"), Job("job-b")]
    tasks = [
        usf.create(train_job("smollm", "smollm_360m", args.steps, 0),
                   job=jobs[0], name="train-smollm"),
        usf.create(train_job("danube", "h2o_danube_3_4b", args.steps, 1),
                   job=jobs[1], name="train-danube"),
    ]
    for t in tasks:
        assert usf.join(t, timeout=3600.0)

    for name, losses in results.items():
        print(f"{name}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"over {len(losses)} steps "
              f"({'DECREASED' if losses[-1] < losses[0] - 0.5 else 'flat'})")
    s = usf.stats()
    print(f"scheduler: dispatches={s['dispatches']} yields={s['yields']} "
          f"preemptions={s['preemptions']} (SCHED_COOP: must be 0)")
    usf.shutdown()


if __name__ == "__main__":
    main()
