"""Quickstart: the USF scheduler in 60 lines.

Two co-located jobs on a 4-slot "node": a bursty latency-sensitive job and
a throughput job. SCHED_COOP multiplexes them at blocking points only —
no preemptions, FIFO fairness via the per-job quantum.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import simtask as st
from repro.core.events import SimExecutor
from repro.core.policies import SchedCoop, SchedFair
from repro.core.task import Job
from repro.core.topology import Topology


def workload(sim):
    """A throughput job (long uninterrupted compute) + a service job
    (short bursts separated by blocking waits)."""
    throughput = Job("throughput")
    service = Job("service")
    latencies = []

    def hog():
        for _ in range(4):
            yield st.compute(0.050)

    def burst(i):
        def gen():
            t0 = sim.now()
            yield st.compute(0.005)
            latencies.append(sim.now() - t0)

        return gen

    for _ in range(4):
        sim.spawn(throughput, hog)
    for i in range(16):
        sim.spawn(service, burst(i), at=0.010 * i)
    return latencies


def main():
    for policy in (SchedCoop(quantum=0.02), SchedFair(slice_s=0.003)):
        sim = SimExecutor(Topology(4, 1), policy)
        lat = workload(sim)
        stats = sim.run()
        print(f"{policy.name:12s} makespan={stats.makespan * 1e3:7.1f}ms "
              f"burst-latency-mean={sum(lat) / len(lat) * 1e3:6.1f}ms "
              f"preemptions={stats.preemptions} "
              f"migrations={stats.migrations}")


if __name__ == "__main__":
    main()
