"""Nested-runtime matmul (paper §5.3) — REAL threads + REAL JAX compute.

An outer "runtime" of worker threads each calls an inner parallel BLAS-like
region (blocked jnp matmuls with a busy-wait team barrier). All threads are
gated by USF: with SCHED_COOP only `slots` threads run at once, swapping at
blocking points; with --free the Linux scheduler multiplexes everything.

Run:  PYTHONPATH=src python examples/nested_runtime_matmul.py [--free]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.policies import SchedCoop
from repro.core.sync import BusyWaitBarrier, CoopChannel
from repro.core.task import Job
from repro.core.threads import UsfRuntime
from repro.core.topology import Topology

N = 256          # block size
N_BLOCKS = 12    # outer tasks
INNER = 3        # inner team width
SLOTS = 2        # "cores"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--free", action="store_true",
                    help="Linux-baseline mode (no USF gating)")
    args = ap.parse_args()

    usf = UsfRuntime(Topology(SLOTS, 1), SchedCoop(), gating=not args.free)
    job = Job("matmul")
    a = jnp.ones((N, N))
    mm = jax.jit(lambda x: x @ x)
    mm(a).block_until_ready()  # compile once

    work = CoopChannel(usf)
    for i in range(N_BLOCKS):
        work.put(i)
    for _ in range(SLOTS):
        work.put(None)

    def outer_worker():
        while True:
            item = work.get()
            if item is None:
                return
            bar = BusyWaitBarrier(usf, INNER, yield_every=1)
            members = [
                usf.create(lambda b=bar: (mm(a).block_until_ready(),
                                          b.wait(max_spins=2_000_000)),
                           job=job, name=f"team{item}")
                for _ in range(INNER - 1)
            ]
            mm(a).block_until_ready()
            bar.wait(max_spins=2_000_000)
            for m in members:
                usf.join(m)

    t0 = time.monotonic()
    workers = [usf.create(outer_worker, job=job, name=f"outer{i}")
               for i in range(SLOTS)]
    for w in workers:
        assert usf.join(w, timeout=300.0)
    dt = time.monotonic() - t0
    s = usf.stats()
    mode = "free (Linux)" if args.free else "SCHED_COOP"
    print(f"{mode}: {N_BLOCKS} blocks x {INNER}-thread teams on {SLOTS} "
          f"slots in {dt:.2f}s; dispatches={s['dispatches']} "
          f"cache_hits={s['cache_hits']} yields={s['yields']}")
    usf.shutdown()


if __name__ == "__main__":
    main()
