"""USF core: the paper's contribution.

A centralized, multi-job, user-space scheduling framework:

* ``Topology``/``Slot``   — execution resources (cores on the paper's node;
  device partitions on a TPU pod) grouped into locality domains (NUMA on the
  paper's node; ICI neighborhoods on a pod).
* ``Task``/``Job``        — schedulable work units owned by jobs (processes).
* ``Scheduler``           — the central scheduler: one running task per slot,
  worker swaps at blocking points only, pluggable policy.
* ``SlotArbiter``/``SlotLease`` — the job level of the two-level design:
  nice-weighted proportional slot leases with work-conserving borrowing,
  elastic resize, and attach/detach of jobs running *different* intra-job
  policies side by side (SCHED_COOP co-located with SCHED_FAIR).
* ``lease`` (``LeaseTable``)  — the extracted lease/quota machinery
  (largest-remainder apportionment + the I5 borrow order) shared by the
  arbiter and the cross-process ``repro.ipc.NodeBroker``.
* ``policies``            — SCHED_COOP (the paper's default), SCHED_FAIR
  (EEVDF-like preemptive stand-in for Linux), SCHED_RR.
* ``sync``                — cooperative synchronization primitives with
  per-object FIFO wait queues (paper Listing 1), including the busy-wait
  barrier + yield adaptation of §5.2.
* ``events``              — discrete-event executor (virtual time) used to run
  the paper's experiments at pod scale deterministically.
* ``threads``             — real-thread executor ("glibcv" analogue): gates
  genuine Python threads (which dispatch genuine JAX work), preserves TLS,
  caches threads across create/join cycles (§4.3.1).
* ``autockpt``            — auto-checkpoint instrumentation: wrap jitted
  step functions (``preemptible``/``wrap_jit``) or hot loops
  (``maybe_checkpoint``) so every dispatch boundary is a preemption
  point, with a ``SimExecutor`` twin (``preemptible_body``) injecting
  the sim's checkpoint op at the same boundaries. The four preemption
  delivery tiers are documented in docs/PREEMPTION.md.
"""

from repro.core.task import Task, Job, TaskState
from repro.core.autockpt import (maybe_checkpoint, preemptible,
                                 preemptible_body, wrap_jit)
from repro.core.topology import Topology, Slot
from repro.core.arbiter import ArbiterError, SlotArbiter, SlotLease
from repro.core.lease import LeaseTable, apportion, borrow_order
from repro.core.scheduler import Scheduler
from repro.core.policies import SchedCoop, SchedFair, SchedRR, Policy
from repro.core import sync
from repro.core.stats import SchedStats

__all__ = [
    "Task",
    "Job",
    "TaskState",
    "Topology",
    "Slot",
    "Scheduler",
    "SlotArbiter",
    "SlotLease",
    "ArbiterError",
    "LeaseTable",
    "apportion",
    "borrow_order",
    "Policy",
    "SchedCoop",
    "SchedFair",
    "SchedRR",
    "sync",
    "SchedStats",
    "preemptible",
    "wrap_jit",
    "maybe_checkpoint",
    "preemptible_body",
]
