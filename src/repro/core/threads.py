"""Real-thread USF runtime — the "glibcv" analogue.

Gates genuine Python threads (which dispatch genuine JAX work) through the
central Scheduler:

* ``create()`` is pthread_create (§4.3.1): the new thread is recruited as a
  worker, its task is submitted to the scheduler, and it *parks* until
  dispatched to a slot — freshly created threads never run freely.
* ``join()`` is masked (§4.3.1): the completed worker parks in the thread
  cache; subsequent ``create()`` calls reuse the most recent cached worker
  (Dice & Kogan), avoiding create/destroy cost (the 4x win of Table 2's
  pth rows).
* Blocking primitives in ``repro.core.sync`` call ``pause()`` /
  ``ready()`` — the nosv_pause / nosv_submit analogues.
* A single **watchdog** thread (``UsfRuntime.watchdog``) is the tick
  driver: it times preemption ticks for slots running preemptive-policy
  tasks (never SCHED_COOP — I2 per job) and owns the timer heap behind
  ``sleep()``/timeouts. Ticks become ``request_preempt`` flags that the
  running task consumes at its next scheduling point or explicit
  ``checkpoint()`` — user-space preemption the LibPreemptible way: the
  timer path delivers promptly, the task yields at a safe point.
* ``gating=False`` turns the runtime into the *Linux baseline*: threads run
  free (oversubscribed), synchronization falls back to plain threading —
  the OS scheduler multiplexes.

TLS: a task runs its whole life on one worker thread (tasks migrate between
*slots*, never between threads), so ``threading.local`` written inside a
task is stable across block/resume — the paper's seamlessness claim,
verified in tests/test_threads.py. Worker reuse gives a *new* task a fresh
``task_local()`` dict (pthread_create semantics).
"""

from __future__ import annotations

import heapq
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

from repro.core.adaptive import SliceController
from repro.core.arbiter import SlotArbiter
from repro.core.policies.base import Policy
from repro.core.scheduler import Scheduler
from repro.core.task import Job, Task, TaskState
from repro.core.topology import Topology


class UsfError(RuntimeError):
    pass


class UsfTaskError(UsfError):
    """A task body raised: re-surfaced at join (the worker itself parks
    back in the cache — §4.3.1 — so the failure must travel via the task)."""

    def __init__(self, task: Task, tb: str):
        super().__init__(f"task {task.name!r} of {task.job.name!r} raised:\n{tb}")
        self.task = task
        self.traceback = tb


_WD_CALL = 0  # payload = _TimerHandle (timed wakeup / timeout callback)
_WD_TICK = 1  # payload = tick interval (one coalesced entry per interval
#               class; the member slots are looked up at pop time)
_WD_KICK = 2  # payload = slot_id (urgent flag service: fires immediately
#               instead of waiting out the slot's class deadline)


class _TimerHandle:
    """Cancellable one-shot timer entry (threading.Timer analogue, but it
    lives in the watchdog's heap instead of owning an OS thread)."""

    __slots__ = ("fn", "_wd")

    def __init__(self, fn: Callable[[], None], wd: Optional["_Watchdog"]):
        self.fn: Optional[Callable[[], None]] = fn
        self._wd = wd

    def cancel(self) -> None:
        if self.fn is None:
            return
        self.fn = None  # the heap entry fires as a no-op and is dropped
        if self._wd is not None:
            self._wd._note_cancel()  # lazy compaction keeps the heap O(live)


class _Watchdog:
    """The real-thread tick driver: ONE timer thread owning a deadline heap.

    Two entry kinds share the heap:

    * **preemption ticks**, coalesced by *interval class*: every slot
      running a preemptive-policy task joins the class of its policy's
      tick period, and all slots of a class ride ONE periodic heap entry
      — the heap holds O(distinct intervals) tick entries, not O(slots),
      so hundreds of slots at a couple of slice lengths cost two entries
      per period instead of hundreds. A slot is armed only while it runs
      a task whose *own* intra-job policy is preemptive (SCHED_COOP slots
      are never ticked, keeping I2 per job); a policy swap moves the slot
      between classes (an earlier class deadline still supersedes a
      longer pending one). On expiry the scheduler is asked ``tick(slot)``
      for each member slot; a True answer (slice expiry, or the
      lease-revocation condition for an over-lease borrower) becomes
      ``request_preempt``, which the running task consumes at its next
      scheduling point or explicit ``usf.checkpoint()``. This is what
      makes preemptive policies and mid-run ``lease.resize()`` reclaim
      land under real threads.
    * **timed wakeups** (``call_at``/``call_later``): ``sleep()``, timed
      ``join()`` and timed waits route here instead of spawning one
      ``threading.Timer`` thread per call.

    The thread starts lazily on the first armed entry, so a runtime that
    never sleeps and never attaches a preemptive policy pays nothing.
    """

    def __init__(self, runtime: "UsfRuntime"):
        self._rt = runtime
        self._cv = threading.Condition(threading.Lock())
        self._heap: list[tuple] = []  # (deadline, seq, kind, payload)
        self._seq = 0
        # -- interval-class coalescing state (all under self._cv) -------- #
        #: interval -> member slots riding that class's periodic entry
        self._classes: dict[float, set[int]] = {}
        #: interval -> deadline of the class's single pending heap entry;
        #: absent = no entry pending (pushed again when a slot joins or
        #: the class re-arms after a fire)
        self._class_deadline: dict[float, float] = {}
        #: slot -> the interval class it currently rides (at most one:
        #: re-arming with a different period migrates the slot)
        self._slot_interval: dict[int, float] = {}
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        self._cancelled = 0  # dead call entries since the last compaction
        #: adaptive tick-period controller: the class *key* stays the base
        #: interval (the heap stays O(interval classes)); only the re-arm
        #: deadline uses the effective period (repro.core.adaptive)
        self.slices = SliceController()
        #: ticks fired / preemptions requested (introspection + benchmarks)
        self.ticks_fired = 0
        self.preempts_requested = 0
        #: urgent condition-variable kicks serviced
        self.kicks = 0

    # -- arming (any thread) ------------------------------------------- #
    def call_at(self, deadline: float, fn: Callable[[], None]) -> _TimerHandle:
        handle = _TimerHandle(fn, self)
        with self._cv:
            if not self._stop:
                self._push(deadline, _WD_CALL, handle)
                return handle
        # stopped runtime: fire degenerately now rather than dropping the
        # wakeup — a sleeper that would otherwise park forever wakes early
        fn()
        return handle

    def _note_cancel(self) -> None:
        """Compact the heap once cancelled entries dominate: a cancelled
        long timeout (e.g. a 300 s request deadline that resolved in ms)
        must not pin its waiter closure until the original deadline."""
        with self._cv:
            self._cancelled += 1
            if self._cancelled <= 32 or 2 * self._cancelled <= len(self._heap):
                return
            live = [e for e in self._heap
                    if e[2] != _WD_CALL or e[3].fn is not None]
            heapq.heapify(live)
            self._heap[:] = live  # in place: _main binds the list object
            self._cancelled = 0
            self._cv.notify()  # head may have changed: re-time the wait

    def call_later(self, delay: float, fn: Callable[[], None]) -> _TimerHandle:
        return self.call_at(time.monotonic() + delay, fn)

    def arm_tick(self, slot_id: int, interval: float) -> None:
        """Join the slot to the tick class of ``interval``.

        Slots sharing a tick period ride one periodic heap entry, so
        re-arming an already-member slot is a dict lookup, not a heap
        push. A slot armed with a *different* period (a policy handoff)
        migrates between classes only when the new class would service it
        EARLIER — an arm never lengthens a pending service, so a racing
        stale re-arm (e.g. the fire loop's, whose interval was computed
        just before a live swap armed the shorter class) cannot clobber
        the earlier tick. A slot left in a shorter class by a swap to a
        longer period settles into the right class at that shorter
        class's next fire (the fire-loop re-arm sees no current class
        then)."""
        with self._cv:
            if self._stop:
                return
            cur = self._slot_interval.get(slot_id)
            if cur == interval:
                return  # already riding this class's periodic entry
            effective = self.slices.effective
            if cur is not None:
                now = time.monotonic()
                cur_dl = self._class_deadline.get(cur, now + effective(cur))
                new_dl = self._class_deadline.get(interval,
                                                  now + effective(interval))
                if cur_dl <= new_dl:
                    return  # pending service is already no later: keep it
                self._classes[cur].discard(slot_id)
            self._slot_interval[slot_id] = interval
            members = self._classes.get(interval)
            if members is None:
                members = self._classes[interval] = set()
            members.add(slot_id)
            if interval not in self._class_deadline:
                # the adaptive controller sets the class's *effective*
                # period; the class identity (heap key) stays the base
                # interval, so coalescing is untouched
                deadline = time.monotonic() + effective(interval)
                self._class_deadline[interval] = deadline
                self._push(deadline, _WD_TICK, interval)

    def kick(self, slot_id: int) -> None:
        """Urgent flag service: wake the driver NOW for one slot instead
        of letting the flag wait out the slot's class deadline (the
        condition-variable kick of the fast preempt cycle). The scheduler's
        ``on_urgent`` hook lands here — under the scheduler lock, which is
        safe: the established lock order is scheduler -> watchdog CV and
        the driver never takes the scheduler lock while holding the CV."""
        with self._cv:
            if self._stop:
                return
            self.kicks += 1
            self._push(0.0, _WD_KICK, slot_id)

    def tick_heap_stats(self) -> dict:
        """Introspection (tests/benchmarks): the coalescing contract is
        ``tick_entries <= interval_classes`` — never O(slots_armed)."""
        with self._cv:
            return {
                "tick_entries": sum(1 for e in self._heap
                                    if e[2] == _WD_TICK),
                "interval_classes": len(self._class_deadline),
                "slots_armed": len(self._slot_interval),
                "timed_wakeups": sum(1 for e in self._heap
                                     if e[2] == _WD_CALL
                                     and e[3].fn is not None),
                "heap_len": len(self._heap),
            }

    def _push(self, deadline: float, kind: int, payload) -> None:
        # caller holds self._cv
        if self._stop:
            return
        seq = self._seq
        self._seq = seq + 1
        entry = (deadline, seq, kind, payload)
        heapq.heappush(self._heap, entry)
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._main, name="usf-watchdog", daemon=True
            )
            self._thread.start()
        elif self._heap[0] is entry:
            self._cv.notify()  # new earliest deadline: re-time the wait

    # -- the driver loop ------------------------------------------------ #
    def _main(self) -> None:
        heap = self._heap
        while True:
            with self._cv:
                while not self._stop:
                    if not heap:
                        self._cv.wait()
                        continue
                    delay = heap[0][0] - time.monotonic()
                    if delay <= 0.0:
                        break
                    self._cv.wait(delay)
                if self._stop:
                    return
                entry = heapq.heappop(heap)
                if entry[2] == _WD_TICK:
                    interval = entry[3]
                    if self._class_deadline.get(interval) != entry[0]:
                        continue  # stale token (class was torn down)
                    del self._class_deadline[interval]
                    # detach the whole class under the lock: member slots
                    # re-join via arm_tick (from _fire's re-arm loop or a
                    # concurrent dispatch) which re-pushes ONE fresh entry
                    slots = self._classes.pop(interval, set())
                    for sid in slots:
                        if self._slot_interval.get(sid) == interval:
                            del self._slot_interval[sid]
                    entry = (entry[0], entry[1], _WD_TICK,
                             (interval, slots))
            try:
                self._fire(entry)  # outside the watchdog lock
            except Exception:  # one bad callback must not kill the driver:
                # every later sleep()/timeout/preemption rides this thread
                import sys
                import traceback

                print("usf-watchdog: timer callback raised:\n"
                      + traceback.format_exc(), file=sys.stderr)

    def _fire(self, entry: tuple) -> None:
        kind = entry[2]
        if kind == _WD_CALL:
            fn = entry[3].fn
            if fn is not None:
                fn()
            return
        sched = self._rt.sched
        if kind == _WD_KICK:
            # urgent single-slot service: same verdict/flag/re-arm path as
            # a periodic tick, just now instead of at the class deadline
            slot_id = entry[3]
            self.ticks_fired += 1
            try:
                flagged, interval, depth, laxity = \
                    sched.tick_and_rearm(slot_id)
            except Exception:
                import sys
                import traceback

                print(f"usf-watchdog: kick for slot {slot_id} raised:\n"
                      + traceback.format_exc(), file=sys.stderr)
                return
            if flagged:
                self.preempts_requested += 1
            if interval:
                self.slices.observe(interval, depth=depth, laxity=laxity)
                self.arm_tick(slot_id, interval)
            return
        interval_cls, slots = entry[3]
        observed = False
        for slot_id in slots:
            self.ticks_fired += 1
            try:
                # verdict + flag + re-arm decision under ONE scheduler lock
                flagged, interval, depth, laxity = \
                    sched.tick_and_rearm(slot_id)
            except Exception:
                # a raising custom should_preempt must only cost ITS slot
                # one tick, not disarm every sibling slot of the class —
                # the whole class was detached at pop time. Re-arm the
                # failing slot at its old class period so a transient
                # error does not silence its ticks until the next dispatch
                import sys
                import traceback

                print(f"usf-watchdog: tick for slot {slot_id} raised:\n"
                      + traceback.format_exc(), file=sys.stderr)
                self.arm_tick(slot_id, interval_cls)
                continue
            if not observed:
                # one adaptation observation per class fire (before the
                # member re-arms, so the new effective period applies to
                # the class entry they push)
                self.slices.observe(interval_cls, depth=depth, laxity=laxity)
                observed = True
            if flagged:
                self.preempts_requested += 1
            # re-join a class while the slot still runs a preemptive-policy
            # task (the flagged task keeps its slot until it reaches a
            # preemption point); after a policy swap this may be a
            # *different* class than the one that just fired. Idle slots
            # simply drop out — the next dispatch re-arms them.
            if interval:
                self.arm_tick(slot_id, interval)

    def stop(self, timeout: float = 5.0) -> None:
        with self._cv:
            self._stop = True
            # keep the pending timed wakeups: ticks may be dropped, but a
            # sleeper/timeout waiter must never be left parked forever
            pending = [e for e in self._heap if e[2] == _WD_CALL]
            self._heap.clear()
            self._classes.clear()
            self._class_deadline.clear()
            self._slot_interval.clear()
            self._cv.notify_all()
        t = self._thread
        if t is not None:
            t.join(timeout)
        for entry in pending:  # fire early (after the thread quit: no dupes)
            fn = entry[3].fn
            if fn is not None:
                fn()


class _Worker:
    """A cached OS thread that serves one task at a time."""

    __slots__ = ("thread", "inbox", "name", "_sem")

    def __init__(self, runtime: "UsfRuntime", idx: int):
        self.name = f"usf-worker-{idx}"
        self.inbox: "deque[Optional[Task]]" = deque()
        self._sem = threading.Semaphore(0)
        self.thread = threading.Thread(
            target=runtime._worker_main, args=(self,), name=self.name, daemon=True
        )
        self.thread.start()

    def assign(self, task: Optional[Task]) -> None:
        self.inbox.append(task)
        self._sem.release()

    def take(self) -> Optional[Task]:
        self._sem.acquire()
        return self.inbox.popleft()


class UsfRuntime:
    """One per node — the shared nOS-V instance analogue (multi-job)."""

    def __init__(
        self,
        topology: Topology,
        policy: Policy,
        *,
        gating: bool = True,
        thread_cache: bool = True,
        arbiter: Optional[SlotArbiter] = None,
    ):
        self.topology = topology
        self.gating = gating
        self.thread_cache_enabled = thread_cache
        self._tls = threading.local()
        self._cache: deque[_Worker] = deque()
        self._all_workers: list[_Worker] = []
        self._cache_lock = threading.Lock()
        self._widx = 0
        self._shutdown = False
        self.cache_hits = 0
        self.cache_misses = 0
        #: the tick driver (single watchdog thread, started lazily)
        self.watchdog = _Watchdog(self)
        #: True once any attached (or default) intra-job policy is
        #: preemptive: gates the per-dispatch policy lookup so purely
        #: cooperative runtimes pay nothing for the tick driver
        self._ticks_enabled = bool(policy.preemptive and policy.tick_interval)
        self.sched = Scheduler(
            topology,
            policy,
            clock=time.monotonic,
            dispatch=self._on_dispatch,
            arbiter=arbiter,
        )
        #: urgent flags (deadline arbiter) kick the watchdog CV instead of
        #: waiting out the pending class deadline
        self.sched.on_urgent = self.watchdog.kick

    # ------------------------------------------------------------------ #
    # pthread-like API
    # ------------------------------------------------------------------ #
    def create(
        self,
        fn: Callable[..., Any],
        args: tuple = (),
        kwargs: Optional[dict] = None,
        *,
        job: Job,
        name: str = "",
        deadline: Optional[float] = None,
    ) -> Task:
        """pthread_create: recruit a (new or cached) worker for a new task.

        ``deadline`` (absolute, scheduler clock domain) rides on the task:
        a deadline-aware arbiter folds it into its grant order the moment
        the task turns READY — including an urgent grant when the deadline
        is already past."""
        if self._shutdown:
            raise UsfError("runtime is shut down")
        task = Task(job, body=(fn, args, kwargs or {}), name=name,
                    deadline=deadline)
        task._resume_sem = threading.Semaphore(0)  # type: ignore[attr-defined]
        task._done_event = threading.Event()  # type: ignore[attr-defined]
        task._storage = {}  # type: ignore[attr-defined]  # fresh task-locals
        task.on_done.append(lambda t: t._done_event.set())  # type: ignore[attr-defined]
        worker = self._get_worker()
        task._ctx = worker
        worker.assign(task)
        return task

    def join(self, task: Task, timeout: Optional[float] = None) -> bool:
        """pthread_join, masked (§4.3.1): the worker is already parked in the
        cache; we only wait for task completion. A gated caller blocks
        cooperatively (releases its slot); an external thread just waits.

        Returns False on timeout. If the task body raised, the exception is
        re-surfaced here as ``UsfTaskError`` instead of silently reporting
        completion."""
        cur = self.current_task()
        ev: threading.Event = task._done_event  # type: ignore[attr-defined]
        if cur is None or not self.gating:
            if not ev.wait(timeout):
                return False
            self._check_task_exc(task)
            return True
        # registration must be atomic wrt finish() (which runs on_done under
        # the scheduler lock), or the wakeup could be lost. The wake fires
        # at most once, from either completion or the timeout timer.
        woken = [False]

        def wake_once(_t=None) -> None:
            with self.sched._lock:
                if woken[0]:
                    return
                woken[0] = True
                self.sched.unblock(cur)

        with self.sched._lock:
            if task.done:
                self._check_task_exc(task)
                return True
            task.on_done.append(wake_once)
        timer: Optional[_TimerHandle] = None
        if timeout is not None:
            timer = self.watchdog.call_later(timeout, wake_once)
        self.sched.block(cur)
        self._park(cur)
        if timer is not None:
            timer.cancel()
        if task.done:
            self._check_task_exc(task)
            return True
        return False

    def _check_task_exc(self, task: Task) -> None:
        exc = getattr(task, "_exc", None)
        if exc is not None:
            raise UsfTaskError(task, exc)

    # ------------------------------------------------------------------ #
    # job-level attach/detach (nosv_attach analogue, two-level scheduling)
    # ------------------------------------------------------------------ #
    def attach(self, job: Job, *, policy: Optional[Policy] = None,
               share: Optional[float] = None):
        """Register ``job`` with an optional dedicated intra-job policy and
        slot share; returns its ``SlotLease``.

        A job already attached is re-homed LIVE — promoted out of the
        default group, or policy-swapped in place when already dedicated:
        queued tasks migrate to the new policy, running tasks keep their
        slots and route later scheduling points there. Preemptive policies
        get watchdog ticks: slice expiry and lease reclaim land within one
        tick period at the task's next scheduling point or checkpoint
        (SCHED_COOP jobs are never ticked — reclaim from them waits for
        their next blocking point, I2)."""
        lease = self.sched.attach_job(job, policy=policy, share=share)
        self._arm_running(job)
        return lease

    def demote(self, job: Job, *, share: Optional[float] = None):
        """Live dedicated→default re-homing (the reverse attach edge):
        the job's dedicated lease/policy group is released and its work —
        queued and running — moves into the shared default group without
        quiescence; returns the new default-group lease."""
        lease = self.sched.demote_job(job, share=share)
        self._arm_running(job)
        return lease

    def _arm_running(self, job: Job) -> None:
        """Arm ticks for a re-homed job's RUNNING tasks when its (new)
        policy is preemptive: they were dispatched before the policy
        change, so dispatch-time arming never saw them."""
        pol = self.sched.policy_of(job)
        if pol.preemptive and pol.tick_interval:
            self._ticks_enabled = True
            for slot_id in self.sched.slots_running(job):
                self.watchdog.arm_tick(slot_id, pol.tick_interval)

    def detach(self, job: Job) -> None:
        """Unregister a quiescent job, releasing its lease to the siblings."""
        self.sched.detach_job(job)

    def set_slot_target(self, n: Optional[int]) -> int:
        """Elastic slot parking: cap the runtime's effective width at ``n``
        slots (``None`` restores the full topology); returns the target.

        Surplus slots park at their tasks' next scheduling point (the
        need-resched / lease-revocation path — within one watchdog tick
        period for preemptive-policy tasks with checkpoints); a regrow
        unparks and refills immediately. Floored at one slot, so a broker
        revoke can throttle this process but never deadlock it. This is
        the landing point of node-level grants (``repro.ipc.BrokerClient``
        binds it) and works equally for in-process width caps."""
        return self.sched.set_slot_target(n)

    def runnable_backlog(self) -> int:
        """Instantaneous READY + RUNNING count (``Scheduler.runnable_backlog``,
        a lock-free probe): the live demand a bound ``BrokerClient``
        piggybacks on its heartbeats so the node broker can tell an idle
        process from a saturated one."""
        return self.sched.runnable_backlog()

    def set_recorder(self, rec) -> None:
        """Arm (or, with ``None``, disarm) a trace decision recorder on the
        live runtime: ``rec((t, code, a, b))`` is invoked under the scheduler
        lock at every decision point (``repro.trace.TraceRecorder.emit`` is
        the usual target — see ``TraceRecorder.attach_runtime``). Disarmed,
        every decision path pays a single predicate check."""
        self.sched._rec = rec

    # ------------------------------------------------------------------ #
    # nOS-V-like blocking API (used by repro.core.sync)
    # ------------------------------------------------------------------ #
    def current_task(self) -> Optional[Task]:
        return getattr(self._tls, "task", None)

    def pause(self) -> None:
        """nosv_pause: the calling task blocks; its slot swaps in another.

        The caller must have made itself discoverable (e.g. queued itself on
        a sync object) *before* calling pause — wakeups that race ahead are
        absorbed by the scheduler's pending-wakeup counter.
        """
        task = self._require_task()
        self.sched.block(task)
        self._park(task)

    def ready(self, task: Task) -> None:
        """nosv_submit: mark a paused task ready (queued, not resumed — I3)."""
        self.sched.unblock(task)

    def yield_now(self) -> None:
        """sched_yield → nosv_yield: requeue behind peers, maybe resume."""
        task = self._require_task()
        self.sched.yield_(task)
        self._park(task)

    def sleep(self, seconds: float) -> None:
        """nosv_waitfor: timed block; auto-resubmitted when the watchdog's
        timer heap fires (one shared thread, not a Timer thread per call)."""
        task = self._require_task()
        self.watchdog.call_later(seconds, lambda: self.sched.unblock(task))
        self.sched.block(task)
        self._park(task)

    def call_later(self, delay: float, fn: Callable[[], None]) -> _TimerHandle:
        """Timed callback on the watchdog's shared timer heap (the
        ``threading.Timer`` replacement used by the sync primitives)."""
        return self.watchdog.call_later(delay, fn)

    def checkpoint(self) -> None:
        """Explicit preemption point (LibPreemptible-style): a compute loop
        that never blocks calls this periodically.

        Fast path: two lock-free attribute reads against the slot state
        the scheduler cached on the task at dispatch — the need-resched
        flag, then the precomputed absolute slice expiry. A checkpoint
        that crosses the expiry *self-ticks* through
        ``Scheduler.poll_preempt`` (verdict re-validated under the lock):
        the preempt cycle completes at checkpoint latency instead of
        waiting out a watchdog tick, which is what takes the end-to-end
        ``sched.preempt_cycle`` number from tick-period-bound (~100/s) to
        checkpoint-bound. The watchdog remains the backstop for tasks
        that checkpoint rarely (and the only driver for lease-revocation
        flags on slots whose task never self-expires).

        Safe to call from anywhere: a plain (non-USF) thread and a
        free-running (``gating=False``) task both no-op, so library code
        can sprinkle checkpoints unconditionally — the auto-checkpoint
        wrappers (``repro.core.autockpt``) rely on this to keep
        instrumented code identical between coordinated runs and
        free-running baselines. The full delivery-latency ladder
        (blocking point / explicit checkpoint / auto-checkpoint at
        dispatch / watchdog backstop) is documented in
        docs/PREEMPTION.md."""
        task = self.current_task()
        if task is None:
            return  # plain thread: checkpoints are unconditional no-ops
        st = task._slot_state
        if st is None:
            return  # not scheduler-dispatched (free-running baseline mode)
        if st.need_resched:
            if self.sched.consume_preempt(task):
                self._park(task)
            return
        expiry = st.slice_expiry
        if expiry and time.monotonic() >= expiry \
                and self.sched.poll_preempt(task):
            self._park(task)

    def task_local(self) -> dict:
        """Per-task storage (fresh per task even on worker reuse)."""
        return self._require_task()._storage  # type: ignore[attr-defined]

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def shutdown(self, timeout: float = 10.0) -> None:
        """Unpark, detach and truly join all cached workers (§4.3.1)."""
        self._shutdown = True
        self.watchdog.stop()
        with self._cache_lock:
            workers = list(self._all_workers)
            self._cache.clear()
        for w in workers:
            w.assign(None)  # poison pill
        deadline = time.monotonic() + timeout
        for w in workers:
            w.thread.join(max(0.0, deadline - time.monotonic()))

    def stats(self) -> dict:
        s = self.sched.stats().as_dict()
        s["cache_hits"] = self.cache_hits
        s["cache_misses"] = self.cache_misses
        s["workers"] = len(self._all_workers)
        s["watchdog_ticks"] = self.watchdog.ticks_fired
        s["watchdog_preempt_requests"] = self.watchdog.preempts_requested
        s["watchdog_kicks"] = self.watchdog.kicks
        s["poll_preempts"] = self.sched.poll_preempts
        return s

    # ------------------------------------------------------------------ #
    # internals
    # ------------------------------------------------------------------ #
    def _require_task(self) -> Task:
        t = self.current_task()
        if t is None:
            raise UsfError("not inside a USF task")
        return t

    def _get_worker(self) -> _Worker:
        with self._cache_lock:
            if self.thread_cache_enabled and self._cache:
                self.cache_hits += 1
                return self._cache.pop()  # most recent first (warm)
            self.cache_misses += 1
            w = _Worker(self, self._widx)
            self._widx += 1
            self._all_workers.append(w)
            return w

    def _park(self, task: Task) -> None:
        """Wait until the scheduler dispatches ``task`` to a slot again."""
        task._resume_sem.acquire()  # type: ignore[attr-defined]

    def _on_dispatch(self, task: Task, slot_id: int) -> None:
        if self._ticks_enabled:
            pol = self.sched.policy_of(task.job)
            if pol.preemptive and pol.tick_interval:
                # stamp the absolute slice expiry BEFORE waking the worker:
                # checkpoints self-detect expiry lock-free against this
                # (the fast preempt cycle); the watchdog tick stays armed
                # as the backstop for checkpoint-free stretches
                sl = pol.slice_for(task)
                st = self.sched._slots[slot_id]
                st.slice_expiry = (st.run_started + sl) if sl else 0.0
                self.watchdog.arm_tick(slot_id, pol.tick_interval)
        task._resume_sem.release()  # type: ignore[attr-defined]

    def _worker_main(self, worker: _Worker) -> None:
        while True:
            task = worker.take()
            if task is None:
                return  # detached at shutdown
            self._tls.task = task
            try:
                fn, args, kwargs = task.body
                if self.gating:
                    # nosv_attach: submit + park until first dispatch
                    self.sched.submit(task)
                    self._park(task)
                    try:
                        fn(*args, **kwargs)
                    except BaseException:
                        import traceback

                        # record BEFORE finish(): join waiters wake inside
                        # finish() and must observe the failure (no race)
                        task._exc = traceback.format_exc()  # type: ignore[attr-defined]
                    finally:
                        self.sched.finish(task)
                else:
                    # free-running Linux-baseline mode
                    self.sched.register_job(task.job)
                    task.state = TaskState.RUNNING
                    now = time.monotonic()
                    task.stats.created_at = task.stats.created_at or now
                    task.stats.first_run_at = now
                    try:
                        fn(*args, **kwargs)
                    except BaseException:
                        import traceback

                        task._exc = traceback.format_exc()  # type: ignore[attr-defined]
                    finally:
                        task.state = TaskState.DONE
                        task.stats.done_at = time.monotonic()
                        for cb in task.on_done:
                            cb(task)
            except Exception:  # pragma: no cover - runtime-internal failure
                import traceback

                task._exc = traceback.format_exc()  # type: ignore[attr-defined]
                if not getattr(task, "_done_event", None) or not task._done_event.is_set():  # type: ignore[attr-defined]
                    task._done_event.set()  # type: ignore[attr-defined]
            finally:
                self._tls.task = None
                if not self._shutdown:
                    with self._cache_lock:
                        if self.thread_cache_enabled:
                            self._cache.append(worker)
                        else:
                            self._all_workers.remove(worker)
                    if not self.thread_cache_enabled:
                        return  # thread truly exits (pth-style create/destroy)
