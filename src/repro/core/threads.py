"""Real-thread USF runtime — the "glibcv" analogue.

Gates genuine Python threads (which dispatch genuine JAX work) through the
central Scheduler:

* ``create()`` is pthread_create (§4.3.1): the new thread is recruited as a
  worker, its task is submitted to the scheduler, and it *parks* until
  dispatched to a slot — freshly created threads never run freely.
* ``join()`` is masked (§4.3.1): the completed worker parks in the thread
  cache; subsequent ``create()`` calls reuse the most recent cached worker
  (Dice & Kogan), avoiding create/destroy cost (the 4x win of Table 2's
  pth rows).
* Blocking primitives in ``repro.core.sync`` call ``pause()`` /
  ``ready()`` — the nosv_pause / nosv_submit analogues.
* ``gating=False`` turns the runtime into the *Linux baseline*: threads run
  free (oversubscribed), synchronization falls back to plain threading —
  the OS scheduler multiplexes.

TLS: a task runs its whole life on one worker thread (tasks migrate between
*slots*, never between threads), so ``threading.local`` written inside a
task is stable across block/resume — the paper's seamlessness claim,
verified in tests/test_threads.py. Worker reuse gives a *new* task a fresh
``task_local()`` dict (pthread_create semantics).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Optional

from repro.core.policies.base import Policy
from repro.core.scheduler import Scheduler
from repro.core.task import Job, Task, TaskState
from repro.core.topology import Topology


class UsfError(RuntimeError):
    pass


class UsfTaskError(UsfError):
    """A task body raised: re-surfaced at join (the worker itself parks
    back in the cache — §4.3.1 — so the failure must travel via the task)."""

    def __init__(self, task: Task, tb: str):
        super().__init__(f"task {task.name!r} of {task.job.name!r} raised:\n{tb}")
        self.task = task
        self.traceback = tb


class _Worker:
    """A cached OS thread that serves one task at a time."""

    __slots__ = ("thread", "inbox", "name", "_sem")

    def __init__(self, runtime: "UsfRuntime", idx: int):
        self.name = f"usf-worker-{idx}"
        self.inbox: "deque[Optional[Task]]" = deque()
        self._sem = threading.Semaphore(0)
        self.thread = threading.Thread(
            target=runtime._worker_main, args=(self,), name=self.name, daemon=True
        )
        self.thread.start()

    def assign(self, task: Optional[Task]) -> None:
        self.inbox.append(task)
        self._sem.release()

    def take(self) -> Optional[Task]:
        self._sem.acquire()
        return self.inbox.popleft()


class UsfRuntime:
    """One per node — the shared nOS-V instance analogue (multi-job)."""

    def __init__(
        self,
        topology: Topology,
        policy: Policy,
        *,
        gating: bool = True,
        thread_cache: bool = True,
    ):
        self.topology = topology
        self.gating = gating
        self.thread_cache_enabled = thread_cache
        self._tls = threading.local()
        self._cache: deque[_Worker] = deque()
        self._all_workers: list[_Worker] = []
        self._cache_lock = threading.Lock()
        self._widx = 0
        self._shutdown = False
        self.cache_hits = 0
        self.cache_misses = 0
        self.sched = Scheduler(
            topology,
            policy,
            clock=time.monotonic,
            dispatch=self._on_dispatch,
        )

    # ------------------------------------------------------------------ #
    # pthread-like API
    # ------------------------------------------------------------------ #
    def create(
        self,
        fn: Callable[..., Any],
        args: tuple = (),
        kwargs: Optional[dict] = None,
        *,
        job: Job,
        name: str = "",
    ) -> Task:
        """pthread_create: recruit a (new or cached) worker for a new task."""
        if self._shutdown:
            raise UsfError("runtime is shut down")
        task = Task(job, body=(fn, args, kwargs or {}), name=name)
        task._resume_sem = threading.Semaphore(0)  # type: ignore[attr-defined]
        task._done_event = threading.Event()  # type: ignore[attr-defined]
        task._storage = {}  # type: ignore[attr-defined]  # fresh task-locals
        task.on_done.append(lambda t: t._done_event.set())  # type: ignore[attr-defined]
        worker = self._get_worker()
        task._ctx = worker
        worker.assign(task)
        return task

    def join(self, task: Task, timeout: Optional[float] = None) -> bool:
        """pthread_join, masked (§4.3.1): the worker is already parked in the
        cache; we only wait for task completion. A gated caller blocks
        cooperatively (releases its slot); an external thread just waits.

        Returns False on timeout. If the task body raised, the exception is
        re-surfaced here as ``UsfTaskError`` instead of silently reporting
        completion."""
        cur = self.current_task()
        ev: threading.Event = task._done_event  # type: ignore[attr-defined]
        if cur is None or not self.gating:
            if not ev.wait(timeout):
                return False
            self._check_task_exc(task)
            return True
        # registration must be atomic wrt finish() (which runs on_done under
        # the scheduler lock), or the wakeup could be lost. The wake fires
        # at most once, from either completion or the timeout timer.
        woken = [False]

        def wake_once(_t=None) -> None:
            with self.sched._lock:
                if woken[0]:
                    return
                woken[0] = True
                self.sched.unblock(cur)

        with self.sched._lock:
            if task.done:
                self._check_task_exc(task)
                return True
            task.on_done.append(wake_once)
        timer: Optional[threading.Timer] = None
        if timeout is not None:
            timer = threading.Timer(timeout, wake_once)
            timer.daemon = True
            timer.start()
        self.sched.block(cur)
        self._park(cur)
        if timer is not None:
            timer.cancel()
        if task.done:
            self._check_task_exc(task)
            return True
        return False

    def _check_task_exc(self, task: Task) -> None:
        exc = getattr(task, "_exc", None)
        if exc is not None:
            raise UsfTaskError(task, exc)

    # ------------------------------------------------------------------ #
    # job-level attach/detach (nosv_attach analogue, two-level scheduling)
    # ------------------------------------------------------------------ #
    def attach(self, job: Job, *, policy: Optional[Policy] = None,
               share: Optional[float] = None):
        """Register ``job`` with an optional dedicated intra-job policy and
        slot share; returns its ``SlotLease``. In the real-thread runtime,
        lease reclaim is honoured at scheduling points (block/yield/finish):
        there is no tick driver here, so shrunk leases of busy cooperative
        jobs take effect at the job's next blocking point."""
        return self.sched.attach_job(job, policy=policy, share=share)

    def detach(self, job: Job) -> None:
        """Unregister a quiescent job, releasing its lease to the siblings."""
        self.sched.detach_job(job)

    # ------------------------------------------------------------------ #
    # nOS-V-like blocking API (used by repro.core.sync)
    # ------------------------------------------------------------------ #
    def current_task(self) -> Optional[Task]:
        return getattr(self._tls, "task", None)

    def pause(self) -> None:
        """nosv_pause: the calling task blocks; its slot swaps in another.

        The caller must have made itself discoverable (e.g. queued itself on
        a sync object) *before* calling pause — wakeups that race ahead are
        absorbed by the scheduler's pending-wakeup counter.
        """
        task = self._require_task()
        self.sched.block(task)
        self._park(task)

    def ready(self, task: Task) -> None:
        """nosv_submit: mark a paused task ready (queued, not resumed — I3)."""
        self.sched.unblock(task)

    def yield_now(self) -> None:
        """sched_yield → nosv_yield: requeue behind peers, maybe resume."""
        task = self._require_task()
        self.sched.yield_(task)
        self._park(task)

    def sleep(self, seconds: float) -> None:
        """nosv_waitfor: timed block; auto-resubmitted when the timer fires."""
        task = self._require_task()
        timer = threading.Timer(seconds, lambda: self.sched.unblock(task))
        timer.daemon = True
        timer.start()
        self.sched.block(task)
        self._park(task)

    def task_local(self) -> dict:
        """Per-task storage (fresh per task even on worker reuse)."""
        return self._require_task()._storage  # type: ignore[attr-defined]

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def shutdown(self, timeout: float = 10.0) -> None:
        """Unpark, detach and truly join all cached workers (§4.3.1)."""
        self._shutdown = True
        with self._cache_lock:
            workers = list(self._all_workers)
            self._cache.clear()
        for w in workers:
            w.assign(None)  # poison pill
        deadline = time.monotonic() + timeout
        for w in workers:
            w.thread.join(max(0.0, deadline - time.monotonic()))

    def stats(self) -> dict:
        s = self.sched.stats().as_dict()
        s["cache_hits"] = self.cache_hits
        s["cache_misses"] = self.cache_misses
        s["workers"] = len(self._all_workers)
        return s

    # ------------------------------------------------------------------ #
    # internals
    # ------------------------------------------------------------------ #
    def _require_task(self) -> Task:
        t = self.current_task()
        if t is None:
            raise UsfError("not inside a USF task")
        return t

    def _get_worker(self) -> _Worker:
        with self._cache_lock:
            if self.thread_cache_enabled and self._cache:
                self.cache_hits += 1
                return self._cache.pop()  # most recent first (warm)
            self.cache_misses += 1
            w = _Worker(self, self._widx)
            self._widx += 1
            self._all_workers.append(w)
            return w

    def _park(self, task: Task) -> None:
        """Wait until the scheduler dispatches ``task`` to a slot again."""
        task._resume_sem.acquire()  # type: ignore[attr-defined]

    def _on_dispatch(self, task: Task, slot_id: int) -> None:
        task._resume_sem.release()  # type: ignore[attr-defined]

    def _worker_main(self, worker: _Worker) -> None:
        while True:
            task = worker.take()
            if task is None:
                return  # detached at shutdown
            self._tls.task = task
            try:
                fn, args, kwargs = task.body
                if self.gating:
                    # nosv_attach: submit + park until first dispatch
                    self.sched.submit(task)
                    self._park(task)
                    try:
                        fn(*args, **kwargs)
                    except BaseException:
                        import traceback

                        # record BEFORE finish(): join waiters wake inside
                        # finish() and must observe the failure (no race)
                        task._exc = traceback.format_exc()  # type: ignore[attr-defined]
                    finally:
                        self.sched.finish(task)
                else:
                    # free-running Linux-baseline mode
                    self.sched.register_job(task.job)
                    task.state = TaskState.RUNNING
                    now = time.monotonic()
                    task.stats.created_at = task.stats.created_at or now
                    task.stats.first_run_at = now
                    try:
                        fn(*args, **kwargs)
                    except BaseException:
                        import traceback

                        task._exc = traceback.format_exc()  # type: ignore[attr-defined]
                    finally:
                        task.state = TaskState.DONE
                        task.stats.done_at = time.monotonic()
                        for cb in task.on_done:
                            cb(task)
            except Exception:  # pragma: no cover - runtime-internal failure
                import traceback

                task._exc = traceback.format_exc()  # type: ignore[attr-defined]
                if not getattr(task, "_done_event", None) or not task._done_event.is_set():  # type: ignore[attr-defined]
                    task._done_event.set()  # type: ignore[attr-defined]
            finally:
                self._tls.task = None
                if not self._shutdown:
                    with self._cache_lock:
                        if self.thread_cache_enabled:
                            self._cache.append(worker)
                        else:
                            self._all_workers.remove(worker)
                    if not self.thread_cache_enabled:
                        return  # thread truly exits (pth-style create/destroy)
