"""Tasks and jobs.

Paper mapping: a ``Task`` is a pthread recruited as a nOS-V worker+task
(glibcv converts every pthread into exactly one task bound to one worker);
a ``Job`` is a process registered in the shared nOS-V instance.

TPU mapping: a ``Task`` is a unit of device work (training micro-step,
serving request phase, checkpoint flush); a ``Job`` is a training run or a
model server sharing the pod.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable, Optional

_TID = itertools.count()
_JID = itertools.count()


class TaskState(enum.Enum):
    CREATED = "created"
    READY = "ready"        # queued in the scheduler, not running
    RUNNING = "running"    # the unique running task of some slot
    BLOCKED = "blocked"    # parked on a sync object / wait
    DONE = "done"


@dataclasses.dataclass(slots=True)
class TaskStats:
    """Per-task accounting (feeds SchedStats and the benchmarks)."""

    created_at: float = 0.0
    first_run_at: Optional[float] = None
    done_at: Optional[float] = None
    run_time: float = 0.0          # time actually executing on a slot
    wait_time: float = 0.0         # READY time spent queued
    blocked_time: float = 0.0      # BLOCKED time
    spin_time: float = 0.0         # busy-wait time (consumes a slot!)
    dispatches: int = 0            # times resumed onto a slot
    migrations: int = 0            # resumed on a different slot than last time
    cross_domain_migrations: int = 0
    preemptions: int = 0           # involuntary (preemptive policies only)
    yields: int = 0                # voluntary


class Job:
    """A process in the paper; a co-located training/serving job here.

    ``nice`` mirrors the paper's microservices setup (gateway nice 0 vs
    server nice 20); SCHED_COOP itself does not need it, but preemptive
    baselines weight quanta by it, and the job-level ``SlotArbiter``
    derives the default lease ``share`` from it.

    ``share``/``lease`` are the two-level scheduling fields: ``share`` is
    an optional explicit slot-share weight (``None`` -> derived from
    ``nice``); ``lease`` is set by the arbiter while the job is attached
    (``repro.core.arbiter.SlotLease``) and ``None`` otherwise.
    """

    __slots__ = ("jid", "name", "nice", "quantum", "tasks", "service_time",
                 "share", "lease")

    def __init__(self, name: str, *, nice: int = 0,
                 quantum: Optional[float] = None,
                 share: Optional[float] = None):
        self.jid: int = next(_JID)
        self.name = name
        self.nice = nice
        self.quantum = quantum  # None -> policy default (paper: 20 ms)
        self.share = share      # None -> nice-derived weight (arbiter)
        self.lease: Optional[Any] = None  # SlotLease while attached
        self.tasks: list["Task"] = []
        self.service_time: float = 0.0  # total slot time consumed

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Job({self.name}#{self.jid})"


class Task:
    """A schedulable unit bound to one job.

    ``body`` is executor-specific:
      * events.SimExecutor: a generator factory yielding op tuples
        (see ``repro.core.simtask``);
      * threads.ThreadExecutor: a plain callable run on a real thread.

    A task keeps a *preferred affinity* = the last slot it ran on (§4.1), and
    an optional *user affinity hint* (§4.3.2 — stored, reported back on
    query, but treated as a hint only).

    ``__slots__`` covers the executor-private fields too (sim generator
    state, thread-runtime handles): tasks are the densest hot-path objects
    in the system, and slot access keeps pick/dispatch allocation-free.
    """

    __slots__ = (
        "tid", "job", "body", "name", "cost_hint", "deadline", "state", "slot",
        "last_slot", "user_affinity", "stats", "on_done", "_pending_wakeups",
        "_ctx",
        # sim-executor fields (events.py)
        "_gen", "_send", "_epoch", "_pending", "_pending_started",
        "_warmup_scale", "_owned_mutexes",
        # scheduler bookkeeping (scheduler.py / policies)
        "_blocked_at", "_ready_at", "_yielded", "_slot_state",
        # thread-runtime fields (threads.py)
        "_resume_sem", "_done_event", "_storage", "_exc",
    )

    def __init__(
        self,
        job: Job,
        body: Any = None,
        *,
        name: str = "",
        cost_hint: float = 0.0,
        deadline: Optional[float] = None,
    ):
        self.tid: int = next(_TID)
        self.job = job
        self.body = body
        self.name = name or f"task{self.tid}"
        self.cost_hint = cost_hint
        #: optional absolute completion deadline (scheduler clock domain).
        #: ``None`` (the default) means no SLO: the deadline-aware arbiter
        #: ignores the task and plain arbiters never read the field.
        self.deadline = deadline
        self.state = TaskState.CREATED
        self.slot: Optional[int] = None          # slot currently running on
        self.last_slot: Optional[int] = None     # preferred affinity (§4.1)
        self.user_affinity: Optional[frozenset[int]] = None  # hint (§4.3.2)
        self.stats = TaskStats()
        self.on_done: list[Callable[["Task"], None]] = []
        #: futex-style wakeup counter — an unblock that raced ahead of the
        #: corresponding block (real-thread mode) is remembered, not lost.
        self._pending_wakeups: int = 0
        # executor-private fields:
        self._ctx: Any = None
        self._yielded = False
        self._owned_mutexes: Any = None
        self._warmup_scale: float = 1.0
        #: while RUNNING: the _SlotState of the task's slot, cached so the
        #: real-thread checkpoint fast path is one attribute hop instead of
        #: a slot-table index (scheduler.py sets/clears it at dispatch/stop)
        self._slot_state: Any = None
        job.tasks.append(self)

    # -- affinity hints (paper §4.3.2: setaffinity is a hint; getaffinity
    #    returns the stored hint, not the real placement) ------------------
    def set_affinity_hint(self, slots: frozenset[int]) -> None:
        self.user_affinity = frozenset(slots)

    def get_affinity(self) -> Optional[frozenset[int]]:
        return self.user_affinity

    @property
    def done(self) -> bool:
        return self.state is TaskState.DONE

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Task({self.name}#{self.tid} {self.state.value} j={self.job.name})"
