"""Job-level slot arbitration — the top half of the two-level scheduler.

The paper coordinates *multi-runtime and multi-process* workloads through
one shared user-space scheduler instance. A single flat policy cannot
express that: a co-located BLAS job wants SCHED_COOP semantics while a
preemptive baseline job wants SCHED_FAIR, and the co-location wins come
from *job-level capacity arbitration*, not from intra-job pick order.

``SlotArbiter`` is that job level. It sits between the ``Scheduler`` (which
owns slots, invariants and scheduling points) and one *intra-job policy per
policy group*:

* every attached job holds a ``SlotLease`` — a nice-weighted proportional
  share of the slots, materialized as an integer ``quota`` by
  largest-remainder apportionment;
* leases are **work-conserving**: a job with ready tasks may *borrow* slots
  beyond its quota, but only when no sibling group with spare lease has
  ready work (invariant I5, tested in tests/test_arbiter.py);
* leases are **elastic**: ``lease.resize(share)`` regrows or reclaims
  capacity at runtime (the job-level generalization of
  ``repro.launch.elastic`` — grants take effect immediately via an idle
  fill, reclaims at the next scheduling point, or at the next preemption
  tick for preemptive intra-job policies);
* jobs attach and detach dynamically (the ``nosv_attach`` analogue): a
  detached job's blocked tasks may later re-register transparently through
  the default group.

Live migration is **any↔any**: every edge of the 3x3 matrix of
(source, destination) group kinds — default / dedicated-cooperative /
dedicated-preemptive — re-homes a *busy* job without draining it.
``attach_job`` promotes out of the default group or, on an
already-dedicated job, performs a live policy swap; ``demote_job``
re-homes a dedicated job back into the default group. In every case the
job's READY tasks are withdrawn from the old policy (``Policy.remove``)
and re-queued exactly once in the new one, while RUNNING tasks keep
their slots, start a fresh slice, and route their next scheduling point
to the new policy. ``detach_job`` remains quiescence-checked: it is
teardown, not migration.

Invariant I5 (grant rule): *a job is never granted a slot beyond its
current lease while a sibling group has ready tasks and spare lease*. The
arbiter enforces it structurally — borrowing grants are only reached after
every under-quota group has declined the slot.

Fast path: with a single policy group (the common single-runtime case) the
arbiter rebinds its scheduling-point entry points to the default policy's
bound methods, so the two-level design costs nothing until a second
runtime actually attaches.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Optional

from repro.core.lease import LeaseTable
from repro.core.policies.base import Policy, StopReason
from repro.core.policies.sched_fair import nice_to_weight
from repro.core.task import Job, Task, TaskState

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.scheduler import Scheduler


class ArbiterError(RuntimeError):
    pass


class ArbiterGroup:
    """One intra-job policy instance plus the jobs it multiplexes.

    Jobs attached *with* a dedicated policy form a one-job group; jobs
    registered without one share the default group (and its policy does its
    own intra-group multiplexing, e.g. SCHED_COOP's job rotation). Lease
    enforcement is at group granularity: ``quota``/``in_use`` aggregate the
    member leases.
    """

    __slots__ = ("policy", "jids", "quota", "in_use", "dedicated")

    def __init__(self, policy: Policy, *, dedicated: bool):
        self.policy = policy
        self.jids: set[int] = set()
        self.quota = 0
        self.in_use = 0
        self.dedicated = dedicated

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"ArbiterGroup({self.policy.name} jobs={len(self.jids)} "
                f"{self.in_use}/{self.quota})")


class SlotLease:
    """A job's proportional claim on the slot pool.

    ``share`` is a relative weight (defaults to the nice-derived weight, so
    the paper's gateway-nice-0 / server-nice-20 setup maps directly onto
    leases); ``quota`` is the integer slot entitlement the arbiter derives
    from it; ``in_use`` counts the job's currently running tasks.
    """

    __slots__ = ("job", "arbiter", "group", "share", "quota", "in_use")

    def __init__(self, job: Job, arbiter: "SlotArbiter", group: ArbiterGroup,
                 share: float):
        self.job = job
        self.arbiter = arbiter
        self.group = group
        self.share = share
        self.quota = 0
        self.in_use = 0

    def resize(self, share: float) -> "SlotLease":
        """Elastic grant/reclaim: change this job's share at runtime.

        Growing takes effect immediately (idle slots are refilled under the
        new quotas); shrinking is reclaimed at the job's next scheduling
        point — or next preemption tick when its policy is preemptive (the
        lease-revocation scheduling point). SCHED_COOP jobs are never
        preempted for reclaim (I2).
        """
        self.arbiter._resize(self, share)
        return self

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"SlotLease({self.job.name} share={self.share:.1f} "
                f"{self.in_use}/{self.quota})")


def _job_share(job: Job, share: Optional[float]) -> float:
    if share is not None:
        s = float(share)
    elif job.share is not None:
        s = float(job.share)
    else:
        s = nice_to_weight(job.nice)
    if s < 0:
        raise ArbiterError(f"negative share {s} for {job}")
    return s


class SlotArbiter:
    """Two-level scheduler front: routes scheduling points to per-group
    intra-job policies under lease arbitration.

    The ``Scheduler`` drives it through the same entry points as a flat
    ``Policy`` (pick / on_ready / on_run / on_stop / should_preempt /
    has_ready / ready_count); job lifecycle goes through ``attach_job`` /
    ``detach_job`` / ``on_job``.

    **Extending the grant order**: subclasses customize job-level
    arbitration by overriding ``_pick_multi`` (which job's policy gets a
    freed slot) and ``_recompute_quotas`` (how shares materialize into
    integer quotas). The worked example is
    ``repro.core.deadline.DeadlineArbiter``: it reorders ``_pick_multi``
    candidates *within* each I5 tier by earliest deadline (spare-lease
    groups still strictly precede borrowers, so non-deadline siblings keep
    their I5 guarantee), boosts the effective share of deadline-pressed
    jobs in ``_recompute_quotas``, and adds an urgent-grant path that
    flags need-resched on the lowest-value borrowed slot the moment a
    deadline job's laxity goes negative. Overrides only see the
    multi-group path: with a single policy group the entry points stay
    rebound to the default policy's own methods (the zero-overhead fast
    path below), so deadline machinery costs nothing until a second group
    — or a deadline — actually shows up.
    """

    def __init__(self, default_policy: Policy):
        self.sched: Optional["Scheduler"] = None
        self._default = default_policy
        self._default_group = ArbiterGroup(default_policy, dedicated=False)
        self._groups: list[ArbiterGroup] = [self._default_group]
        #: the shared lease/quota machinery (repro.core.lease) — the same
        #: table class the node-level broker apportions processes with
        self._table = LeaseTable()
        #: jid -> lease, attach order (the table's own dict, bound once so
        #: the multi-group scheduling points skip an attribute hop)
        self._leases: dict[int, SlotLease] = self._table.entries
        self._bind_single()

    # ------------------------------------------------------------------ #
    # scheduler binding (Policy.attach shape)
    # ------------------------------------------------------------------ #
    def attach(self, sched) -> None:
        self.sched = sched
        self._table.capacity = sched.topology.n_slots
        self._default.attach(sched)
        self._recompute_quotas()

    def set_capacity(self, n_slots: int) -> None:
        """Re-apportion the leases over a new effective slot pool (elastic
        slot parking: a broker revoke shrinks the process's width, and the
        in-process quotas must track the *active* pool, not the topology)."""
        self._table.capacity = int(n_slots)
        self._recompute_quotas()

    @property
    def default_policy(self) -> Policy:
        return self._default

    @property
    def multi(self) -> bool:
        return len(self._groups) > 1

    def groups(self) -> tuple[ArbiterGroup, ...]:
        return tuple(self._groups)

    def leases(self) -> tuple[SlotLease, ...]:
        return tuple(self._leases.values())

    def describe(self) -> str:
        if not self.multi:
            return self._default.name
        names = "+".join(g.policy.name for g in self._groups if g.jids)
        return f"arbiter[{names}]"

    def policy_of(self, job: Job) -> Policy:
        lease = job.lease
        if lease is not None and lease.arbiter is self:
            return lease.group.policy
        return self._default

    def lease_of(self, job: Job) -> Optional[SlotLease]:
        lease = job.lease
        return lease if lease is not None and lease.arbiter is self else None

    def laxity_headroom(self, now: float) -> Optional[float]:
        """Minimum deadline laxity across attached jobs, or ``None`` when
        nothing deadline-bound is pending. The base arbiter tracks no
        deadlines — the adaptive slice controller and the watchdog read
        this through one virtual call that stays a constant ``None`` here
        (``DeadlineArbiter`` overrides it)."""
        return None

    def claim(self, task: Task) -> bool:
        """Withdraw a specific READY ``task`` from its policy queue for an
        urgent-grant redispatch (``Scheduler._fill`` consumes the slot's
        successor hint through this, skipping the full pick while keeping
        the policy's incremental accounting exact). Returns False when the
        task cannot be claimed — not attached here, not queued, or its
        policy lacks ``remove`` — in which case the caller falls back to a
        normal pick."""
        if task.state is not TaskState.READY:
            return False
        lease = self.lease_of(task.job)
        policy = lease.group.policy if lease is not None else self._default
        try:
            policy.remove(task)
        except (KeyError, NotImplementedError):
            return False
        return True

    def lease_snapshot(self) -> dict:
        return {
            l.job.name: {
                "share": l.share,
                "quota": l.quota,
                "in_use": l.in_use,
                "policy": l.group.policy.name,
            }
            for l in self._leases.values()
        }

    # ------------------------------------------------------------------ #
    # job lifecycle (nosv_attach / nosv_detach analogues)
    # ------------------------------------------------------------------ #
    def on_job(self, job: Job) -> None:
        """Implicit registration: unknown jobs join the default group."""
        if job.jid not in self._leases:
            self.attach_job(job)

    def attach_job(self, job: Job, *, policy: Optional[Policy] = None,
                   share: Optional[float] = None) -> SlotLease:
        """Register ``job``, optionally with its own intra-job policy.

        With ``policy=None`` the job joins the shared default group (the
        flat pre-arbiter behaviour). With a dedicated policy the job forms
        its own group — this is how one SCHED_COOP job co-locates with a
        SCHED_FAIR sibling. A job already attached is *re-homed live*:
        out of the default group (promotion) or out of its current
        dedicated group (a **live policy swap** — the old group is torn
        down and the job's work moves to the fresh policy instance
        without quiescence). READY tasks are withdrawn from the old
        policy (``Policy.remove``) and re-queued — exactly once each — in
        the new group's policy; RUNNING tasks keep their slots, start a
        fresh slice, and route their next scheduling point to the new
        policy; BLOCKED tasks route there on wakeup. No dispatch is lost
        or duplicated: a task is either withdrawn before it could be
        picked or it was already dispatched, never both.
        """
        existing = self._leases.get(job.jid)
        if existing is not None and policy is None:
            raise ArbiterError(
                f"{job} already attached; use lease.resize to change its "
                "share, attach_job(policy=...) to swap its policy live, or "
                "demote_job to re-home it into the default group"
            )
        if policy is not None and (policy is self._default or any(
            policy is g.policy for g in self._groups
        )):
            raise ArbiterError(
                "dedicated policy instance is already in use by another "
                "group (or is the job's current policy); pass a fresh "
                "instance per attach"
            )
        share_val = _job_share(job, share)  # validate BEFORE any teardown:
        # a failed attach must leave the job's queue/lease state untouched
        if policy is not None:
            # user-supplied policy hooks may raise (custom policies):
            # run them BEFORE the withdrawal too, or the migrated tasks
            # would be left queued nowhere
            if self.sched is not None:
                policy.attach(self.sched)
            policy.on_job(job)

            def make_group() -> ArbiterGroup:
                group = ArbiterGroup(policy, dedicated=True)
                self._groups.append(group)
                return group
        else:
            self._default.on_job(job)

            def make_group() -> ArbiterGroup:
                return self._default_group
        return self._rehome(job, existing, make_group, share_val)

    def demote_job(self, job: Job, *, share: Optional[float] = None
                   ) -> SlotLease:
        """Live dedicated→default re-homing (the reverse of promotion).

        The job's dedicated lease and policy group are released and its
        work moves into the shared default group *without quiescence*:
        READY tasks are withdrawn from the dedicated policy and re-queued
        exactly once in the default policy; RUNNING tasks keep their
        slots, start a fresh slice, and route their next scheduling point
        to the default policy. The returned lease is the job's new
        default-group membership (``share`` defaults to the job's
        explicit share or its nice-derived weight, like any implicit
        registration). Use ``detach_job`` — quiescence-checked — for true
        teardown.
        """
        existing = self._leases.get(job.jid)
        if existing is None:
            raise ArbiterError(f"{job} is not attached")
        if not existing.group.dedicated:
            raise ArbiterError(
                f"{job} already runs in the default group; demote_job only "
                "re-homes dedicated jobs"
            )
        share_val = _job_share(job, share)
        # refuse an unwithdrawable source BEFORE registering the job with
        # the default policy: a failed demote must not leave a phantom
        # job entry in its rotation (attach_job needs no such pre-check —
        # its failed fresh policy instance is simply discarded)
        self._check_withdrawable(job, existing.group.policy)
        self._default.on_job(job)  # before withdrawal: must not raise later

        def make_group() -> ArbiterGroup:
            return self._default_group

        return self._rehome(job, existing, make_group, share_val)

    def _rehome(self, job: Job, existing: Optional[SlotLease],
                make_group, share_val: float) -> SlotLease:
        """Shared migration tail of attach_job/demote_job: withdraw the
        job's queued work from its old group (if any), bind it to the
        group built by ``make_group``, re-queue the withdrawn READY tasks
        exactly once, and hand the new policy the job's RUNNING tasks as
        running-since-now."""
        migrated: list[Task] = []
        if existing is not None:
            migrated = self._withdraw_ready(job, existing.group.policy)
            self._release_lease(job)
        group = make_group()
        group.jids.add(job.jid)
        lease = SlotLease(job, self, group, share_val)
        self._leases[job.jid] = lease
        job.lease = lease
        for t in migrated:  # re-home the withdrawn READY tasks, once each
            group.policy.on_ready(t)
        clock = getattr(self.sched, "clock", None)  # absent on bare stand-ins
        now = clock() if clock is not None else 0.0
        for t in job.tasks:
            # RUNNING tasks keep their slots but must be known to the new
            # policy as running-since-now (a fresh slice), or a preemptive
            # policy could never slice-expire them
            if t.state is TaskState.RUNNING and t.slot is not None:
                self._restart_slice(t, now)
                group.policy.on_run(t, t.slot, now)
        self._rebalance()
        return lease

    def _restart_slice(self, task: Task, now: float) -> None:
        """Charge a re-homed RUNNING task's accrued run time and restart
        its slot's slice clock: the new policy's first ``on_stop`` must
        see only post-migration elapsed time (on_run promised it a fresh
        slice), and the old policy — possibly already torn down — keeps
        the pre-migration accrual out of the new one's accounting."""
        slots = getattr(self.sched, "_slots", None)
        if slots is None:  # bare stand-in scheduler (benchmarks/tests)
            return
        st = slots[task.slot]
        elapsed = now - st.run_started
        if elapsed > 0.0:
            task.stats.run_time += elapsed
            task.job.service_time += elapsed
            st.run_started = now

    def _withdraw_ready(self, job: Job, policy: Policy) -> list[Task]:
        """Surrender ``job``'s queued tasks from ``policy`` (live migration:
        promotion, policy swap, and demotion all start here).
        Every READY task of an attached job is queued in its group's policy,
        so the withdrawal is total: afterwards the policy holds none of the
        job's work and its incremental accounting matches a never-admitted
        pool."""
        ready = self._check_withdrawable(job, policy)
        for t in ready:
            policy.remove(t)
        return ready

    def _check_withdrawable(self, job: Job, policy: Policy) -> list[Task]:
        """Mutation-free precondition of a live withdrawal: returns the
        job's READY tasks, raising if ``policy`` cannot surrender them —
        checked BEFORE touching any queue (or registering the job
        elsewhere), so a refused migration leaves every policy's state
        untouched."""
        ready = [t for t in job.tasks if t.state is TaskState.READY]
        if ready and type(policy).remove is Policy.remove:
            raise ArbiterError(
                f"{policy.name} does not implement Policy.remove: cannot "
                f"live-migrate {job}'s queued tasks; attach before "
                "submitting work or implement remove()"
            )
        return ready

    def detach_job(self, job: Job) -> None:
        """Unregister ``job`` and release its lease (dynamic re-registration:
        a later submit — or a blocked task waking up — re-attaches the job
        to the default group)."""
        if job.jid not in self._leases:
            raise ArbiterError(f"{job} is not attached")
        self._require_quiescent(job, "detach")
        self._release_lease(job)
        self._rebalance()

    def _release_lease(self, job: Job) -> ArbiterGroup:
        """Tear down ``job``'s lease binding (shared by detach and the live
        re-home path of attach); returns the group the job left. The caller
        rebalances."""
        lease = self._leases.pop(job.jid)
        job.lease = None
        group = lease.group
        group.jids.discard(job.jid)
        if group.dedicated:
            self._groups.remove(group)
        else:
            self._default.on_job_detach(job)
        return group

    def _require_quiescent(self, job: Job, what: str) -> None:
        busy = [t for t in job.tasks
                if t.state in (TaskState.READY, TaskState.RUNNING)]
        if busy:
            shown = ", ".join(
                f"{t.name}#{t.tid}={t.state.value}" for t in busy[:8])
            more = f", +{len(busy) - 8} more" if len(busy) > 8 else ""
            raise ArbiterError(
                f"cannot {what}: {job.name}#{job.jid} still has {len(busy)} "
                f"READY/RUNNING task(s): {shown}{more} — detach is teardown "
                "only; attach_job(policy=...)/demote_job re-home a busy job "
                "live"
            )

    # ------------------------------------------------------------------ #
    # lease bookkeeping
    # ------------------------------------------------------------------ #
    def _resize(self, lease: SlotLease, share: float) -> None:
        # identity, not jid membership: a live swap/demote supersedes the
        # job's lease object, and a resize of the dead one must fail loud
        # rather than write a share no quota computation will ever read
        if lease.arbiter is not self \
                or self._leases.get(lease.job.jid) is not lease:
            raise ArbiterError(f"{lease} is no longer attached "
                               "(detached, or superseded by a re-home)")
        share = float(share)
        if share < 0:
            raise ArbiterError(f"negative share {share}")
        sched = self.sched
        lock = getattr(sched, "_lock", None)
        if lock is not None:
            with lock:
                lease.share = share
                rec = getattr(sched, "_rec", None)
                if rec is not None:
                    from repro.core.scheduler import REC_RESIZE
                    rec((sched.clock(), REC_RESIZE, lease.job.jid, share))
                self._recompute_quotas()
                # grant path: newly entitled capacity admits queued work now
                sched._fill_idle_slots(sched.clock())
        else:
            lease.share = share
            self._recompute_quotas()

    def _rebalance(self) -> None:
        self._recompute_quotas()
        self._resync_in_use()
        if self.multi:
            self._bind_multi()
        else:
            self._bind_single()

    def _recompute_quotas(self) -> None:
        """Largest-remainder apportionment of the slot pool by share —
        delegated to the shared ``LeaseTable`` (repro.core.lease), then
        aggregated per policy group."""
        for g in self._groups:
            g.quota = 0
        self._table.recompute()
        for lease in self._leases.values():
            lease.group.quota += lease.quota

    def _resync_in_use(self) -> None:
        """Recount running tasks per lease/group from the slot table
        (attach/detach can happen while sibling jobs are mid-flight)."""
        for l in self._leases.values():
            l.in_use = 0
        for g in self._groups:
            g.in_use = 0
        slots = getattr(self.sched, "_slots", None)
        if not slots:
            return
        for st in slots:
            t = st.running
            if t is None:
                continue
            lease = self._leases.get(t.job.jid)
            if lease is not None:
                lease.in_use += 1
                lease.group.in_use += 1

    # ------------------------------------------------------------------ #
    # scheduling-point routing
    # ------------------------------------------------------------------ #
    def _bind_single(self) -> None:
        """Single policy group: rebind the hot entry points straight to the
        default policy's bound methods — near-zero two-level overhead (the
        PR 1 fast-path numbers are gated on this, benchmarks/sched_ops.py).
        ``on_ready`` keeps a thin wrapper: it is the wakeup path, so it must
        re-register detached jobs whose BLOCKED tasks resurface — otherwise
        a leaseless task could reach a later multi-group transition."""
        p = self._default
        self.pick = p.pick
        self.on_ready = self._on_ready_single
        self.on_run = p.on_run
        self.on_stop = p.on_stop
        self.should_preempt = p.should_preempt
        self.has_ready = p.has_ready
        self.ready_count = p.ready_count

    def _bind_multi(self) -> None:
        self.pick = self._pick_multi
        self.on_ready = self._on_ready_multi
        self.on_run = self._on_run_multi
        self.on_stop = self._on_stop_multi
        self.should_preempt = self._should_preempt_multi
        self.has_ready = self._has_ready_multi
        self.ready_count = self._ready_count_multi

    def _pick_multi(self, slot_id: int) -> Optional[Task]:
        """Grant the slot under the lease rule (I5).

        Candidate order: groups holding spare lease first (largest spare
        wins, ties by attach order), then — work-conserving borrowing —
        groups already at/over quota, least-over first. This is exactly
        ``repro.core.lease.borrow_order`` — the shared I5 order the node
        broker applies at process granularity — inlined into the filter
        pass because this runs per pick (lockstep-asserted equivalent in
        tests/test_lease_table.py). A borrowing grant is therefore only
        reachable after every spare-lease group declined, which is
        exactly the I5 grant rule.
        """
        candidates = []
        for i, g in enumerate(self._groups):
            if g.policy.has_ready():
                candidates.append((g.in_use - g.quota, i, g))
        if not candidates:
            return None
        candidates.sort()
        for _, _, g in candidates:
            if not g.dedicated and len(g.jids) > 1:
                task = self._pick_shared_group(g, slot_id)
            else:
                task = g.policy.pick(slot_id)
            if task is not None:
                return task
        return None

    def _pick_shared_group(self, g: ArbiterGroup, slot_id: int
                           ) -> Optional[Task]:
        """Per-job lease enforcement inside a shared (default) group: the
        job-granular I5 analogue — no member job is granted a slot beyond
        its own lease while a sibling member has ready tasks and spare
        lease. When that situation holds, the grant is restricted to the
        under-lease members via a job-filtered pick; otherwise the group's
        policy picks freely (work-conserving borrowing between members).
        """
        policy = g.policy
        try:
            allowed: Optional[set[int]] = None
            over = False
            leases = self._leases
            for jid in g.jids:
                lease = leases[jid]
                if not policy.ready_count_of(lease.job):
                    continue
                if lease.in_use < lease.quota:
                    if allowed is None:
                        allowed = set()
                    allowed.add(jid)
                else:
                    over = True
            if allowed and over:
                task = policy.pick_filtered(slot_id, allowed)
                if task is not None:
                    return task
        except NotImplementedError:
            # legacy custom policy without the job-filtered surface: keep
            # the pre-PR-3 group-granular behaviour instead of crashing
            pass
        return policy.pick(slot_id)

    def _on_ready_single(self, task: Task) -> None:
        lease = task.job.lease
        if lease is None or lease.arbiter is not self:
            self.on_job(task.job)  # dynamic re-registration on wakeup
        self._default.on_ready(task)

    def _on_ready_multi(self, task: Task) -> None:
        job = task.job
        lease = job.lease
        if lease is None or lease.arbiter is not self:
            self.on_job(job)  # dynamic re-registration (detached job woke up)
            lease = job.lease
        lease.group.policy.on_ready(task)

    def _on_run_multi(self, task: Task, slot_id: int, now: float) -> None:
        lease = task.job.lease
        lease.in_use += 1
        lease.group.in_use += 1
        lease.group.policy.on_run(task, slot_id, now)

    def _on_stop_multi(self, task: Task, slot_id: int, now: float,
                       elapsed: float, reason: StopReason) -> None:
        lease = task.job.lease
        lease.in_use -= 1
        lease.group.in_use -= 1
        lease.group.policy.on_stop(task, slot_id, now, elapsed, reason)

    def _should_preempt_multi(self, task: Task, slot_id: int,
                              now: float) -> bool:
        group = task.job.lease.group
        policy = group.policy
        if not policy.preemptive:
            return False  # I2: cooperative jobs are never preempted
        if policy.should_preempt(task, slot_id, now):
            return True
        # lease-revocation scheduling point: running beyond quota while a
        # sibling group holds spare lease and ready work
        if group.in_use > group.quota:
            for h in self._groups:
                if h is not group and h.in_use < h.quota and h.policy.has_ready():
                    return True
        return False

    def _has_ready_multi(self) -> bool:
        for g in self._groups:
            if g.policy.has_ready():
                return True
        return False

    def _ready_count_multi(self) -> int:
        return sum(g.policy.ready_count() for g in self._groups)
