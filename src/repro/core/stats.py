"""System-wide scheduling statistics.

These counters are what the paper's evaluation plots are made of:
throughput (tasks or work units / s), latency distributions, preemption and
migration counts, slot busy fraction, spin (busy-wait) waste.
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import TYPE_CHECKING, Iterable

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.task import Task


@dataclasses.dataclass
class SchedStats:
    makespan: float = 0.0
    tasks_completed: int = 0
    total_run_time: float = 0.0
    total_wait_time: float = 0.0
    total_blocked_time: float = 0.0
    total_spin_time: float = 0.0
    dispatches: int = 0
    migrations: int = 0
    cross_domain_migrations: int = 0
    preemptions: int = 0
    yields: int = 0
    context_switch_time: float = 0.0
    n_slots: int = 0

    @property
    def slot_busy_fraction(self) -> float:
        """run_time already includes spin intervals (a spinning task is
        RUNNING and holds its slot)."""
        cap = self.makespan * max(self.n_slots, 1)
        return self.total_run_time / cap if cap else 0.0

    @property
    def useful_fraction(self) -> float:
        """Fraction of slot capacity doing *useful* (non-spin) work."""
        cap = self.makespan * max(self.n_slots, 1)
        return (self.total_run_time - self.total_spin_time) / cap if cap else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["slot_busy_fraction"] = self.slot_busy_fraction
        d["useful_fraction"] = self.useful_fraction
        return d


def collect(tasks: Iterable["Task"], *, makespan: float, n_slots: int) -> SchedStats:
    s = SchedStats(makespan=makespan, n_slots=n_slots)
    for t in tasks:
        st = t.stats
        s.tasks_completed += int(t.done)
        s.total_run_time += st.run_time
        s.total_wait_time += st.wait_time
        s.total_blocked_time += st.blocked_time
        s.total_spin_time += st.spin_time
        s.dispatches += st.dispatches
        s.migrations += st.migrations
        s.cross_domain_migrations += st.cross_domain_migrations
        s.preemptions += st.preemptions
        s.yields += st.yields
    return s


def latency_summary(latencies: list[float]) -> dict:
    """Mean / p50 / p95 / p99 / p999 / max — what Fig. 4 reports per
    request (p999 is what an SLO sweep's tail story hinges on)."""
    if not latencies:
        return {"n": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0,
                "p999": 0.0, "max": 0.0}
    xs = sorted(latencies)

    def pct(p: float) -> float:
        i = min(len(xs) - 1, max(0, int(round(p * (len(xs) - 1)))))
        return xs[i]

    return {
        "n": len(xs),
        "mean": statistics.fmean(xs),
        "p50": pct(0.50),
        "p95": pct(0.95),
        "p99": pct(0.99),
        "p999": pct(0.999),
        "max": xs[-1],
    }
