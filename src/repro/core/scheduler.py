"""The centralized USF scheduler.

Invariants (paper §2.3/§4.1, property-tested in tests/test_scheduler_props.py):

  I1. At most one RUNNING task per slot at any time ("exactly one running
      worker pinned per core").
  I2. Task swaps happen only at *scheduling points*: block, yield, end — or
      an explicit preemption tick when a preemptive baseline policy is
      active (the Linux stand-in). SCHED_COOP never preempts.
  I3. Unblocked tasks are NOT resumed immediately; they are queued and the
      policy decides placement later (§4.1 "these threads are not resumed
      immediately. Instead, they are queued within the scheduler").
  I4. A task that ends its body is parked, not destroyed, when a worker
      cache is attached (§4.3.1) — executor-level behaviour.

  I5. Two-level lease rule (arbiter.py): no job is *granted* a slot beyond
      its current lease while a sibling policy group has ready tasks and
      spare lease (work-conserving borrowing otherwise).

The scheduler is executor-agnostic: the discrete-event engine (events.py)
and the real-thread runtime (threads.py) both drive it through the same
six entry points: ``submit / block / unblock / yield_ / finish / tick``.

Two-level architecture: the scheduler owns slots, scheduling points and
invariants; *which job* gets a freed slot and *which task* of that job runs
is delegated to a job-level ``SlotArbiter`` routing to per-job intra-job
policies (one job can run SCHED_COOP while a co-located job runs
SCHED_FAIR). With a single policy group the arbiter is a transparent
pass-through to the default policy.
"""

from __future__ import annotations

import threading
from typing import Callable, Optional

from repro.core.arbiter import SlotArbiter, SlotLease
from repro.core.policies.base import Policy, StopReason
from repro.core.stats import SchedStats, collect
from repro.core.task import Job, Task, TaskState
from repro.core.topology import Topology


class SchedulerError(RuntimeError):
    pass


# --------------------------------------------------------------------- #
# trace decision-record codes (repro.trace builds on these; they live
# here so core never imports the trace package). An armed recorder is
# called as ``rec((t, code, a, b))`` — ONE pre-built record tuple, so the
# recorder can be a bare C-level ``deque.append`` with no Python frame.
# --------------------------------------------------------------------- #
(REC_OP, REC_SPAWN, REC_DISPATCH, REC_BLOCK, REC_YIELD, REC_DONE,
 REC_PREEMPT, REC_WAKE, REC_JOB, REC_ATTACH, REC_DEMOTE, REC_DETACH,
 REC_TARGET, REC_RESIZE, REC_DL_POST, REC_DL_RETIRE, REC_URGENT,
 REC_REQUEST, REC_REQ_DONE) = range(19)

#: StopReason -> decision code for the one shared stop site
_REC_STOP = {
    StopReason.BLOCK: REC_BLOCK,
    StopReason.YIELD: REC_YIELD,
    StopReason.DONE: REC_DONE,
    StopReason.PREEMPT: REC_PREEMPT,
}


def _pol_desc(policy: Optional[Policy]):
    """Serializable (name, param) description of an intra-job policy —
    enough for the replayer to rebuild an equivalent instance."""
    if policy is None:
        return None
    for attr in ("slice_s", "quantum", "default_quantum"):
        v = getattr(policy, attr, None)
        if v is not None:
            return (policy.name, v)
    return (policy.name, None)


class _SlotState:
    __slots__ = ("running", "run_started", "idle_since", "need_resched",
                 "slice_expiry", "successor")

    def __init__(self) -> None:
        self.running: Optional[Task] = None
        self.run_started: float = 0.0
        self.idle_since: float = 0.0
        #: set by request_preempt (watchdog tick / lease revocation); the
        #: running task's next scheduling point or explicit checkpoint
        #: consumes it and converts into a preempt/yield
        self.need_resched: bool = False
        #: absolute clock time at which the running task's slice expires
        #: (0.0 = no self-expiry). The real-thread checkpoint fast path
        #: compares against this WITHOUT taking the scheduler lock, so a
        #: slice expiry is noticed at the very next checkpoint instead of
        #: waiting out a watchdog tick period — the core of the fast
        #: preempt cycle. A stale read is benign: ``poll_preempt``
        #: re-validates the verdict under the lock.
        self.slice_expiry: float = 0.0
        #: preferred successor for the next fill of this slot (urgent-grant
        #: redispatch hint, set by a deadline-aware arbiter): consumed —
        #: and validated — by ``_fill`` before falling back to a full pick.
        self.successor: Optional[Task] = None


class Scheduler:
    """Central multi-job scheduler (the shared nOS-V instance analogue).

    Parameters
    ----------
    topology:  the slot/domain layout.
    policy:    the *default* intra-job policy (SCHED_COOP at most call
               sites): jobs that never attach with a dedicated policy are
               multiplexed by this one, exactly as before the two-level
               split. Per-job policies are added via ``attach_job``.
    clock:     zero-arg callable returning the current time. Virtual in the
               event engine, ``time.monotonic`` in the thread runtime.
    dispatch:  executor callback ``(task, slot_id) -> None`` that actually
               resumes the task on the slot.
    ctx_switch_cost: accounted (and, in the sim, *charged*) per swap.
    arbiter:   optional job-level arbiter instance (default: a fresh
               ``SlotArbiter``). Pass a ``DeadlineArbiter`` for EDF /
               least-laxity grant ordering (repro.core.deadline).
    """

    def __init__(
        self,
        topology: Topology,
        policy: Policy,
        *,
        clock: Callable[[], float],
        dispatch: Callable[[Task, int], None],
        ctx_switch_cost: float = 0.0,
        arbiter: Optional[SlotArbiter] = None,
    ):
        self.topology = topology
        #: the default intra-job policy (kept by name for back-compat; the
        #: authoritative router is ``self.arbiter``)
        self.policy = policy
        self.clock = clock
        self._dispatch_cb = dispatch
        self.ctx_switch_cost = ctx_switch_cost
        self._slots = [_SlotState() for _ in topology.slots]
        #: idle-slot free-list: exactly the slots with ``running is None``
        #: that are not parked. Maintained by _run_on/_stop_running so fill
        #: never scans all slots.
        self._idle: set[int] = set(range(topology.n_slots))
        #: elastic slot parking (node-level coordination): slots withdrawn
        #: from dispatch because the effective width was capped below the
        #: topology (``set_slot_target`` — a broker revoke, or an explicit
        #: cap). A slot is in exactly one of {running, _idle, _parked}.
        self._parked: set[int] = set()
        #: the effective width; == n_slots means parking is inert (the
        #: single compare in ``_fill`` is the whole fast-path cost)
        self._slot_target: int = topology.n_slots
        self.jobs: dict[int, Job] = {}
        self.all_tasks: list[Task] = []
        self._lock = threading.RLock()
        self._ctx_switch_time = 0.0
        self._started_at = self.clock()
        #: preemptions initiated by the checkpoint self-tick fast path
        #: (``poll_preempt``) rather than a watchdog request
        self.poll_preempts = 0
        #: executor hook fired (under the scheduler lock) when an urgent
        #: preemption request lands on a slot — the real-thread runtime
        #: binds this to the watchdog's condition-variable kick so the
        #: request is serviced immediately instead of at the next tick.
        self.on_urgent: Optional[Callable[[int], None]] = None
        #: decision-record hook (repro.trace): ``None`` when disarmed — the
        #: hot paths pay exactly one predicate check; armed, it is called
        #: as ``rec((t, code, a, b))`` under the scheduler lock, so records
        #: are totally ordered exactly like the decisions themselves.
        self._rec = None
        #: job-level slot arbiter: every scheduling point routes through it
        self.arbiter = arbiter if arbiter is not None else SlotArbiter(policy)
        self.arbiter.attach(self)

    # ------------------------------------------------------------------ #
    # job / task registration (nOS-V process registration analogue)
    # ------------------------------------------------------------------ #
    def register_job(self, job: Job) -> Job:
        with self._lock:
            self.jobs[job.jid] = job
            self.arbiter.on_job(job)
            rec = self._rec
            if rec is not None:
                rec((self.clock(), REC_JOB, job.jid,
                     (job.name, job.nice, job.share)))
        return job

    def attach_job(self, job: Job, *, policy: Optional[Policy] = None,
                   share: Optional[float] = None) -> SlotLease:
        """nosv_attach analogue: register ``job`` with an optional dedicated
        intra-job policy and an explicit slot share; returns its lease."""
        with self._lock:
            lease = self.arbiter.attach_job(job, policy=policy, share=share)
            self.jobs[job.jid] = job
            rec = self._rec
            if rec is not None:
                rec((self.clock(), REC_ATTACH, job.jid,
                     (share, _pol_desc(policy))))
            self._fill_idle_slots(self.clock())
            return lease

    def demote_job(self, job: Job, *, share: Optional[float] = None
                   ) -> SlotLease:
        """Live dedicated→default re-homing: release the job's dedicated
        lease/policy group and move its work — READY tasks re-queued
        exactly once, RUNNING tasks keeping their slots — into the shared
        default group. No quiescence required (the any↔any migration
        matrix; ``detach_job`` remains the teardown path)."""
        with self._lock:
            lease = self.arbiter.demote_job(job, share=share)
            rec = self._rec
            if rec is not None:
                rec((self.clock(), REC_DEMOTE, job.jid, share))
            self._fill_idle_slots(self.clock())
            return lease

    def detach_job(self, job: Job) -> None:
        """nosv_detach analogue: unregister a quiescent job, freeing its
        lease for the siblings (raises if it still has READY/RUNNING work).
        A later submit — or a blocked task waking up — re-registers it."""
        with self._lock:
            self.arbiter.detach_job(job)
            self.jobs.pop(job.jid, None)
            rec = self._rec
            if rec is not None:
                rec((self.clock(), REC_DETACH, job.jid, None))
            self._fill_idle_slots(self.clock())

    def policy_of(self, job: Job) -> Policy:
        """The intra-job policy currently serving ``job``'s tasks."""
        return self.arbiter.policy_of(job)

    # ------------------------------------------------------------------ #
    # elastic slot parking (node-level width coordination)
    # ------------------------------------------------------------------ #
    def set_slot_target(self, n: Optional[int]) -> int:
        """Cap the effective width at ``n`` slots (``None`` restores the
        full topology); returns the effective target.

        This is how a node-level grant/revoke (``repro.ipc``) — or any
        in-process width cap — lands on a live scheduler:

        * **shrink**: surplus *idle* slots park immediately; surplus
          *running* slots are flagged need-resched (the same flag the
          lease-revocation path uses), so each parks at its task's next
          scheduling point or explicit ``checkpoint()`` — for preemptive
          intra-job policies that is within one tick period. The running
          task is requeued, not lost: it resumes on a surviving slot.
        * **grow**: parked slots rejoin the idle pool and are refilled
          with queued work immediately (work-conserving grant).

        The target is floored at one slot: a process is never throttled to
        zero width (liveness — a dead or miserly broker must degrade a
        worker, never deadlock it). Job leases re-apportion over the
        *active* pool so intra-process shares keep tracking quotas.
        """
        with self._lock:
            n_total = len(self._slots)
            target = n_total if n is None else max(1, min(int(n), n_total))
            self._slot_target = target
            now = self.clock()
            active = n_total - len(self._parked)
            if active < target:
                for sid in sorted(self._parked):
                    if active >= target:
                        break
                    self._parked.discard(sid)
                    self._idle.add(sid)
                    self._slots[sid].idle_since = now
                    active += 1
            elif active > target:
                surplus = active - target
                # park idle slots first (highest ids — deterministic)...
                for sid in sorted(self._idle, reverse=True):
                    if surplus == 0:
                        break
                    self._idle.discard(sid)
                    self._parked.add(sid)
                    surplus -= 1
                # ...then flag surplus running slots: their tasks park the
                # slot at their next scheduling point (need-resched, the
                # lease-revocation path)
                if surplus:
                    for sid in range(n_total - 1, -1, -1):
                        if surplus == 0:
                            break
                        st = self._slots[sid]
                        if st.running is not None and not st.need_resched:
                            st.need_resched = True
                            surplus -= 1
            rec = self._rec
            if rec is not None:
                rec((now, REC_TARGET, target, None))
            self.arbiter.set_capacity(target)
            self._fill_idle_slots(now)
            return target

    def slot_target(self) -> int:
        return self._slot_target

    def runnable_backlog(self) -> int:
        """Instantaneous runnable backlog: READY + RUNNING task count.

        Lock-free by design — this is the demand probe a ``BrokerClient``
        heartbeat samples from its beat thread (``repro.ipc``), so it must
        never contend with the dispatch hot path. The reads race benignly:
        ``ready_count`` sums per-policy counters and the running count is
        derived from set sizes; a transiently stale sample is smoothed out
        by the broker's demand damping anyway."""
        running = len(self._slots) - len(self._idle) - len(self._parked)
        return max(0, self.arbiter.ready_count() + running)

    def parked_slot_ids(self) -> list[int]:
        with self._lock:
            return sorted(self._parked)

    # ------------------------------------------------------------------ #
    # the six scheduling entry points
    # ------------------------------------------------------------------ #
    def submit(self, task: Task) -> None:
        """New or re-submitted task becomes READY and is queued (never runs
        directly — glibcv blocks freshly created pthreads until dispatched)."""
        with self._lock:
            now = self.clock()
            if task.job.jid not in self.jobs:
                self.register_job(task.job)
            if task.state is TaskState.CREATED:
                self.all_tasks.append(task)
                task.stats.created_at = now
                rec = self._rec
                if rec is not None:
                    rec((now, REC_SPAWN, task.tid,
                         (task.job.jid, task.deadline, task.cost_hint)))
            self._make_ready(task, now)
            self._fill_idle_slots(now)

    def unblock_batch(self, tasks) -> None:
        """Unblock several tasks under one lock acquisition, preserving the
        per-task make-ready/fill sequence (same placement as N unblocks).
        The event engine uses this to coalesce same-timestamp wakeups."""
        with self._lock:
            now = self.clock()
            rec = self._rec
            for task in tasks:
                if task.state is not TaskState.BLOCKED:
                    task._pending_wakeups += 1
                    continue
                task.stats.blocked_time += now - task._blocked_at  # type: ignore[attr-defined]
                if rec is not None:
                    rec((now, REC_WAKE, task.tid, None))
                self._make_ready(task, now)
                self._fill_idle_slots(now)

    def block(self, task: Task) -> Optional[Task]:
        """Task reached a blocking point: free its slot, swap in the next.

        Returns the replacement task (for the executor), if any. If an
        ``unblock`` raced ahead of this block (real threads), the task is
        requeued immediately instead of parking (futex wake-before-wait).
        """
        with self._lock:
            slot, now = self._stop_running(task, StopReason.BLOCK)
            if task._pending_wakeups > 0:
                task._pending_wakeups -= 1
                self._make_ready(task, now)
            else:
                task.state = TaskState.BLOCKED
                task._blocked_at = now  # type: ignore[attr-defined]
            return self._fill(slot, now)

    def unblock(self, task: Task) -> None:
        """Blocking condition satisfied: queue the task (I3), fill idle slots."""
        with self._lock:
            if task.state is not TaskState.BLOCKED:
                # raced ahead of the block (real-thread mode): remember it
                task._pending_wakeups += 1
                return
            now = self.clock()
            task.stats.blocked_time += now - task._blocked_at  # type: ignore[attr-defined]
            rec = self._rec
            if rec is not None:
                rec((now, REC_WAKE, task.tid, None))
            self._make_ready(task, now)
            self._fill_idle_slots(now)

    def yield_(self, task: Task) -> Optional[Task]:
        """Voluntary yield (sched_yield / nosv_yield): requeue behind peers.

        Returns the task to run next on the slot (possibly the same task when
        nothing else is ready — yield is then a no-op, as on Linux).
        """
        with self._lock:
            slot, now = self._stop_running(task, StopReason.YIELD)
            task.stats.yields += 1
            task._yielded = True  # policies deprioritize: go to the back
            self._make_ready(task, now)
            return self._fill(slot, now)

    def finish(self, task: Task) -> Optional[Task]:
        """Task body ended: mark DONE, run callbacks, swap in the next."""
        with self._lock:
            slot, now = self._stop_running(task, StopReason.DONE)
            task.state = TaskState.DONE
            task.stats.done_at = now
            for cb in task.on_done:
                cb(task)
            return self._fill(slot, now)

    def preempt(self, task: Task) -> Optional[Task]:
        """Involuntary preemption — only preemptive intra-job policies (I2
        is per job now: a SCHED_COOP job is never preempted even while a
        co-located SCHED_FAIR job is)."""
        with self._lock:
            pol = self.arbiter.policy_of(task.job)
            if not pol.preemptive:
                raise SchedulerError(f"{pol.name} must not preempt (I2)")
            slot, now = self._stop_running(task, StopReason.PREEMPT)
            task.stats.preemptions += 1
            self._make_ready(task, now)
            return self._fill(slot, now)

    def tick(self, slot_id: int) -> bool:
        """Periodic tick (preemptive policies): should the slot's task be
        preempted now? The *executor* then calls ``preempt``. Routed to the
        running task's own policy; the arbiter also turns this into the
        lease-revocation scheduling point for over-lease preemptive jobs.
        A pending asynchronous preemption request (``request_preempt`` /
        ``urgent_preempt``) is honoured here too — a tick is a scheduling
        point, and ticks only ever fire on preemptive-policy slots."""
        with self._lock:
            st = self._slots[slot_id]
            if st.running is None:
                return False
            return st.need_resched or \
                self.arbiter.should_preempt(st.running, slot_id, self.clock())

    # ------------------------------------------------------------------ #
    # deferred preemption (real-thread tick driver)
    # ------------------------------------------------------------------ #
    def tick_request(self, slot_id: int) -> bool:
        """``tick`` + ``request_preempt`` under ONE lock acquisition, so
        the need-resched flag can only land on the task the verdict was
        about — with two separate calls the slot could swap in between
        and a SCHED_COOP task could get flagged. Kept for external tick
        drivers; the watchdog itself uses ``tick_and_rearm`` (same
        verdict logic, not a duplicate — this delegates)."""
        return self.tick_and_rearm(slot_id)[0]

    def tick_and_rearm(self, slot_id: int
                       ) -> tuple[bool, Optional[float], int, Optional[float]]:
        """``tick_request`` plus the watchdog's re-arm decision under ONE
        lock acquisition: returns (flagged, tick_interval, ready_depth,
        laxity) where ``tick_interval`` is the running task's policy
        period when that policy is preemptive (else None),
        ``ready_depth`` is the arbiter-wide ready-queue depth and
        ``laxity`` the arbiter's deadline headroom (None without a
        deadline-aware arbiter) — the two signals the adaptive slice
        controller shrinks/grows tick classes from. The coalesced fire
        loop calls this once per member slot instead of several lock
        round-trips, and the re-arm verdict is guaranteed to be about the
        same task the tick verdict was."""
        with self._lock:
            st = self._slots[slot_id]
            task = st.running
            if task is None:
                return False, None, 0, None
            now = self.clock()
            flagged = False
            if self.arbiter.should_preempt(task, slot_id, now):
                st.need_resched = True
                flagged = True
            pol = self.arbiter.policy_of(task.job)
            return (flagged,
                    (pol.tick_interval if pol.preemptive else None),
                    self.arbiter.ready_count(),
                    self.arbiter.laxity_headroom(now))

    def request_preempt(self, slot_id: int) -> bool:
        """Mark the slot need-resched (asynchronous preemption request).

        Real threads cannot be descheduled from outside: the watchdog tick
        driver calls this instead, and the running task's *next* scheduling
        point — or an explicit ``usf.checkpoint()`` preemption point —
        consumes the flag and converts into a preempt (I2: only ever
        requested for preemptive-policy tasks). Returns False if the slot
        was already idle (nothing to preempt)."""
        with self._lock:
            st = self._slots[slot_id]
            if st.running is None:
                return False
            st.need_resched = True
            return True

    def preempt_requested(self, task: Task) -> bool:
        """Lock-free peek for the checkpoint fast path: a stale read is
        benign (``consume_preempt`` re-checks under the lock)."""
        slot = task.slot
        return slot is not None and self._slots[slot].need_resched

    def consume_preempt(self, task: Task) -> bool:
        """Explicit preemption point: honour a pending ``request_preempt``.

        Returns True if the task was descheduled (the executor must park it
        until redispatch); the pending request converts into a ``preempt``
        for preemptive intra-job policies and a plain ``yield_`` otherwise
        (only reachable through a user-placed checkpoint in a cooperative
        task — the watchdog never flags SCHED_COOP slots)."""
        with self._lock:
            slot = task.slot
            if slot is None or not self._slots[slot].need_resched:
                return False
            if self.arbiter.policy_of(task.job).preemptive:
                self.preempt(task)
            else:
                self.yield_(task)
            return True

    def poll_preempt(self, task: Task) -> bool:
        """Checkpoint-driven slice-expiry poll — the self-ticking half of
        the fast preempt cycle.

        The real-thread runtime stamps ``_SlotState.slice_expiry`` at
        dispatch (run_started + the policy's per-task slice); a checkpoint
        that observes the expiry lock-free lands here, where the verdict is
        re-validated under the lock: exactly what a watchdog tick arriving
        at this instant would decide, but at checkpoint latency instead of
        tick latency. Returns True if the task was descheduled (the
        executor must park it). On a False verdict the expiry is pushed one
        slice forward so an uncontended loop does not take the lock at
        every checkpoint."""
        with self._lock:
            slot = task.slot
            if slot is None:
                return False
            st = self._slots[slot]
            if st.running is not task:
                return False
            if st.need_resched or \
                    self.arbiter.should_preempt(task, slot, self.clock()):
                self.poll_preempts += 1
                if self.arbiter.policy_of(task.job).preemptive:
                    self.preempt(task)
                else:
                    self.yield_(task)
                return True
            sl = self.arbiter.policy_of(task.job).slice_for(task)
            st.slice_expiry = (self.clock() + sl) if sl else 0.0
            return False

    def urgent_preempt(self, slot_id: int,
                       successor: Optional[Task] = None) -> bool:
        """``request_preempt`` plus the urgent extras under ONE lock: stash
        the preferred ``successor`` on the slot (consumed by the next
        ``_fill`` — redispatch skips the full pick) and fire the executor's
        ``on_urgent`` hook (the real-thread runtime kicks the watchdog's
        condition variable so the flag is serviced immediately instead of
        at the next heap deadline). Used by the deadline arbiter when a
        job's laxity goes negative. Returns False if the slot was idle."""
        with self._lock:
            st = self._slots[slot_id]
            if st.running is None:
                return False
            st.need_resched = True
            if successor is not None:
                st.successor = successor
            rec = self._rec
            if rec is not None:
                rec((self.clock(), REC_URGENT, slot_id,
                     None if successor is None else successor.tid))
            if self.on_urgent is not None:
                self.on_urgent(slot_id)
            return True

    # ------------------------------------------------------------------ #
    # internals
    # ------------------------------------------------------------------ #
    def _make_ready(self, task: Task, now: float) -> None:
        task.state = TaskState.READY
        task._ready_at = now  # type: ignore[attr-defined]
        self.arbiter.on_ready(task)

    def _stop_running(self, task: Task, reason: StopReason) -> tuple[int, float]:
        if task.state is not TaskState.RUNNING or task.slot is None:
            raise SchedulerError(f"stop of non-running {task}")
        slot = task.slot
        st = self._slots[slot]
        if st.running is not task:  # I1 violated
            raise SchedulerError(f"slot {slot} does not run {task}")
        now = self.clock()
        elapsed = now - st.run_started
        task.stats.run_time += elapsed
        task.job.service_time += elapsed
        rec = self._rec
        if rec is not None:
            rec((now, _REC_STOP[reason], task.tid, slot))
        self.arbiter.on_stop(task, slot, now, elapsed, reason)
        st.running = None
        st.need_resched = False  # any scheduling point satisfies the request
        st.slice_expiry = 0.0
        st.idle_since = now
        self._idle.add(slot)
        task._slot_state = None
        task.slot = None
        task.last_slot = slot  # preferred affinity for next time (§4.1)
        return slot, now

    def _fill(self, slot_id: int, now: float) -> Optional[Task]:
        """Pick and dispatch the next task for an idle slot."""
        st = self._slots[slot_id]
        if st.running is not None:
            return None
        if self._slot_target < len(self._slots) and \
                len(self._slots) - len(self._parked) > self._slot_target:
            # elastic parking: the effective width is capped and this slot
            # is surplus — withdraw it instead of refilling (the slot's
            # previous task, if any, was requeued by its scheduling point
            # and will resume on a surviving slot)
            self._idle.discard(slot_id)
            self._parked.add(slot_id)
            return None
        hint = st.successor
        if hint is not None:
            # urgent-grant redispatch: the arbiter already chose the
            # successor when it flagged this slot — claim it from its
            # policy queue and skip the full pick. The hint is validated
            # (still READY, still claimable); anything stale falls through
            # to the normal pick.
            st.successor = None
            if hint.state is TaskState.READY and self.arbiter.claim(hint):
                return self._run_on(hint, slot_id, now)
        task = self.arbiter.pick(slot_id)
        if task is None:
            return None
        return self._run_on(task, slot_id, now)

    def _fill_idle_slots(self, now: float) -> None:
        idle = self._idle
        arbiter = self.arbiter
        if not idle or not arbiter.has_ready():
            return
        for sid in sorted(idle):
            if self._slots[sid].running is None:
                if self._fill(sid, now) is None and not arbiter.has_ready():
                    break  # nothing ready for anyone

    def _run_on(self, task: Task, slot_id: int, now: float) -> Task:
        st = self._slots[slot_id]
        assert st.running is None, "I1"
        task.stats.wait_time += now - task._ready_at  # type: ignore[attr-defined]
        if task.stats.first_run_at is None:
            task.stats.first_run_at = now
        if task.last_slot is not None and task.last_slot != slot_id:
            task.stats.migrations += 1
            if self.topology.distance(task.last_slot, slot_id) >= 2:
                task.stats.cross_domain_migrations += 1
        task.state = TaskState.RUNNING
        task.slot = slot_id
        task.stats.dispatches += 1
        st.running = task
        st.run_started = now
        task._slot_state = st  # checkpoint fast path: one attribute hop
        self._idle.discard(slot_id)
        self._ctx_switch_time += self.ctx_switch_cost
        rec = self._rec
        if rec is not None:
            rec((now, REC_DISPATCH, task.tid, slot_id))
        self.arbiter.on_run(task, slot_id, now)
        self._dispatch_cb(task, slot_id)
        return task

    # ------------------------------------------------------------------ #
    # introspection / diagnostics
    # ------------------------------------------------------------------ #
    def running_on(self, slot_id: int) -> Optional[Task]:
        """Lock-free peek at one slot (single-threaded executors only —
        the sim engine's tick path; racy under the real-thread runtime)."""
        return self._slots[slot_id].running

    def running_tasks(self) -> list[Optional[Task]]:
        with self._lock:
            return [s.running for s in self._slots]

    def slots_running(self, job: Job) -> list[int]:
        """Slots currently running ``job``'s tasks (executors use this to
        arm preemption ticks for a live re-homed job)."""
        with self._lock:
            return [i for i, s in enumerate(self._slots)
                    if s.running is not None and s.running.job is job]

    def idle_slot_ids(self) -> list[int]:
        with self._lock:
            return sorted(self._idle)

    def snapshot(self) -> dict:
        with self._lock:
            states: dict[str, int] = {}
            for t in self.all_tasks:
                states[t.state.value] = states.get(t.state.value, 0) + 1
            return {
                "now": self.clock(),
                "policy": self.arbiter.describe(),
                "slots_busy": (self.topology.n_slots - len(self._idle)
                               - len(self._parked)),
                "slots": self.topology.n_slots,
                "slots_parked": len(self._parked),
                "slot_target": self._slot_target,
                "task_states": states,
                "ready": self.arbiter.ready_count(),
                "leases": self.arbiter.lease_snapshot(),
            }

    def stats(self) -> SchedStats:
        with self._lock:  # all_tasks/slot accounting mutate under _lock
            s = collect(
                self.all_tasks,
                makespan=self.clock() - self._started_at,
                n_slots=self.topology.n_slots,
            )
            s.context_switch_time = self._ctx_switch_time
        return s
