"""Simulation task vocabulary: ops and synchronization objects.

A sim task body is a generator yielding *op tuples*; the discrete-event
engine (events.py) interprets them. Ops mirror the glibc APIs the paper
interposes (§4.3.4: mutex, condvar, barrier, semaphore, sleep, yield, poll)
plus compute, spawn/join (pthread_create/join, §4.3.1) and a channel
(poll/epoll-style request queues for the microservices benchmark).

Values can be received from ops:  ``item = yield channel_get(ch)``.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Deque, Optional

from repro.core.task import Task

_OID = itertools.count()


# --------------------------------------------------------------------------- #
# op constructors (plain tuples; constructors only prevent typos)
# --------------------------------------------------------------------------- #
def compute(seconds: float, *, flops: float = 0.0) -> tuple:
    """Uninterrupted useful work for ``seconds`` (preemptible by preemptive
    policies). ``flops`` is bookkeeping for throughput metrics."""
    return ("compute", float(seconds), float(flops))


def stall(seconds: float) -> tuple:
    """Work that holds the slot but is *not* useful (un-intercepted blocking
    I/O, §5.6: 'blocking MPI communications stall cores until they complete')."""
    return ("stall", float(seconds))


def lock(m: "SimMutex") -> tuple:
    return ("lock", m)


def unlock(m: "SimMutex") -> tuple:
    return ("unlock", m)


def barrier_wait(b: "SimBarrier") -> tuple:
    return ("barrier", b)


def spin_barrier_wait(b: "SimSpinBarrier") -> tuple:
    return ("spin_barrier", b)


def sem_acquire(s: "SimSemaphore") -> tuple:
    return ("sem_acquire", s)


def sem_release(s: "SimSemaphore") -> tuple:
    return ("sem_release", s)


def cv_wait(cv: "SimCondVar", m: "SimMutex") -> tuple:
    return ("cv_wait", cv, m)


def cv_notify(cv: "SimCondVar", n: int = 1) -> tuple:
    return ("cv_notify", cv, n)


def sleep(seconds: float) -> tuple:
    """nosv_waitfor-style timed block: slot is released, task auto-resubmits."""
    return ("sleep", float(seconds))


def sleep_until(t: float) -> tuple:
    """Absolute-time block: wake at virtual time ``t`` (clamped to now).
    The trace replayer encodes recorded sync blocks with this, replaying
    each wake at its recorded timestamp."""
    return ("sleep_until", float(t))


def yield_() -> tuple:
    return ("yield",)


def checkpoint() -> tuple:
    """Explicit preemption point (usf.checkpoint analogue): consumes a
    pending external preemption request against the task's slot, else a
    no-op that keeps the generator advancing synchronously."""
    return ("checkpoint",)


def spawn(task: Task) -> tuple:
    return ("spawn", task)


def join(task: Task) -> tuple:
    return ("join", task)


def channel_put(ch: "SimChannel", item: Any) -> tuple:
    return ("channel_put", ch, item)


def channel_get(ch: "SimChannel") -> tuple:
    return ("channel_get", ch)


# --------------------------------------------------------------------------- #
# synchronization objects (state only; engine interprets)
# --------------------------------------------------------------------------- #
class _SyncObj:
    def __init__(self) -> None:
        self.oid = next(_OID)


class SimMutex(_SyncObj):
    """Paper Listing 1: FIFO wait queue; unlock transfers ownership."""

    def __init__(self) -> None:
        super().__init__()
        self.owner: Optional[Task] = None
        self.queue: Deque[Task] = deque()


class SimBarrier(_SyncObj):
    """Cooperative (blocking) barrier: arrivals block, last arrival releases."""

    def __init__(self, parties: int):
        super().__init__()
        assert parties >= 1
        self.parties = parties
        self.count = 0
        self.generation = 0
        self.waiting: Deque[Task] = deque()


class SimSpinBarrier(_SyncObj):
    """Busy-wait barrier (the §5.2/§4.4 troublemaker).

    Spinning *consumes the slot*. ``yield_every`` is the paper's one-line
    adaptation (occasional sched_yield); ``None`` reproduces the unmodified
    OpenBLAS/BLIS/MPICH behaviour, which can livelock SCHED_COOP when
    waiting threads exceed slots (§4.4) and wastes quanta under preemptive
    scheduling (§5.3 'Original').
    """

    def __init__(self, parties: int, *, spin_slice: float = 50e-6,
                 yield_every: Optional[int] = 0):
        super().__init__()
        assert parties >= 1
        self.parties = parties
        self.spin_slice = spin_slice
        # yield_every=0 means "yield every check" (sched_yield loop);
        # None means never yield (pure busy wait).
        self.yield_every = yield_every
        self.count = 0
        self.generation = 0


class SimSemaphore(_SyncObj):
    def __init__(self, value: int = 0):
        super().__init__()
        self.value = value
        self.queue: Deque[Task] = deque()


class SimCondVar(_SyncObj):
    def __init__(self) -> None:
        super().__init__()
        self.waiting: Deque[tuple[Task, "SimMutex"]] = deque()


class SimChannel(_SyncObj):
    """FIFO message queue; ``get`` blocks when empty (epoll-ish wait)."""

    def __init__(self) -> None:
        super().__init__()
        self.items: Deque[Any] = deque()
        self.getters: Deque[Task] = deque()


@dataclasses.dataclass
class SimCosts:
    """Calibration constants for the event engine.

    Defaults are CPU-node ballparks (context switch ~5 us, NUMA-local warm-up
    ~20 us, remote ~100 us). ``cache_refill`` is charged when a task resumes
    on a slot whose cache another task polluted in between (the preemption
    cache-pollution effect the paper targets); per-task ``warmup_scale``
    scales all warm-up penalties by working-set size (ws_bytes / mem_bw).
    TPU-slot runs override these with HBM state-swap costs.
    """

    ctx_switch: float = 5e-6
    migration_domain: float = 20e-6
    migration_cross: float = 100e-6
    cache_refill: float = 20e-6
    dispatch_latency: float = 1e-6

    def migration_penalty(self, distance: int) -> float:
        if distance <= 0:
            return 0.0
        return self.migration_domain if distance == 1 else self.migration_cross
