from repro.core.policies.base import Policy, StopReason
from repro.core.policies.sched_coop import SchedCoop
from repro.core.policies.sched_fair import SchedFair
from repro.core.policies.sched_rr import SchedRR

__all__ = ["Policy", "StopReason", "SchedCoop", "SchedFair", "SchedRR"]
