"""Policy interface — the user-extensible part of USF.

The paper's pitch is that USF "enables users to implement their own process
scheduling algorithms without requiring special permissions"; this class is
that extension point. A policy only sees scheduling points; the Scheduler
enforces the framework invariants around it.
"""

from __future__ import annotations

import enum
from typing import TYPE_CHECKING, AbstractSet, Optional

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.scheduler import Scheduler
    from repro.core.task import Job, Task


class StopReason(enum.Enum):
    BLOCK = "block"
    YIELD = "yield"
    DONE = "done"
    PREEMPT = "preempt"


class Policy:
    """Base policy. Subclasses override the queueing/picking logic."""

    name: str = "base"
    #: preemptive policies model the OS baseline; SCHED_COOP must keep False.
    preemptive: bool = False
    #: sim-engine tick granularity for preemptive policies (seconds).
    tick_interval: Optional[float] = None

    def __init__(self) -> None:
        self.sched: Optional["Scheduler"] = None

    # -- lifecycle ------------------------------------------------------ #
    def attach(self, sched: "Scheduler") -> None:
        self.sched = sched

    def on_job(self, job: "Job") -> None:
        """A job (process) registered with the scheduler."""

    def on_job_detach(self, job: "Job") -> None:
        """A job left this policy (arbiter detach, or a live re-home out
        of the *default* group — dedicated groups are dropped wholesale
        on swap/demote instead). The job's per-job queues are empty
        either way: a quiescent detach has no READY tasks by contract,
        and a live re-home withdraws them via ``remove`` first — but the
        job MAY still have RUNNING tasks on the live path, so only queue
        and per-task bookkeeping may be dropped here, never slot state."""

    # -- scheduling points ---------------------------------------------- #
    def on_ready(self, task: "Task") -> None:
        raise NotImplementedError

    def pick(self, slot_id: int) -> Optional["Task"]:
        raise NotImplementedError

    def on_run(self, task: "Task", slot_id: int, now: float) -> None:
        pass

    def on_stop(
        self, task: "Task", slot_id: int, now: float, elapsed: float, reason: StopReason
    ) -> None:
        pass

    def should_preempt(self, task: "Task", slot_id: int, now: float) -> bool:
        return False

    def slice_for(self, task: "Task") -> Optional[float]:
        """The running time after which ``should_preempt`` would evict
        ``task`` — i.e. the task's *effective* slice, which may be shorter
        than ``tick_interval`` (SCHED_FAIR divides the slice by weight).
        ``None`` means the task never slice-expires (non-preemptive
        policies). The real-thread runtime stamps this on the slot at
        dispatch so checkpoints can self-detect expiry without waiting for
        a watchdog tick (the fast preempt cycle)."""
        return self.tick_interval if self.preemptive else None

    # -- migration support (live job re-homing, arbiter attach) ---------- #
    def remove(self, task: "Task") -> None:
        """Detach a READY task from the pool without dispatching it.

        The inverse of ``on_ready``: after ``remove`` the task is no longer
        pickable here and all incremental pool accounting must be as if it
        had never been admitted. The arbiter uses this to surrender one
        job's queued tasks when the job re-homes to another policy group —
        every edge of the any↔any migration matrix (promotion, live policy
        swap, demotion) funnels through it, so it must stay correct under
        arbitrary withdraw-all/re-admit churn (locksteped against RefFair
        in tests/test_sched_fastpath.py).
        Raises ``KeyError`` if the task is not queued here.
        """
        raise NotImplementedError

    def pick_filtered(
        self, slot_id: int, allowed_jids: AbstractSet[int]
    ) -> Optional["Task"]:
        """Like ``pick`` but only tasks of jobs in ``allowed_jids`` may be
        returned. Used for per-job lease enforcement inside a shared group:
        the arbiter restricts the grant to under-lease member jobs while a
        sibling member is over its lease (the job-granular I5 analogue).
        """
        raise NotImplementedError

    # -- introspection --------------------------------------------------- #
    def ready_count(self) -> int:
        raise NotImplementedError

    def ready_count_of(self, job: "Job") -> int:
        """READY tasks of one job queued in this policy (job-filtered pick
        and migration support; policies keep this O(1))."""
        raise NotImplementedError

    def has_ready(self) -> bool:
        return self.ready_count() > 0
