"""Policy interface — the user-extensible part of USF.

The paper's pitch is that USF "enables users to implement their own process
scheduling algorithms without requiring special permissions"; this class is
that extension point. A policy only sees scheduling points; the Scheduler
enforces the framework invariants around it.
"""

from __future__ import annotations

import enum
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.scheduler import Scheduler
    from repro.core.task import Job, Task


class StopReason(enum.Enum):
    BLOCK = "block"
    YIELD = "yield"
    DONE = "done"
    PREEMPT = "preempt"


class Policy:
    """Base policy. Subclasses override the queueing/picking logic."""

    name: str = "base"
    #: preemptive policies model the OS baseline; SCHED_COOP must keep False.
    preemptive: bool = False
    #: sim-engine tick granularity for preemptive policies (seconds).
    tick_interval: Optional[float] = None

    def __init__(self) -> None:
        self.sched: Optional["Scheduler"] = None

    # -- lifecycle ------------------------------------------------------ #
    def attach(self, sched: "Scheduler") -> None:
        self.sched = sched

    def on_job(self, job: "Job") -> None:
        """A job (process) registered with the scheduler."""

    def on_job_detach(self, job: "Job") -> None:
        """A job unregistered (arbiter detach). The job is quiescent: no
        READY/RUNNING tasks remain, so per-job queues are empty."""

    # -- scheduling points ---------------------------------------------- #
    def on_ready(self, task: "Task") -> None:
        raise NotImplementedError

    def pick(self, slot_id: int) -> Optional["Task"]:
        raise NotImplementedError

    def on_run(self, task: "Task", slot_id: int, now: float) -> None:
        pass

    def on_stop(
        self, task: "Task", slot_id: int, now: float, elapsed: float, reason: StopReason
    ) -> None:
        pass

    def should_preempt(self, task: "Task", slot_id: int, now: float) -> bool:
        return False

    # -- introspection --------------------------------------------------- #
    def ready_count(self) -> int:
        raise NotImplementedError

    def has_ready(self) -> bool:
        return self.ready_count() > 0
