"""SCHED_COOP — the paper's default cooperative policy (§3, §4.1).

Behaviour reproduced from the paper:

* Threads run uninterrupted with fixed single-slot affinity until the
  *application* makes them wait; SCHED_COOP never preempts (I2).
* A previously blocked task is queued in a **per-job, per-slot FIFO** keyed
  by the last slot it ran on.
* Placement search order: idle slot matching affinity → same locality
  domain (NUMA / ICI neighborhood) → anywhere.
* A per-job quantum (default 20 ms), **evaluated only at scheduling
  points**, rotates service between jobs; like nOS-V, the rotation is
  work-conserving: if the current job has nothing ready for a slot, tasks
  of other jobs are served rather than idling the slot.
"""

from __future__ import annotations

from collections import OrderedDict, deque
from typing import Deque, Optional

from repro.core.policies.base import Policy, StopReason
from repro.core.task import Job, Task

DEFAULT_QUANTUM = 0.020  # 20 ms, the paper's default


class _JobQueues:
    """Per-job ready queues: one FIFO per preferred slot + one unaffine FIFO."""

    __slots__ = ("job", "per_slot", "unaffine", "size")

    def __init__(self, job: Job):
        self.job = job
        self.per_slot: dict[int, Deque[Task]] = {}
        self.unaffine: Deque[Task] = deque()
        self.size = 0

    def push(self, task: Task) -> None:
        # A yielding task goes to the back of the global order (nosv_yield):
        # re-enqueueing it by affinity would let it get re-picked instantly,
        # defeating the §5.2 busy-wait adaptation.
        if task._yielded:
            task._yielded = False
            self.unaffine.append(task)
        elif task.last_slot is None:
            self.unaffine.append(task)
        else:
            self.per_slot.setdefault(task.last_slot, deque()).append(task)
        self.size += 1

    def pop_for(self, slot_id: int, neighbors) -> Optional[Task]:
        """Affinity → unaffine (new work) → same domain → anywhere (§4.1)."""
        q = self.per_slot.get(slot_id)
        if q:
            self.size -= 1
            return q.popleft()
        if self.unaffine:
            self.size -= 1
            return self.unaffine.popleft()
        for s in neighbors:  # distance-ordered, slot_id first (already tried)
            q = self.per_slot.get(s.sid)
            if q:
                self.size -= 1
                return q.popleft()
        return None

    def withdraw(self, task: Task) -> bool:
        """Remove one specific queued task (migration support)."""
        q = self.per_slot.get(task.last_slot)
        if q is not None:
            try:
                q.remove(task)
            except ValueError:
                pass
            else:
                self.size -= 1
                return True
        try:
            self.unaffine.remove(task)
        except ValueError:
            return False
        self.size -= 1
        return True


class SchedCoop(Policy):
    name = "SCHED_COOP"
    preemptive = False

    def __init__(self, *, quantum: float = DEFAULT_QUANTUM):
        super().__init__()
        self.default_quantum = quantum
        self._jobs: "OrderedDict[int, _JobQueues]" = OrderedDict()
        self._current_jid: Optional[int] = None
        self._quantum_used: float = 0.0
        # registration-ordered job list + positions: the rotation order is
        # index arithmetic over this list, never rebuilt per pick
        self._jid_list: list[int] = []
        self._jid_pos: dict[int, int] = {}

    # -- job management -------------------------------------------------- #
    def on_job(self, job: Job) -> None:
        if job.jid not in self._jobs:
            self._jobs[job.jid] = _JobQueues(job)
            self._jid_pos[job.jid] = len(self._jid_list)
            self._jid_list.append(job.jid)
            if self._current_jid is None:
                self._current_jid = job.jid

    def on_job_detach(self, job: Job) -> None:
        jq = self._jobs.pop(job.jid, None)
        if jq is None:
            return
        if jq.size:  # arbiter withdraws queued work first; guard anyway
            self._jobs[job.jid] = jq
            left = [t.name for q in jq.per_slot.values() for t in q]
            left += [t.name for t in jq.unaffine]
            raise ValueError(
                f"detach of {job} with {jq.size} queued task(s) still in "
                f"this policy: {', '.join(left[:8])}"
            )
        self._jid_list.remove(job.jid)
        self._jid_pos = {jid: i for i, jid in enumerate(self._jid_list)}
        if self._current_jid == job.jid:
            self._current_jid = self._jid_list[0] if self._jid_list else None
            self._quantum_used = 0.0

    # -- queueing --------------------------------------------------------- #
    def on_ready(self, task: Task) -> None:
        self.on_job(task.job)
        self._jobs[task.job.jid].push(task)

    def remove(self, task: Task) -> None:
        jq = self._jobs.get(task.job.jid)
        if jq is None or not jq.withdraw(task):
            raise KeyError(f"{task} is not queued in {self.name}")

    def _job_quantum(self, jid: int) -> float:
        q = self._jobs[jid].job.quantum
        return q if q is not None else self.default_quantum

    def _rotate_if_expired(self) -> None:
        """Quantum evaluation — only ever called from scheduling points."""
        if self._current_jid is None:
            return
        if self._quantum_used >= self._job_quantum(self._current_jid):
            self._advance_current()

    def _advance_current(self) -> None:
        jids = self._jid_list
        if not jids:
            return
        i = self._jid_pos.get(self._current_jid, -1)
        n = len(jids)
        # next job with ready tasks; else keep rotating pointer anyway
        for off in range(1, n + 1):
            jid = jids[(i + off) % n]
            self._current_jid = jid
            self._quantum_used = 0.0
            if self._jobs[jid].size > 0:
                return

    # -- picking ----------------------------------------------------------- #
    def pick(self, slot_id: int) -> Optional[Task]:
        self._rotate_if_expired()
        assert self.sched is not None
        neighbors = self.sched.topology.neighbors_first(slot_id)
        jobs = self._jobs
        jids = self._jid_list
        n = len(jids)
        # rotation order: current job first, then registration order wrapped
        start = self._jid_pos.get(self._current_jid, 0)
        for off in range(n):
            jq = jobs[jids[(start + off) % n]]
            if jq.size:  # empty jobs can't serve: skip the placement search
                task = jq.pop_for(slot_id, neighbors)
                if task is not None:
                    return task
        return None

    def pick_filtered(self, slot_id: int, allowed_jids) -> Optional[Task]:
        """``pick`` restricted to member jobs in ``allowed_jids`` (per-job
        lease enforcement inside a shared group); same rotation order."""
        self._rotate_if_expired()
        assert self.sched is not None
        neighbors = self.sched.topology.neighbors_first(slot_id)
        jobs = self._jobs
        jids = self._jid_list
        n = len(jids)
        start = self._jid_pos.get(self._current_jid, 0)
        for off in range(n):
            jid = jids[(start + off) % n]
            if jid not in allowed_jids:
                continue
            jq = jobs[jid]
            if jq.size:
                task = jq.pop_for(slot_id, neighbors)
                if task is not None:
                    return task
        return None

    # -- accounting --------------------------------------------------------- #
    def on_stop(
        self, task: Task, slot_id: int, now: float, elapsed: float, reason: StopReason
    ) -> None:
        if task.job.jid == self._current_jid:
            self._quantum_used += elapsed

    # -- introspection ------------------------------------------------------- #
    def ready_count(self) -> int:
        return sum(j.size for j in self._jobs.values())

    def ready_count_of(self, job: Job) -> int:
        jq = self._jobs.get(job.jid)
        return jq.size if jq is not None else 0
