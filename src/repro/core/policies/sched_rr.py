"""SCHED_RR — round-robin preemptive baseline (paper §3 comparison point).

A single global FIFO; running tasks are preempted when their quantum expires
and requeued at the tail. Unlike the real SCHED_RR class it has no priority
bands — the paper only uses it as a conceptual reference ("SCHED_COOP
resembles SCHED_RR, where threads run until they yield or block", except
SCHED_RR still time-slices among same-priority peers).
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Optional

from repro.core.policies.base import Policy, StopReason
from repro.core.task import Task


class SchedRR(Policy):
    name = "SCHED_RR"
    preemptive = True

    def __init__(self, *, quantum: float = 0.010):
        super().__init__()
        self.quantum = quantum
        self.tick_interval = quantum
        self._q: Deque[Task] = deque()
        self._run_started: dict[int, float] = {}
        self._per_job: dict[int, int] = {}

    def on_job_detach(self, job) -> None:
        # queues hold none of the job's tasks by contract (quiescent, or
        # withdrawn via remove() on a live re-home); drop the slice-start
        # stamps so a default-group SchedRR does not leak them across
        # swap churn
        for t in job.tasks:
            self._run_started.pop(t.tid, None)

    def on_ready(self, task: Task) -> None:
        self._q.append(task)
        jid = task.job.jid
        self._per_job[jid] = self._per_job.get(jid, 0) + 1

    def _drop_count(self, task: Task) -> None:
        jid = task.job.jid
        left = self._per_job[jid] - 1
        if left:
            self._per_job[jid] = left
        else:
            del self._per_job[jid]

    def pick(self, slot_id: int) -> Optional[Task]:
        if not self._q:
            return None
        task = self._q.popleft()
        self._drop_count(task)
        return task

    def pick_filtered(self, slot_id: int, allowed_jids) -> Optional[Task]:
        """First-in-FIFO task of an allowed job (O(n) scan: the filtered
        path only runs under per-job lease enforcement)."""
        for task in self._q:
            if task.job.jid in allowed_jids:
                self._q.remove(task)
                self._drop_count(task)
                return task
        return None

    def remove(self, task: Task) -> None:
        try:
            self._q.remove(task)
        except ValueError:
            raise KeyError(f"{task} is not queued in {self.name}") from None
        self._drop_count(task)

    def on_run(self, task: Task, slot_id: int, now: float) -> None:
        self._run_started[task.tid] = now

    def should_preempt(self, task: Task, slot_id: int, now: float) -> bool:
        if not self._q:
            return False
        return (now - self._run_started.get(task.tid, now)) >= self.quantum

    def ready_count(self) -> int:
        return len(self._q)

    def ready_count_of(self, job) -> int:
        return self._per_job.get(job.jid, 0)
