"""SCHED_RR — round-robin preemptive baseline (paper §3 comparison point).

A single global FIFO; running tasks are preempted when their quantum expires
and requeued at the tail. Unlike the real SCHED_RR class it has no priority
bands — the paper only uses it as a conceptual reference ("SCHED_COOP
resembles SCHED_RR, where threads run until they yield or block", except
SCHED_RR still time-slices among same-priority peers).
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Optional

from repro.core.policies.base import Policy, StopReason
from repro.core.task import Task


class SchedRR(Policy):
    name = "SCHED_RR"
    preemptive = True

    def __init__(self, *, quantum: float = 0.010):
        super().__init__()
        self.quantum = quantum
        self.tick_interval = quantum
        self._q: Deque[Task] = deque()
        self._run_started: dict[int, float] = {}

    def on_ready(self, task: Task) -> None:
        self._q.append(task)

    def pick(self, slot_id: int) -> Optional[Task]:
        return self._q.popleft() if self._q else None

    def on_run(self, task: Task, slot_id: int, now: float) -> None:
        self._run_started[task.tid] = now

    def should_preempt(self, task: Task, slot_id: int, now: float) -> bool:
        if not self._q:
            return False
        return (now - self._run_started.get(task.tid, now)) >= self.quantum

    def ready_count(self) -> int:
        return len(self._q)
