"""SCHED_FAIR — EEVDF-like preemptive baseline (the Linux stand-in, §2.1).

Earliest Eligible Virtual Deadline First [Stoica & Abdel-Wahab '95], the
Linux default since 6.6:

* each task accrues *virtual runtime* at rate 1/weight (weight from nice);
* a task is *eligible* when its vruntime is not ahead of the pool's virtual
  time V (its lag is >= 0);
* among eligible tasks, pick the earliest virtual deadline
  ``vd = vruntime + slice/weight``;
* running tasks are preempted when their slice expires (time quantum),
  regardless of what they are doing — this is precisely the behaviour that
  produces Lock-Holder/Lock-Waiter Preemption under oversubscription.

Placement is affinity-blind by design: like the kernel's fair class with
regular load balancing, tasks migrate freely between slots, modelling the
"OS lack of application awareness" the paper discusses.
"""

from __future__ import annotations

from typing import Optional

from repro.core.policies.base import Policy, StopReason
from repro.core.task import Task

DEFAULT_SLICE = 0.003  # ~3 ms, Linux base_slice ballpark


def nice_to_weight(nice: int) -> float:
    """Linux sched_prio_to_weight spacing: ~+10% CPU per -1 nice."""
    return 1024.0 / (1.25 ** nice)


class SchedFair(Policy):
    name = "SCHED_FAIR"
    preemptive = True

    def __init__(self, *, slice_s: float = DEFAULT_SLICE):
        super().__init__()
        self.slice_s = slice_s
        self.tick_interval = slice_s
        self._ready: list[Task] = []
        self._vruntime: dict[int, float] = {}
        self._run_started: dict[int, float] = {}
        self._min_vruntime = 0.0

    # -- helpers ---------------------------------------------------------- #
    def _w(self, task: Task) -> float:
        return nice_to_weight(task.job.nice)

    def _vr(self, task: Task) -> float:
        return self._vruntime.setdefault(task.tid, self._min_vruntime)

    def _pool_virtual_time(self) -> float:
        """V = weighted average vruntime over the ready pool."""
        if not self._ready:
            return self._min_vruntime
        wsum = sum(self._w(t) for t in self._ready)
        return sum(self._vr(t) * self._w(t) for t in self._ready) / wsum

    def _deadline(self, task: Task) -> float:
        return self._vr(task) + self.slice_s / self._w(task)

    # -- policy ----------------------------------------------------------- #
    def on_ready(self, task: Task) -> None:
        # Sleepers rejoin at max(own vruntime, pool floor): they don't hoard
        # lag while blocked (Linux place_entity behaviour, simplified).
        self._vruntime[task.tid] = max(self._vr(task), self._min_vruntime)
        self._ready.append(task)

    def pick(self, slot_id: int) -> Optional[Task]:
        if not self._ready:
            return None
        V = self._pool_virtual_time()
        eligible = [t for t in self._ready if self._vr(t) <= V + 1e-12]
        pool = eligible if eligible else self._ready
        # wake affinity (select_task_rq prev-CPU preference): among the
        # eligible set, prefer tasks that last ran on this slot
        local = [t for t in pool if t.last_slot in (slot_id, None)]
        best = min(local or pool, key=self._deadline)
        self._ready.remove(best)
        return best

    def on_run(self, task: Task, slot_id: int, now: float) -> None:
        self._run_started[task.tid] = now

    def on_stop(
        self, task: Task, slot_id: int, now: float, elapsed: float, reason: StopReason
    ) -> None:
        vr = self._vr(task) + elapsed / self._w(task)
        self._vruntime[task.tid] = vr
        if self._ready:
            self._min_vruntime = max(
                self._min_vruntime, min(self._vr(t) for t in self._ready)
            )
        else:
            self._min_vruntime = max(self._min_vruntime, vr)

    def should_preempt(self, task: Task, slot_id: int, now: float) -> bool:
        if not self._ready:
            return False  # nothing to run instead: keep going
        ran = now - self._run_started.get(task.tid, now)
        return ran >= self.slice_s / self._w(task)

    def ready_count(self) -> int:
        return len(self._ready)
