"""SCHED_FAIR — EEVDF-like preemptive baseline (the Linux stand-in, §2.1).

Earliest Eligible Virtual Deadline First [Stoica & Abdel-Wahab '95], the
Linux default since 6.6:

* each task accrues *virtual runtime* at rate 1/weight (weight from nice);
* a task is *eligible* when its vruntime is not ahead of the pool's virtual
  time V (its lag is >= 0);
* among eligible tasks, pick the earliest virtual deadline
  ``vd = vruntime + slice/weight``;
* running tasks are preempted when their slice expires (time quantum),
  regardless of what they are doing — this is precisely the behaviour that
  produces Lock-Holder/Lock-Waiter Preemption under oversubscription.

Placement is affinity-blind by design: like the kernel's fair class with
regular load balancing, tasks migrate freely between slots, modelling the
"OS lack of application awareness" the paper discusses.

Implementation: a task's vruntime — and therefore its virtual deadline —
is frozen while it sits in the ready pool (it only advances on ``on_stop``,
and ``on_ready`` clamps once at admission). That makes every per-pick
quantity incrementally maintainable:

* the pool virtual time V = sum(w·vr)/sum(w) is kept as two running sums,
  reset to exact zero whenever the pool drains and resynced exactly at
  every heap compaction, so incremental float drift is bounded to a few
  hundred add/subtract ops — orders of magnitude below the 1e-12
  eligibility slack that both implementations share;
* candidates live in deadline-keyed heaps — one global, plus one per
  ``last_slot`` bucket for the wake-affinity preference — with lazy
  invalidation: picking a task merely drops its entry token, stale
  entries are discarded when they surface at a heap top, and the heaps
  are compacted (rebuilt from live entries) once stale entries dominate,
  keeping memory O(live);
* the ready-pool minimum vruntime (the ``min_vruntime`` floor update in
  ``on_stop``) comes from a vruntime-keyed heap with the same lazy scheme.

Tie-breaks are by admission order (a monotone sequence number), which is
exactly the list order the original O(n²) scan used, so pick order — and
therefore every simulated makespan — is preserved (property-tested in
lockstep against the reference implementation, and pinned on the fig3
benchmark cells).
"""

from __future__ import annotations

from heapq import heapify, heappop, heappush
from typing import Optional

from repro.core.policies.base import Policy, StopReason
from repro.core.task import Task

DEFAULT_SLICE = 0.003  # ~3 ms, Linux base_slice ballpark

_ELIGIBLE_EPS = 1e-12  # slack on the vr <= V eligibility comparison


def nice_to_weight(nice: int) -> float:
    """Linux sched_prio_to_weight spacing: ~+10% CPU per -1 nice."""
    return 1024.0 / (1.25 ** nice)


class SchedFair(Policy):
    name = "SCHED_FAIR"
    preemptive = True

    def __init__(self, *, slice_s: float = DEFAULT_SLICE):
        super().__init__()
        self.slice_s = slice_s
        self.tick_interval = slice_s
        self._vruntime: dict[int, float] = {}
        self._run_started: dict[int, float] = {}
        self._min_vruntime = 0.0
        # -- incremental ready-pool state -------------------------------- #
        self._nready = 0
        self._wsum = 0.0     # sum of weights over the ready pool
        self._wvsum = 0.0    # sum of weight*vruntime over the ready pool
        self._seq = 0        # admission counter: heap tie-break = FIFO order
        #: tid -> live entry seq; an entry (key, seq, task) is stale unless
        #: ``_live.get(task.tid) == seq`` (lazy invalidation)
        self._live: dict[int, int] = {}
        #: jid -> READY tasks of that job in the pool (job-filtered picks)
        self._per_job: dict[int, int] = {}
        self._dl_all: list[tuple[float, int, Task]] = []
        #: last_slot (int | None) -> deadline heap of that affinity bucket
        self._dl_by_slot: dict[Optional[int], list[tuple[float, int, Task]]] = {}
        self._vr_heap: list[tuple[float, int, Task]] = []

    # -- helpers ---------------------------------------------------------- #
    def _w(self, task: Task) -> float:
        return nice_to_weight(task.job.nice)

    def _vr(self, task: Task) -> float:
        return self._vruntime.setdefault(task.tid, self._min_vruntime)

    def _deadline(self, task: Task) -> float:
        return self._vr(task) + self.slice_s / self._w(task)

    # -- heap scans (lazy invalidation) ----------------------------------- #
    def _min_eligible(self, heap, vmax: float):
        """Smallest live (deadline, seq) entry whose vruntime <= vmax.

        Stale entries surfacing at the top are dropped for good; live but
        ineligible entries are popped into a side buffer and pushed back
        (rare: deadline order ~ vruntime order unless weights diverge).
        """
        live = self._live
        vruntime = self._vruntime
        buf = None
        found = None
        while heap:
            entry = heap[0]
            task = entry[2]
            if live.get(task.tid) != entry[1]:
                heappop(heap)
                continue
            if vruntime[task.tid] <= vmax:
                found = entry
                break
            if buf is None:
                buf = []
            buf.append(heappop(heap))
        if buf is not None:
            for entry in buf:
                heappush(heap, entry)
        return found

    def _live_top(self, heap):
        """Smallest live (deadline, seq) entry, ignoring eligibility."""
        live = self._live
        while heap:
            entry = heap[0]
            if live.get(entry[2].tid) == entry[1]:
                return entry
            heappop(heap)
        return None

    def _remove(self, entry) -> Task:
        """Invalidate a picked task's entries and update the pool sums."""
        task = entry[2]
        del self._live[task.tid]
        jid = task.job.jid
        left = self._per_job[jid] - 1
        if left:
            self._per_job[jid] = left
        else:
            del self._per_job[jid]
        w = self._w(task)
        self._nready -= 1
        if self._nready == 0:
            # exact reset: no float residue survives an empty pool
            self._wsum = 0.0
            self._wvsum = 0.0
            self._dl_all.clear()
            self._dl_by_slot.clear()
            self._vr_heap.clear()
        else:
            self._wsum -= w
            self._wvsum -= self._vruntime[task.tid] * w
        return task

    def _compact(self) -> None:
        """Rebuild the heaps from live entries and resync the pool sums.

        Triggered when stale entries dominate (amortized O(1) per op): this
        bounds heap memory to O(live) even when the pool never drains, and
        squashes any float drift the incremental sums picked up since the
        last exact reset.
        """
        live = self._live
        entries = [e for e in self._dl_all if live.get(e[2].tid) == e[1]]
        heapify(entries)
        self._dl_all = entries
        # last_slot is frozen while a task is in the pool, so the bucket
        # key at admission is still correct here
        buckets: dict = {}
        for e in entries:
            buckets.setdefault(e[2].last_slot, []).append(e)
        for b in buckets.values():
            heapify(b)
        self._dl_by_slot = buckets
        vrs = [e for e in self._vr_heap if live.get(e[2].tid) == e[1]]
        heapify(vrs)
        self._vr_heap = vrs
        wsum = 0.0
        wvsum = 0.0
        vruntime = self._vruntime
        for e in entries:
            w = self._w(e[2])
            wsum += w
            wvsum += vruntime[e[2].tid] * w
        self._wsum = wsum
        self._wvsum = wvsum

    def on_job_detach(self, job) -> None:
        # No READY tasks remain by contract (quiescent detach, or a live
        # re-home that already withdrew them via remove()), so dropping
        # the per-task accounting cannot orphan a queued entry. Without
        # this the default group would leak vruntime entries for every
        # job that ever promoted out of it (swap-churn workloads).
        for t in job.tasks:
            self._vruntime.pop(t.tid, None)
            self._run_started.pop(t.tid, None)

    # -- policy ----------------------------------------------------------- #
    def on_ready(self, task: Task) -> None:
        # Sleepers rejoin at max(own vruntime, pool floor): they don't hoard
        # lag while blocked (Linux place_entity behaviour, simplified).
        vr = max(self._vr(task), self._min_vruntime)
        self._vruntime[task.tid] = vr
        if len(self._dl_all) > 64 and len(self._dl_all) > 4 * self._nready:
            self._compact()
        w = self._w(task)
        seq = self._seq
        self._seq = seq + 1
        self._live[task.tid] = seq
        entry = (vr + self.slice_s / w, seq, task)
        heappush(self._dl_all, entry)
        bucket = self._dl_by_slot.get(task.last_slot)
        if bucket is None:
            bucket = self._dl_by_slot[task.last_slot] = []
        heappush(bucket, entry)
        heappush(self._vr_heap, (vr, seq, task))
        self._nready += 1
        jid = task.job.jid
        self._per_job[jid] = self._per_job.get(jid, 0) + 1
        self._wsum += w
        self._wvsum += vr * w

    def remove(self, task: Task) -> None:
        """Detach a READY task (live migration): same sum/heap maintenance
        as a pick-removal — the heap tokens go stale and are dropped
        lazily, so incremental V stays exact vs the reference policy."""
        if self._live.get(task.tid) is None:
            raise KeyError(f"{task} is not queued in {self.name}")
        self._remove((0.0, 0, task))

    def pick(self, slot_id: int) -> Optional[Task]:
        if self._nready == 0:
            return None
        # V = weighted average vruntime over the ready pool
        vmax = self._wvsum / self._wsum + _ELIGIBLE_EPS
        # wake affinity (select_task_rq prev-CPU preference): among the
        # eligible set, prefer tasks that last ran on this slot (or nowhere)
        local_a = self._dl_by_slot.get(slot_id)
        local_b = self._dl_by_slot.get(None)
        e_a = self._min_eligible(local_a, vmax) if local_a else None
        e_b = self._min_eligible(local_b, vmax) if local_b else None
        best = e_a if e_b is None or (e_a is not None and e_a < e_b) else e_b
        if best is None:
            best = self._min_eligible(self._dl_all, vmax)
        if best is None:
            # nothing eligible: fall back to the whole pool, local first
            e_a = self._live_top(local_a) if local_a else None
            e_b = self._live_top(local_b) if local_b else None
            best = e_a if e_b is None or (e_a is not None and e_a < e_b) else e_b
            if best is None:
                best = self._live_top(self._dl_all)
        assert best is not None  # _nready > 0 implies a live entry exists
        return self._remove(best)

    def pick_filtered(self, slot_id: int, allowed_jids) -> Optional[Task]:
        """EEVDF pick restricted to jobs in ``allowed_jids``.

        Scans the global deadline heap in order (heap pops come sorted):
        the first live allowed *eligible* entry wins; the first live
        allowed entry seen is the min-deadline fallback when nothing
        allowed is eligible. Popped live entries are pushed back. The
        wake-affinity preference is skipped on this path — it only runs
        under per-job lease enforcement, where fairness of the restricted
        grant matters more than slot warmth.
        """
        if self._nready == 0:
            return None
        vmax = self._wvsum / self._wsum + _ELIGIBLE_EPS
        heap = self._dl_all
        live = self._live
        vruntime = self._vruntime
        buf: list = []
        chosen = None
        fallback = None
        while heap:
            entry = heappop(heap)
            if live.get(entry[2].tid) != entry[1]:
                continue  # stale: dropped for good
            buf.append(entry)
            if entry[2].job.jid not in allowed_jids:
                continue
            if vruntime[entry[2].tid] <= vmax:
                chosen = entry
                break
            if fallback is None:
                fallback = entry
        if chosen is None:
            chosen = fallback
        for entry in buf:
            if entry is not chosen:
                heappush(heap, entry)
        if chosen is None:
            return None
        return self._remove(chosen)

    def on_run(self, task: Task, slot_id: int, now: float) -> None:
        self._run_started[task.tid] = now

    def on_stop(
        self, task: Task, slot_id: int, now: float, elapsed: float, reason: StopReason
    ) -> None:
        vr = self._vr(task) + elapsed / self._w(task)
        self._vruntime[task.tid] = vr
        if self._nready:
            top = self._live_top(self._vr_heap)
            assert top is not None
            self._min_vruntime = max(self._min_vruntime, top[0])
        else:
            self._min_vruntime = max(self._min_vruntime, vr)

    def should_preempt(self, task: Task, slot_id: int, now: float) -> bool:
        if self._nready == 0:
            return False  # nothing to run instead: keep going
        ran = now - self._run_started.get(task.tid, now)
        return ran >= self.slice_s / self._w(task)

    def slice_for(self, task: Task) -> float:
        # the effective slice should_preempt compares against: weight-
        # scaled, so a nice-0 task's self-expiry matches its eviction time
        return self.slice_s / self._w(task)

    def ready_count(self) -> int:
        return self._nready

    def ready_count_of(self, job) -> int:
        return self._per_job.get(job.jid, 0)
