"""Discrete-event executor for USF.

Runs sim tasks (generators of ops, see simtask.py) on a virtual-time machine
under any Policy, through the *same* Scheduler as the real-thread runtime.
This is how we run the paper's experiments at node and pod scale on a 1-core
container, deterministically.

Fidelity notes:

* Preemptive policies get per-slot ticks; preemption mid-compute splits the
  segment and pays a context switch — this is where LHP/LWP emerge naturally
  (a preempted mutex owner keeps its FIFO wait queue stalled).
* Spin barriers consume slot time in ``spin_slice`` quanta; with
  ``yield_every=None`` and a cooperative policy they livelock when waiters
  exceed slots (paper §4.4) — the engine detects this and raises
  ``SimLivelock`` instead of spinning forever.
* Migration penalties (affinity warm-up) are charged on dispatch based on
  topology distance.

Engine fast path: the hot event kinds (dispatch-resume, compute
completion, spin polls, stalls, preemption ticks, sleep wakeups) are
plain ``(time, seq, kind, a, b, c)`` heap tuples dispatched by an
integer tag in a locals-bound drain loop — no per-event closure is
allocated for them. Generic callables (rare: delayed spawns, external
hooks) still go through ``_post``. Consecutive same-timestamp sleep
wakeups are drained as one batch through ``Scheduler.unblock_batch``
(identical per-task semantics, one lock round-trip). ``seq`` is unique,
so tuple comparison never reaches the payload fields.
"""

from __future__ import annotations

import heapq
from typing import Any, Callable, Optional

from repro.core.adaptive import SliceController
from repro.core.arbiter import SlotArbiter
from repro.core.policies.base import Policy
from repro.core.scheduler import REC_OP, Scheduler
from repro.core.simtask import (
    SimBarrier,
    SimChannel,
    SimCondVar,
    SimCosts,
    SimMutex,
    SimSemaphore,
    SimSpinBarrier,
)
from repro.core.stats import SchedStats
from repro.core.task import Job, Task, TaskState
from repro.core.topology import Topology


def _owned(task: Task) -> set:
    s = task._owned_mutexes
    if s is None:
        s = task._owned_mutexes = set()
    return s


class SimLivelock(RuntimeError):
    pass


class SimTimeout(RuntimeError):
    pass


class SimDeadlock(RuntimeError):
    pass


# heap-event kind tags (values are cosmetic; dispatch is by identity)
_EV_CALL = 0     # a = zero-arg callable (generic / cold path)
_EV_RESUME = 1   # a = task, b = slot_id, c = epoch  (post-dispatch resume)
_EV_COMPUTE = 2  # a = task, b = slot_id, c = epoch  (compute segment done)
_EV_SPIN = 3     # a = task, b = slot_id, c = epoch  (next busy-wait poll)
_EV_STALL = 4    # a = task, b = slot_id, c = epoch  (non-sched-point stall)
_EV_TICK = 5     # a = slot_id                        (preemption tick)
_EV_WAKE = 6     # a = task                           (sleep expiry)
_EV_SUBMIT = 7   # a = task                           (deferred arrival)

#: body ops the recording advance loop captures verbatim (numeric payloads
#: only — sync ops are reconstructed from the BLOCK/WAKE decision records
#: instead, see trace/replayer.py)
_REC_OPKINDS = frozenset(
    ("compute", "stall", "sleep", "sleep_until", "yield", "checkpoint")
)


class SimExecutor:
    def __init__(
        self,
        topology: Topology,
        policy: Policy,
        *,
        costs: Optional[SimCosts] = None,
        max_time: float = 3600.0,
        max_events: int = 50_000_000,
        arbiter: Optional[SlotArbiter] = None,
    ):
        self.topology = topology
        self.costs = costs or SimCosts()
        self._now = 0.0
        #: (time, seq, kind, a, b, c) — see the _EV_* tags above
        self._heap: list[tuple] = []
        self._seq = 0
        self.max_time = max_time
        self.max_events = max_events
        #: events drained so far (benchmarks/sched_ops.py reads this)
        self.events_processed = 0
        self._useful_flops = 0.0
        #: Lock-Holder-Preemption events: a task preempted while owning a
        #: mutex (the §1/§6 pathology SCHED_COOP eliminates by design).
        self.lhp_preemptions = 0
        #: constant part of every dispatch delay, hoisted out of the hot path
        self._base_delay = self.costs.ctx_switch + self.costs.dispatch_latency
        self.sched = Scheduler(
            topology,
            policy,
            clock=lambda: self._now,
            dispatch=self._on_dispatch,
            ctx_switch_cost=self.costs.ctx_switch,
            arbiter=arbiter,
        )
        #: adaptive tick periods — the SAME deterministic controller the
        #: real-thread watchdog uses (repro.core.adaptive), fed from the
        #: same (queue depth, laxity headroom) observations at tick time,
        #: so adaptive-slice policy behaviour is lockstep-testable in
        #: virtual time. Without deadline pressure the controller is
        #: stateless and every tick deadline equals the base period:
        #: non-deadline simulations stay bit-identical.
        self.slices = SliceController()
        #: slot -> deadline of its authoritative pending preemption tick;
        #: an earlier re-arm (e.g. a live swap to a shorter-slice policy)
        #: supersedes a pending later tick, whose token dies at fire time
        #: — mirrors the real-thread watchdog's class-migration semantics
        self._tick_armed: dict[int, float] = {}
        #: urgent grants (negative-laxity deadline preemptions) are
        #: serviced at an immediate tick event — the virtual-time twin of
        #: the real-thread watchdog's condition-variable kick
        self.sched.on_urgent = self._urgent_kick
        #: cache residency: which task's working set last warmed each slot
        self._slot_last: dict[int, int] = {}
        #: intrinsic-op recorder (trace.recorder) — None when disarmed;
        #: arming swaps _advance for its recording twin (see
        #: _set_op_recorder), so plain runs pay nothing, not even a check
        self._oprec = None

    # ------------------------------------------------------------------ #
    # public API
    # ------------------------------------------------------------------ #
    def now(self) -> float:
        return self._now

    def spawn(self, job: Job, genfn: Callable[[], Any], *, name: str = "",
              at: float = 0.0, warmup_scale: float = 1.0,
              deadline: Optional[float] = None) -> Task:
        """Create a task whose body is ``genfn()`` and submit it at time
        ``at``. ``deadline`` (absolute virtual time) rides on the task: a
        deadline-aware arbiter folds it into its grant order the moment
        the task turns READY."""
        task = Task(job, body=genfn, name=name, deadline=deadline)
        task._warmup_scale = warmup_scale  # type: ignore[attr-defined]
        if at <= self._now:
            self._submit(task)
        else:
            self._post_ev(at, _EV_SUBMIT, task)
        return task

    def feed(self, arrivals) -> None:
        """Stream task arrivals into the run: ``arrivals`` yields
        ``(time, task)`` pairs sorted by time. Exactly one arrival event
        is in the heap at any moment — the drain loop pulls the next pair
        when it fires — so replaying a million-task trace does not flood
        the heap (and every pop stays shallow). Tasks must be fresh
        (CREATED) ``Task`` objects; times must be non-decreasing and not
        in the past."""
        it = iter(arrivals)
        for at, task in it:
            self._post_ev(at, _EV_SUBMIT, task, it)
            break

    def attach(self, job: Job, *, policy: Optional[Policy] = None,
               share: Optional[float] = None):
        """nosv_attach: register ``job`` with an optional dedicated
        intra-job policy + slot share; returns its ``SlotLease``. A job
        with queued/running work is re-homed live — promotion out of the
        default group, or a live policy swap when already dedicated (see
        SlotArbiter); tasks already running under a newly preemptive
        policy get their slots' preemption ticks armed here (new
        dispatches arm themselves)."""
        lease = self.sched.attach_job(job, policy=policy, share=share)
        self._arm_running(job)
        return lease

    def demote(self, job: Job, *, share: Optional[float] = None):
        """Reverse nosv_attach edge: live re-home a dedicated ``job`` into
        the shared default group (dedicated lease/policy released, tasks
        keep running); returns the new default-group lease."""
        lease = self.sched.demote_job(job, share=share)
        self._arm_running(job)
        return lease

    def _arm_running(self, job: Job) -> None:
        """Arm preemption ticks for a re-homed job's RUNNING tasks when
        its (new) policy is preemptive — they were dispatched before the
        policy change, so dispatch-time arming never saw them."""
        pol = self.sched.policy_of(job)
        if pol.preemptive and pol.tick_interval is not None:
            for slot_id in self.sched.slots_running(job):
                self._arm_tick(slot_id, self.sched.running_on(slot_id))

    def detach(self, job: Job) -> None:
        """nosv_detach: unregister a quiescent job, releasing its lease."""
        self.sched.detach_job(job)

    def set_slot_target(self, n: Optional[int]) -> int:
        """Elastic slot parking in virtual time: cap the effective width at
        ``n`` slots (``None`` restores the topology). Surplus slots park at
        their tasks' next scheduling point, exactly like the real-thread
        runtime — the deterministic twin for testing node-level revokes."""
        return self.sched.set_slot_target(n)

    def runnable_backlog(self) -> int:
        """Instantaneous READY + RUNNING count (``Scheduler.runnable_backlog``)
        — the live-demand probe a ``BrokerClient`` heartbeat reports."""
        return self.sched.runnable_backlog()

    def run(self, *, until: Optional[float] = None) -> SchedStats:
        """Drain all events (or run until virtual time ``until``)."""
        limit = until if until is not None else self.max_time
        # bind hot attributes to locals: this loop is the whole sim
        heap = self._heap
        heappop = heapq.heappop
        heappush = heapq.heappush
        resume = self._resume
        advance = self._advance
        submit = self._submit
        sched = self.sched
        unblock_batch = sched.unblock_batch
        max_events = self.max_events
        RUNNING = TaskState.RUNNING
        oprec = self._oprec
        n = 0
        uf = 0.0
        try:
            while heap:
                entry = heap[0]
                t = entry[0]
                if t > limit:
                    self._now = limit
                    if until is None:
                        self._raise_stuck()
                    break
                heappop(heap)
                self._now = t
                kind = entry[2]
                if kind == _EV_RESUME:
                    resume(entry[3], entry[4], entry[5])
                elif kind == _EV_COMPUTE:
                    # replay fast path: _valid inlined, pending flops read
                    # from the op tuple itself (no per-event allocation),
                    # and a compute->compute chain handled entirely in this
                    # frame — next(body) feeds the next segment without a
                    # generator-frame round-trip through _advance. Bodies
                    # may be plain iterators (the replayer uses C-level
                    # tuple iterators); anything that is not a bare
                    # compute chain falls back to the generic paths.
                    task = entry[3]
                    slot_id = entry[4]
                    if (task._epoch == entry[5]
                            and task.state is RUNNING
                            and task.slot == slot_id):
                        uf += task._pending[2]
                        if oprec is None and task._send is None:
                            try:
                                op = next(task._gen)
                            except StopIteration:
                                task._pending = None
                                task._epoch = entry[5] + 1
                                sched.finish(task)
                            else:
                                if op[0] == "compute":
                                    task._pending = op
                                    task._pending_started = t
                                    seq = self._seq
                                    self._seq = seq + 1
                                    heappush(heap, (t + op[1], seq,
                                                    _EV_COMPUTE, task,
                                                    slot_id, entry[5]))
                                else:
                                    task._pending = None
                                    if self._handle(task, slot_id, op):
                                        advance(task, slot_id)
                        else:
                            task._pending = None
                            advance(task, slot_id)
                elif kind == _EV_WAKE:
                    # batch same-timestamp sleep expiries: one lock
                    # round-trip, identical per-task make-ready/fill order.
                    # Counting is structural — the extras drained here plus
                    # the shared increment below make events_processed equal
                    # exactly the number of heap pops, so recorder event
                    # counts and the events/s gate agree with the decision
                    # stream even when wakeups coalesce.
                    task = entry[3]
                    if heap and heap[0][0] == t and heap[0][2] == _EV_WAKE:
                        batch = [task]
                        while heap and heap[0][0] == t and heap[0][2] == _EV_WAKE:
                            batch.append(heappop(heap)[3])
                        n += len(batch) - 1
                        unblock_batch(batch)
                    else:
                        sched.unblock(task)
                elif kind == _EV_SUBMIT:
                    # b (entry[4]) may carry an arrival stream: an iterator
                    # of (time, task) pairs, pre-sorted by time. The drain
                    # loop pulls one arrival per submit event, so a
                    # million-task replay keeps the heap shallow (no
                    # pre-posted arrival flood) with no feeder closures.
                    submit(entry[3])
                    stream = entry[4]
                    if stream is not None:
                        for at, nxt in stream:
                            seq = self._seq
                            self._seq = seq + 1
                            heappush(heap, (at, seq, _EV_SUBMIT, nxt,
                                            stream, None))
                            break
                elif kind == _EV_SPIN:
                    task = entry[3]
                    slot_id = entry[4]
                    if self._valid(task, slot_id, entry[5]):
                        pend = task._pending
                        self._spin_check(task, slot_id, pend[1], pend[2],
                                         pend[3])
                elif kind == _EV_STALL:
                    task = entry[3]
                    if self._valid(task, entry[4], entry[5]):
                        advance(task, entry[4])
                elif kind == _EV_TICK:
                    self._tick(entry[3])
                else:  # _EV_CALL
                    entry[3]()
                n += 1
                if n > max_events:
                    raise SimTimeout(
                        f"event cap exceeded: {self.sched.snapshot()}"
                    )
        finally:
            self.events_processed += n
            self._useful_flops += uf
        if until is None and not self._heap:
            undone = [t for t in self.sched.all_tasks if not t.done]
            if undone:
                raise SimDeadlock(
                    f"no pending events but {len(undone)} tasks unfinished "
                    f"(cooperative deadlock): {self.sched.snapshot()}"
                )
        return self.sched.stats()

    @property
    def useful_flops(self) -> float:
        return self._useful_flops

    # ------------------------------------------------------------------ #
    # engine internals
    # ------------------------------------------------------------------ #
    def _post(self, t: float, fn: Callable[[], None]) -> None:
        """Generic (cold-path) event: a zero-arg callable."""
        seq = self._seq
        self._seq = seq + 1
        heapq.heappush(self._heap, (t, seq, _EV_CALL, fn, None, None))

    def _post_ev(self, t: float, kind: int, a=None, b=None, c=None) -> None:
        """Closure-free hot-path event."""
        seq = self._seq
        self._seq = seq + 1
        heapq.heappush(self._heap, (t, seq, kind, a, b, c))

    def _submit(self, task: Task) -> None:
        task._gen = task.body()  # type: ignore[attr-defined]
        task._send = None  # type: ignore[attr-defined]
        task._epoch = 0  # type: ignore[attr-defined]
        task._pending = None  # type: ignore[attr-defined]  # resumable op state
        self.sched.submit(task)

    def _on_dispatch(self, task: Task, slot_id: int) -> None:
        """Scheduler picked ``task`` for ``slot_id``: resume after swap costs."""
        epoch = task._epoch
        scale = task._warmup_scale
        delay = self._base_delay
        if task.last_slot is not None and task.last_slot != slot_id:
            dist = self.topology.distance(task.last_slot, slot_id)
            delay += self.costs.migration_penalty(dist) * scale
        elif (task.last_slot == slot_id
              and self._slot_last.get(slot_id) not in (None, task.tid)):
            # back on its own slot, but another task polluted the cache in
            # between (preemption/interleaving noise — paper §1, §5.3)
            delay += self.costs.cache_refill * scale
        self._slot_last[slot_id] = task.tid
        self._post_ev(self._now + delay, _EV_RESUME, task, slot_id, epoch)
        self._arm_tick(slot_id, task)

    def _valid(self, task: Task, slot_id: int, epoch: int) -> bool:
        return (
            task._epoch == epoch  # type: ignore[attr-defined]
            and task.state is TaskState.RUNNING
            and task.slot == slot_id
        )

    def _bump(self, task: Task) -> None:
        task._epoch += 1  # type: ignore[attr-defined]

    def _resume(self, task: Task, slot_id: int, epoch: int) -> None:
        if not self._valid(task, slot_id, epoch):
            return
        pending = task._pending  # type: ignore[attr-defined]
        if pending is None:
            self._advance(task, slot_id)
        elif pending[0] == "compute":
            _, remaining, flops = pending
            self._start_compute(task, slot_id, remaining, flops)
        elif pending[0] == "spin":
            _, bar, gen, iters = pending
            self._spin_check(task, slot_id, bar, gen, iters)
        else:  # pragma: no cover - defensive
            raise AssertionError(pending)

    # -- generator advancement ------------------------------------------ #
    def _advance(self, task: Task, slot_id: int) -> None:
        """Pull ops from the task generator until it blocks/computes/ends."""
        gen = task._gen  # type: ignore[attr-defined]
        heappush = heapq.heappush
        heap = self._heap
        while True:
            try:
                send = task._send  # type: ignore[attr-defined]
                if send is None:
                    op = next(gen)  # any iterator works (replay bodies
                    # are C-level tuple iterators — no generator frame)
                else:
                    task._send = None  # type: ignore[attr-defined]
                    op = gen.send(send)
            except StopIteration:
                self._bump(task)
                self.sched.finish(task)
                return
            if op[0] == "compute":
                # hottest op, inlined (it is also first in _handle — this
                # just skips the extra call): keep the body's own
                # ("compute", dt, flops) tuple as the pending state, no
                # per-segment allocation.
                task._pending = op
                now = self._now
                task._pending_started = now
                seq = self._seq
                self._seq = seq + 1
                heappush(heap, (now + op[1], seq, _EV_COMPUTE, task,
                                slot_id, task._epoch))
                return
            if not self._handle(task, slot_id, op):
                return  # task no longer advancing synchronously

    def _set_op_recorder(self, rec) -> None:
        """Arm (or, with ``None``, disarm) intrinsic-op recording. Arming
        shadows ``_advance`` with its recording twin via an instance
        attribute — the disarmed engine keeps the original method and pays
        zero per-op cost. Must be called before ``run`` (the drain loop
        binds ``_advance`` to a local at entry)."""
        self._oprec = rec
        if rec is None:
            self.__dict__.pop("_advance", None)
        else:
            self._advance = self._advance_recording

    def _advance_recording(self, task: Task, slot_id: int) -> None:
        """Recording twin of ``_advance``: emits a REC_OP record for every
        intrinsic (numeric-payload) op the body yields. Sync ops carry live
        object references and are deliberately not recorded — the replayer
        reconstructs each blocking occurrence from the BLOCK/WAKE decision
        records as an absolute-time ``sleep_until``."""
        gen = task._gen  # type: ignore[attr-defined]
        rec = self._oprec
        while True:
            try:
                send = task._send  # type: ignore[attr-defined]
                if send is None:
                    op = next(gen)
                else:
                    task._send = None  # type: ignore[attr-defined]
                    op = gen.send(send)
            except StopIteration:
                self._bump(task)
                self.sched.finish(task)
                return
            if op[0] in _REC_OPKINDS:
                rec((self._now, REC_OP, task.tid, op))
            if not self._handle(task, slot_id, op):
                return

    def _handle(self, task: Task, slot_id: int, op: tuple) -> bool:
        """Returns True if the generator should keep advancing right now."""
        kind = op[0]

        if kind == "compute":
            # hottest op: keep the body's own ("compute", dt, flops) tuple
            # as the pending state (no per-segment allocation) and push the
            # completion event inline. _start_compute remains for the
            # post-preempt resume path, which must rebuild remaining time.
            task._pending = op
            now = self._now
            task._pending_started = now
            seq = self._seq
            self._seq = seq + 1
            heapq.heappush(self._heap, (now + op[1], seq, _EV_COMPUTE, task,
                                        slot_id, task._epoch))
            return False

        if kind == "yield":  # hot under §5.2-adapted workloads: check early
            self._bump(task)
            self.sched.yield_(task)
            return False

        if kind == "checkpoint":
            # explicit preemption point (the sim analogue of
            # usf.checkpoint): a pending request_preempt flag — e.g. from
            # an external preemption request against this slot — is
            # consumed here; unflagged it is a no-op and the generator
            # keeps advancing. The sim is single-threaded, so the flag
            # cannot vanish between the peek and the consume. Bodies need
            # not yield this op by hand: ``autockpt.preemptible_body``
            # injects it every N ops, mirroring the thread executor's
            # checkpoint-at-dispatch wrappers boundary for boundary.
            if self.sched.preempt_requested(task):
                self._bump(task)
                self.sched.consume_preempt(task)
                return False
            return True

        if kind == "stall":
            # holds the slot, not useful, not a scheduling point (§5.6)
            dt = op[1]
            task.stats.spin_time += dt
            self._post_ev(self._now + dt, _EV_STALL, task, slot_id, task._epoch)
            return False

        if kind == "lock":
            m: SimMutex = op[1]
            if m.owner is None:
                m.owner = task
                _owned(task).add(m)
                return True
            m.queue.append(task)  # FIFO wait queue (Listing 1)
            self._block(task)
            # on resume, ownership will have been transferred to us
            _owned(task).add(m)
            return False

        if kind == "unlock":
            m = op[1]
            if m.owner is not task:
                raise RuntimeError(f"{task} unlocks mutex it does not own")
            _owned(task).discard(m)
            if m.queue:
                nxt = m.queue.popleft()
                m.owner = nxt          # ownership transfer (Listing 1)
                self.sched.unblock(nxt)
            else:
                m.owner = None
            return True

        if kind == "barrier":
            b: SimBarrier = op[1]
            b.count += 1
            if b.count == b.parties:
                b.count = 0
                b.generation += 1
                waiters, b.waiting = list(b.waiting), type(b.waiting)()
                for w in waiters:
                    self.sched.unblock(w)
                return True  # last arrival proceeds without blocking
            b.waiting.append(task)
            self._block(task)
            return False

        if kind == "spin_barrier":
            b2: SimSpinBarrier = op[1]
            gen_at_arrival = b2.generation
            b2.count += 1
            if b2.count == b2.parties:
                b2.count = 0
                b2.generation += 1  # releases all spinners at their next check
                return True
            task._pending = ("spin", b2, gen_at_arrival, 0)  # type: ignore[attr-defined]
            self._spin_check(task, slot_id, b2, gen_at_arrival, 0)
            return False

        if kind == "sem_acquire":
            s: SimSemaphore = op[1]
            if s.value > 0:
                s.value -= 1
                return True
            s.queue.append(task)
            self._block(task)
            return False

        if kind == "sem_release":
            s = op[1]
            if s.queue:
                self.sched.unblock(s.queue.popleft())
            else:
                s.value += 1
            return True

        if kind == "cv_wait":
            cv: SimCondVar = op[1]
            m = op[2]
            if m.owner is not task:
                raise RuntimeError("cv_wait without holding the mutex")
            cv.waiting.append((task, m))
            # release the mutex (with FIFO handoff) then block
            if m.queue:
                nxt = m.queue.popleft()
                m.owner = nxt
                self.sched.unblock(nxt)
            else:
                m.owner = None
            self._block(task)
            return False

        if kind == "cv_notify":
            cv = op[1]
            n = op[2]
            for _ in range(min(n, len(cv.waiting))):
                w, wm = cv.waiting.popleft()
                # re-acquire the mutex on the waiter's behalf before resume
                if wm.owner is None:
                    wm.owner = w
                    self.sched.unblock(w)
                else:
                    wm.queue.append(w)  # stays BLOCKED until unlock hands off
            return True

        if kind == "sleep":
            dt = op[1]
            self._block(task)
            self._post_ev(self._now + dt, _EV_WAKE, task)
            return False

        if kind == "sleep_until":
            # absolute-time sleep: the replay encoding of a recorded sync
            # block (trace/replayer.py pairs each BLOCK with its WAKE time).
            # A replayed wake never precedes its block under the recorded
            # policy; the clamp only guards hand-written traces.
            t = op[1]
            now = self._now
            self._block(task)
            self._post_ev(t if t > now else now, _EV_WAKE, task)
            return False

        if kind == "spawn":
            child: Task = op[1]
            if getattr(child, "_gen", None) is None:
                self._submit(child)
            else:
                self.sched.submit(child)
            return True

        if kind == "join":
            child = op[1]
            if child.done:
                return True
            self._block(task)
            child.on_done.append(lambda _t: self.sched.unblock(task))
            return False

        if kind == "channel_put":
            ch: SimChannel = op[1]
            if ch.getters:
                getter = ch.getters.popleft()
                getter._send = op[2]  # type: ignore[attr-defined]
                self.sched.unblock(getter)
            else:
                ch.items.append(op[2])
            return True

        if kind == "channel_get":
            ch = op[1]
            if ch.items:
                task._send = ch.items.popleft()  # type: ignore[attr-defined]
                return True
            ch.getters.append(task)
            self._block(task)
            return False

        raise RuntimeError(f"unknown op {op!r}")

    # -- compute & spin -------------------------------------------------- #
    def _start_compute(self, task: Task, slot_id: int, dt: float, flops: float) -> None:
        task._pending = ("compute", dt, flops)
        task._pending_started = self._now
        self._post_ev(self._now + dt, _EV_COMPUTE, task, slot_id, task._epoch)

    def _spin_check(
        self,
        task: Task,
        slot_id: int,
        bar: SimSpinBarrier,
        my_gen: int,
        iters: int,
    ) -> None:
        """One busy-wait poll iteration (consumes slot time). Only called
        while the task validly runs; ``task._pending`` always holds current
        spin state so preemption/resume can continue the spin."""
        if bar.generation != my_gen:
            task._pending = None  # type: ignore[attr-defined]
            self._advance(task, slot_id)  # released
            return
        task.stats.spin_time += bar.spin_slice
        nxt = iters + 1
        task._pending = ("spin", bar, my_gen, nxt)  # type: ignore[attr-defined]
        ye = bar.yield_every
        if ye is not None and (ye == 0 or nxt % ye == 0):
            # the §5.2 adaptation: occasionally sched_yield inside the spin
            self._bump(task)
            self.sched.yield_(task)
            return
        # next poll; if preempted meanwhile the epoch check kills the event
        # and _pending (always current) lets the resume continue the spin
        self._post_ev(self._now + bar.spin_slice, _EV_SPIN, task, slot_id,
                      task._epoch)

    # -- blocking helper -------------------------------------------------- #
    def _block(self, task: Task) -> None:
        self._bump(task)
        self.sched.block(task)

    # -- preemption ticks -------------------------------------------------- #
    def _arm_tick(self, slot_id: int, task: Optional[Task] = None) -> None:
        """Arm a preemption tick for the task (about to be) running on the
        slot, unless an equal-or-earlier one is pending. Per-job policies
        make this per-task: a SCHED_COOP job's tasks never arm ticks even
        when a co-located job is preemptive. An earlier request (a swap
        to a shorter-slice policy) supersedes a pending later tick."""
        if task is None:
            task = self.sched.running_on(slot_id)
            if task is None:
                return  # armed again on next dispatch
        pol = self.sched.policy_of(task.job)
        if not pol.preemptive or pol.tick_interval is None:
            return
        deadline = self._now + self.slices.effective(pol.tick_interval)
        cur = self._tick_armed.get(slot_id)
        if cur is not None and cur <= deadline:
            return
        self._tick_armed[slot_id] = deadline
        self._post_ev(deadline, _EV_TICK, slot_id)

    def _urgent_kick(self, slot_id: int) -> None:
        """Service an urgent preemption request (``Scheduler.urgent_preempt``)
        at an immediate tick instead of the slot's next periodic deadline:
        the pending later tick becomes a dead token exactly as in a
        shorter-slice re-arm."""
        self._tick_armed[slot_id] = self._now
        self._post_ev(self._now, _EV_TICK, slot_id)

    def _tick(self, slot_id: int) -> None:
        if self._tick_armed.get(slot_id) != self._now:
            return  # superseded by an earlier re-arm: dead token
        del self._tick_armed[slot_id]
        running = self.sched.running_on(slot_id)
        if running is None:
            return  # re-armed on next dispatch
        pol = self.sched.policy_of(running.job)
        if not pol.preemptive:
            # stale tick: armed for a previous preemptive occupant, but the
            # slot now runs a cooperative-policy task (I2: never preempted
            # here even with need_resched set — the flag stays for the task
            # to consume at its next scheduling point / checkpoint)
            return
        if pol.tick_interval is not None:
            # mirror the watchdog's adaptation observation (same controller,
            # same signals) before the re-arm below reads the new period
            arb = self.sched.arbiter
            self.slices.observe(pol.tick_interval,
                                depth=arb.ready_count(),
                                laxity=arb.laxity_headroom(self._now))
        if self.sched.tick(slot_id):
            task = running
            if _owned(task):
                self.lhp_preemptions += 1  # preempted a lock holder (LHP)
            pend = task._pending  # type: ignore[attr-defined]
            if pend is not None and pend[0] == "compute":
                ran = self._now - task._pending_started  # type: ignore[attr-defined]
                left = max(pend[1] - ran, 0.0)
                task._pending = ("compute", left, pend[2])  # type: ignore[attr-defined]
            self._bump(task)
            self.sched.preempt(task)
        self._arm_tick(slot_id)

    # -- failure diagnosis -------------------------------------------------- #
    def _raise_stuck(self) -> None:
        snap = self.sched.snapshot()
        undone = [t for t in self.sched.all_tasks if not t.done]
        if undone:
            spinning = snap["slots_busy"] > 0
            msg = f"simulation exceeded max_time={self.max_time}s: {snap}"
            if spinning:
                raise SimLivelock(
                    msg + " — busy-wait livelock (paper §4.4: adapt the "
                    "barrier with yield_every)"
                )
            raise SimTimeout(msg)
