"""Auto-checkpoint instrumentation: preemption points without application
changes.

The runtime can only deschedule a task at a *scheduling point* — a
blocking call or an explicit ``usf.checkpoint()``. A CPU-bound task that
does neither (the unmodified-library case the paper's §4.4 worries about)
holds its slot until it finishes, so a broker revoke or an elastic
``set_slot_target`` shrink lands with unbounded latency. This module
closes that gap for the dominant shape of such tasks in this repo —
Python loops driving jitted JAX compute — by interposing the checkpoint
fast path (two lock-free reads, see ``UsfRuntime.checkpoint``) at every
**dispatch boundary**: each call into a jitted step function is one
device-kernel launch, so checkpointing there bounds revoke-to-park
latency at roughly one dispatch interval without touching the
application's code. LibPreemptible (PAPERS.md) makes the same argument
for compiler-inserted preemption points; here the "compiler" is a
wrapper, because the dispatch boundary is already a function call.

Three tiers (see docs/PREEMPTION.md for the full delivery-latency
ladder):

* ``preemptible(fn, runtime=rt)`` / ``wrap_jit`` — wrap a (jitted)
  callable so every invocation passes through ``runtime.checkpoint()``
  first. Idempotent: wrapping a wrapped function returns it unchanged.
* ``maybe_checkpoint(rt, every=N)`` — a generation-counter tick for
  non-JAX hot loops: returns a ``tick()`` closure that counts calls and
  runs the checkpoint on every Nth, so loops too hot for a per-iteration
  checkpoint still reach one at a bounded period.
* ``preemptible_body(genfn, every=N)`` — the ``SimExecutor`` twin: wraps
  a generator task body so the sim's ``("checkpoint",)`` op is injected
  after every Nth yielded op. Instrumented thread bodies and their sim
  twins therefore hit checkpoints at the same logical boundaries, which
  keeps auto-checkpointed programs lockstep-testable on virtual time.

Every tier is safe to sprinkle unconditionally: ``UsfRuntime.checkpoint``
is a no-op from a plain (non-USF) thread and from free-running
(``gating=False``) tasks, and the sim's checkpoint op is a no-op unless a
preemption is pending — so library code instruments once and the same
code path serves gated runs, free-running baselines and unit tests.

Scoping note — the signal-based fallback we deliberately do NOT ship:
the classic alternative to cooperative points is asynchronous delivery
via ``pthread_kill`` + a ``SIGURG``-style handler (LibPreemptible's
kernel-bypass mode, and what an OS-level implementation would use). That
design is not implementable for this runtime's worker threads in
CPython: the interpreter delivers Python-level signal handlers **only on
the main thread** (``signal`` module contract — handlers raised in a
C-level handler on any thread are queued and executed by the main
interpreter loop), so a signal aimed at a worker mid-kernel would
deschedule *the main thread*, not the target. A C-extension handler
could run on the target thread but could not safely re-enter the
scheduler (no GIL guarantees inside a signal context, and XLA's runtime
is not async-signal-safe). The watchdog thread therefore remains the
backstop tier for code no wrapper can reach, and the auto-checkpoint
tier covers the dispatch-driven compute that dominates in practice.
"""

from __future__ import annotations

from typing import Any, Callable, Generator, Optional

from repro.core import simtask as _st

__all__ = [
    "preemptible",
    "wrap_jit",
    "maybe_checkpoint",
    "preemptible_body",
]

#: marker attribute set on wrappers so re-wrapping is the identity
_MARK = "__usf_autockpt__"

#: jit-object attributes forwarded onto the wrapper so ``wrap_jit`` output
#: keeps the inspection surface callers use (AOT lowering, cache control)
_JIT_ATTRS = ("lower", "trace", "eval_shape", "clear_cache")


def _adopt_identity(wrapper: Callable, fn: Callable) -> None:
    """``functools.wraps`` minus the attributes jit function objects may
    not expose (PjitFunction has no ``__dict__`` to merge)."""
    for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
        try:
            setattr(wrapper, attr, getattr(fn, attr))
        except AttributeError:
            pass
    wrapper.__wrapped__ = fn  # type: ignore[attr-defined]


def preemptible(fn: Callable, *, runtime: Any,
                every: int = 1) -> Callable:
    """Wrap ``fn`` so each call runs ``runtime.checkpoint()`` at entry —
    the dispatch boundary becomes a preemption point.

    ``every=N`` checkpoints on every Nth call instead (for dispatch loops
    whose per-call cost is so small the wrapper itself would show up; the
    counter is a plain int cell — a lost increment under thread races
    only defers one checkpoint, it never corrupts anything). Wrapping an
    already-wrapped callable returns it unchanged, so layered helpers can
    instrument defensively without stacking checkpoints.

    The wrapped function is identical to ``fn`` from a plain thread or a
    free-running task: ``checkpoint()`` no-ops there, so baselines run
    the same instrumented code as coordinated runs.
    """
    if getattr(fn, _MARK, False):
        return fn
    every = max(1, int(every))
    ckpt = runtime.checkpoint
    if every == 1:
        def wrapped(*args, **kwargs):
            ckpt()
            return fn(*args, **kwargs)
    else:
        gen = [0]

        def wrapped(*args, **kwargs):
            gen[0] += 1
            if gen[0] >= every:
                gen[0] = 0
                ckpt()
            return fn(*args, **kwargs)

    _adopt_identity(wrapped, fn)
    setattr(wrapped, _MARK, True)
    return wrapped


def wrap_jit(jitted: Callable, *, runtime: Any, every: int = 1) -> Callable:
    """``preemptible`` for ``jax.jit`` outputs: same checkpoint-at-entry
    wrapper, plus the jit object's AOT/cache surface (``lower``,
    ``trace``, ``eval_shape``, ``clear_cache``) forwarded onto the
    wrapper so call sites that lower or clear the underlying executable
    keep working."""
    wrapped = preemptible(jitted, runtime=runtime, every=every)
    if wrapped is jitted:  # already instrumented
        return jitted
    for attr in _JIT_ATTRS:
        target = getattr(jitted, attr, None)
        if target is not None:
            setattr(wrapped, attr, target)
    return wrapped


def maybe_checkpoint(runtime: Any, *, every: int = 64) -> Callable[[], None]:
    """Generation-counter checkpoint tier for non-JAX hot loops.

    Returns a ``tick()`` closure: each call bumps a counter and every
    ``every``-th runs ``runtime.checkpoint()``. This replaces the
    hand-rolled ``if n % K == 0: rt.checkpoint()`` idiom with one object
    a library can create unconditionally — like the wrapper tiers it is
    a no-op outside a gated USF task."""
    every = max(1, int(every))
    ckpt = runtime.checkpoint
    gen = [0]

    def tick() -> None:
        gen[0] += 1
        if gen[0] >= every:
            gen[0] = 0
            ckpt()

    return tick


def preemptible_body(genfn: Callable[..., Generator], *,
                     every: int = 1) -> Callable[..., Generator]:
    """SimExecutor twin of ``preemptible``: wrap a generator task body so
    the sim's ``("checkpoint",)`` op is injected after every ``every``-th
    op the body yields.

    The injected op is the virtual-time analogue of the thread wrapper's
    checkpoint-at-dispatch: ``SimExecutor`` consumes a pending preemption
    there (or continues synchronously — a no-op costs no virtual time),
    so an instrumented body parks at the same logical boundaries in both
    executors. Send-values (``channel_get`` results) pass through to the
    inner generator untouched; checkpoint resumes never carry a value.
    Idempotent like the thread-side wrappers."""
    if getattr(genfn, _MARK, False):
        return genfn
    every = max(1, int(every))

    def wrapped(*args, **kwargs) -> Generator:
        inner = genfn(*args, **kwargs)
        n = 0
        sent: Optional[Any] = None
        while True:
            try:
                op = inner.send(sent)
            except StopIteration:
                return
            sent = yield op
            n += 1
            if n % every == 0:
                yield _st.checkpoint()  # injected dispatch boundary

    _adopt_identity(wrapped, genfn)
    setattr(wrapped, _MARK, True)
    return wrapped
