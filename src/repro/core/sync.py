"""Cooperative synchronization primitives — the extended glibc APIs (§4.3.4).

Each primitive follows the paper's Listing 1 pattern: contended tasks are
placed in a spinlock-protected per-object FIFO wait queue, then paused via
the runtime (nosv_pause); the release path dequeues one waiter and submits
it to the scheduler (nosv_submit), transferring ownership where applicable.

Every primitive supports MIXED use: gated USF tasks park via the scheduler
(releasing their slot), while plain threads (the main thread, non-USF
helpers, or everything in the free-running Linux-baseline mode) wait on an
embedded Event — both against the SAME state, so a release from either
side wakes either kind of waiter. This mirrors glibcv, where USF and
non-USF threads share the same pthread objects.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, Optional, Union

from repro.core.task import Task
from repro.core.threads import UsfRuntime


class _Waiter:
    """Either a gated task (paused via USF) or a plain-thread event."""

    __slots__ = ("task", "event")

    def __init__(self, task: Optional[Task]):
        self.task = task
        self.event = None if task is not None else threading.Event()

    def wake(self, rt: UsfRuntime) -> None:
        if self.task is not None:
            rt.ready(self.task)
        else:
            self.event.set()

    def wait(self, rt: UsfRuntime) -> None:
        if self.task is not None:
            rt.pause()
        else:
            self.event.wait()


def _gated_task(rt: UsfRuntime) -> Optional[Task]:
    return rt.current_task() if rt.gating else None


_HANDOFF = object()  # ownership in flight between unlock() and the waiter


class CoopMutex:
    """pthread_mutex with FIFO handoff (paper Listing 1)."""

    def __init__(self, rt: UsfRuntime):
        self._rt = rt
        self._spin = threading.Lock()
        self._owner: Optional[object] = None  # Task | thread ident | _HANDOFF
        self._queue: Deque[_Waiter] = deque()

    def _me(self):
        task = _gated_task(self._rt)
        return task if task is not None else threading.get_ident()

    def lock(self, timeout: Optional[float] = None) -> bool:
        """Acquire; returns True. With ``timeout`` (seconds) returns False
        if ownership was not handed over in time — consistent with
        ``CoopEvent.wait(timeout)``, for gated tasks (a timer on the
        runtime's watchdog heap withdraws the waiter and resubmits the
        task) and plain threads (timed wait on the embedded Event) alike.
        An unlock racing the expiry is benign: whichever side dequeues the
        waiter first decides, and a handoff that already reserved us wins
        (the lock is held — slightly late beats released-to-nobody)."""
        task = _gated_task(self._rt)
        me = task if task is not None else threading.get_ident()
        with self._spin:
            if self._owner is None:
                self._owner = me
                return True
            if timeout is not None and timeout <= 0:
                return False
            w = _Waiter(task)
            self._queue.append(w)
        if timeout is None:
            w.wait(self._rt)
            with self._spin:  # handoff completed: claim ownership
                assert self._owner is _HANDOFF
                self._owner = me
            return True
        if task is None:  # plain thread: timed wait on the embedded Event
            if not w.event.wait(timeout):
                with self._spin:
                    try:
                        self._queue.remove(w)
                        return False
                    except ValueError:
                        pass  # unlock already reserved us: claim below
            with self._spin:
                assert self._owner is _HANDOFF
                self._owner = me
            return True
        # gated task: timed nosv_pause via the watchdog heap
        timed_out = [False]

        def expire() -> None:
            with self._spin:
                try:
                    self._queue.remove(w)
                except ValueError:
                    return  # unlock already reserved us (handoff in flight)
                timed_out[0] = True
            self._rt.ready(task)

        timer = self._rt.call_later(timeout, expire)
        w.wait(self._rt)
        timer.cancel()
        if timed_out[0]:
            return False
        with self._spin:
            assert self._owner is _HANDOFF
            self._owner = me
        return True

    def unlock(self) -> None:
        nxt: Optional[_Waiter] = None
        with self._spin:
            # equality, not identity: a plain-thread owner is a fresh int
            # from get_ident() per call (equal value, not the same object)
            if self._owner is _HANDOFF or self._owner != self._me():
                raise RuntimeError("unlock by non-owner")
            if self._queue:
                nxt = self._queue.popleft()
                self._owner = _HANDOFF  # reserved for the woken waiter
            else:
                self._owner = None
        if nxt is not None:
            nxt.wake(self._rt)

    def __enter__(self) -> "CoopMutex":
        self.lock()
        return self

    def __exit__(self, *exc) -> None:
        self.unlock()


class CoopCondVar:
    """pthread_cond: wait releases the mutex, re-acquires after notify."""

    def __init__(self, rt: UsfRuntime, mutex: CoopMutex):
        self._rt = rt
        self._mutex = mutex
        self._spin = threading.Lock()
        self._waiting: Deque[_Waiter] = deque()

    def wait(self) -> None:
        w = _Waiter(_gated_task(self._rt))
        with self._spin:
            self._waiting.append(w)
        self._mutex.unlock()
        w.wait(self._rt)
        self._mutex.lock()

    def notify(self, n: int = 1) -> None:
        woken: list[_Waiter] = []
        with self._spin:
            for _ in range(min(n, len(self._waiting))):
                woken.append(self._waiting.popleft())
        for w in woken:
            w.wake(self._rt)

    def notify_all(self) -> None:
        self.notify(1 << 30)


class CoopBarrier:
    """pthread_barrier: cooperative (blocking) flavour."""

    def __init__(self, rt: UsfRuntime, parties: int):
        assert parties >= 1
        self._rt = rt
        self._parties = parties
        self._spin = threading.Lock()
        self._count = 0
        self._waiting: Deque[_Waiter] = deque()

    def wait(self) -> None:
        w = _Waiter(_gated_task(self._rt))
        release: Optional[list[_Waiter]] = None
        with self._spin:
            self._count += 1
            if self._count == self._parties:
                self._count = 0
                release = list(self._waiting)
                self._waiting.clear()
            else:
                self._waiting.append(w)
        if release is not None:
            for other in release:
                other.wake(self._rt)
            return  # last arrival proceeds without blocking
        w.wait(self._rt)


class CoopSemaphore:
    def __init__(self, rt: UsfRuntime, value: int = 0):
        self._rt = rt
        self._spin = threading.Lock()
        self._value = value
        self._queue: Deque[_Waiter] = deque()

    def acquire(self) -> None:
        w = None
        with self._spin:
            if self._value > 0:
                self._value -= 1
                return
            w = _Waiter(_gated_task(self._rt))
            self._queue.append(w)
        w.wait(self._rt)

    def try_acquire(self) -> bool:
        with self._spin:
            if self._value > 0:
                self._value -= 1
                return True
            return False

    def release(self) -> None:
        nxt: Optional[_Waiter] = None
        with self._spin:
            if self._queue:
                nxt = self._queue.popleft()
            else:
                self._value += 1
        if nxt is not None:
            nxt.wake(self._rt)


class CoopEvent:
    """One-shot event (the serving engine's request-completion wait)."""

    def __init__(self, rt: UsfRuntime):
        self._rt = rt
        self._spin = threading.Lock()
        self._set = False
        self._waiting: Deque[_Waiter] = deque()

    def is_set(self) -> bool:
        return self._set

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Wait for the event; returns False on timeout (True otherwise).

        Works for both waiter kinds: plain threads time out on the embedded
        Event; gated tasks arm a timer on the runtime's watchdog heap that
        withdraws the waiter from the queue and resubmits the task (a timed
        nosv_pause — no per-call ``threading.Timer`` thread). A timer
        firing concurrently with ``set()`` is benign: whichever side
        dequeues the waiter first wakes it, the other finds it gone."""
        with self._spin:
            if self._set:
                return True
            task = _gated_task(self._rt)
            w = _Waiter(task)
            self._waiting.append(w)
        if task is None:
            if w.event.wait(timeout):
                return True
            with self._spin:  # withdraw so a later set() skips us
                try:
                    self._waiting.remove(w)
                except ValueError:
                    pass
            return self._set
        if timeout is None:
            w.wait(self._rt)
            return True
        timed_out = [False]

        def expire() -> None:
            with self._spin:
                try:
                    self._waiting.remove(w)
                except ValueError:
                    return  # set() already claimed this waiter
                timed_out[0] = True
            self._rt.ready(task)

        timer = self._rt.call_later(timeout, expire)
        self._rt.pause()
        timer.cancel()
        return self._set or not timed_out[0]

    def set(self) -> None:
        with self._spin:
            self._set = True
            woken = list(self._waiting)
            self._waiting.clear()
        for w in woken:
            w.wake(self._rt)


class CoopChannel:
    """FIFO message queue; ``get`` blocks cooperatively when empty (the
    poll/epoll analogue of §4.3.4 — the serving engine's request queue)."""

    def __init__(self, rt: UsfRuntime):
        self._rt = rt
        self._items: Deque = deque()
        self._sem = CoopSemaphore(rt, 0)
        self._spin = threading.Lock()

    def put(self, item) -> None:
        with self._spin:
            self._items.append(item)
        self._sem.release()

    def get(self):
        self._sem.acquire()
        with self._spin:
            return self._items.popleft()

    def try_get(self):
        """Non-blocking get (single-consumer safe)."""
        if self._sem.try_acquire():
            with self._spin:
                return self._items.popleft()
        return None

    def __len__(self) -> int:
        with self._spin:
            return len(self._items)


class BusyWaitBarrier:
    """A *busy-wait* barrier à la OpenBLAS/BLIS (§5.2) for the real-thread
    mode. ``yield_every=None`` reproduces the unmodified library (spins,
    burning its slot — can livelock a cooperative policy, §4.4);
    ``yield_every=k`` is the paper's one-line sched_yield adaptation.
    """

    def __init__(self, rt: UsfRuntime, parties: int, *,
                 yield_every: Optional[int] = 1, spin_ns: int = 1000):
        self._rt = rt
        self._parties = parties
        self._yield_every = yield_every
        self._spin_ns = spin_ns
        self._count = 0
        self._generation = 0
        self._spin = threading.Lock()

    def wait(self, *, max_spins: Optional[int] = None) -> None:
        with self._spin:
            my_gen = self._generation
            self._count += 1
            if self._count == self._parties:
                self._count = 0
                self._generation += 1
                return
        spins = 0
        gated = self._rt.gating and self._rt.current_task() is not None
        while True:
            with self._spin:
                if self._generation != my_gen:
                    return
            spins += 1
            if max_spins is not None and spins > max_spins:
                raise TimeoutError("busy-wait barrier exceeded max_spins")
            ye = self._yield_every
            if ye is not None and spins % max(ye, 1) == 0:
                if gated:
                    self._rt.yield_now()  # the §5.2 adaptation
                else:
                    time.sleep(0)  # sched_yield
            else:
                t_end = time.monotonic_ns() + self._spin_ns
                while time.monotonic_ns() < t_end:
                    pass
