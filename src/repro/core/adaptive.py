"""Adaptive tick-interval classes — shared by both executors.

The watchdog coalesces preemption ticks by *interval class* (one periodic
heap entry per distinct policy period, O(interval classes) heap entries).
A fixed period is the wrong granularity under SLO pressure: when a
deadline-bound job's laxity headroom shrinks below a couple of periods,
preemption requests must land faster than the configured slice, and when
the node is idle the class can relax back to its base period
(LibPreemptible's adaptive microsecond-granularity argument, PAPERS.md).

``SliceController`` owns that adaptation. It is deliberately *deterministic*
— a pure function of the observation sequence, no wall-clock or RNG — so
the discrete-event executor mirrors the real-thread watchdog exactly and
policies stay lockstep-testable across both.

Semantics per interval class (the base period is the class key, so the
watchdog heap stays O(interval classes) — adaptation changes the class's
*effective* period, never its identity):

* **shrink** (×1/2 per step, floored at ``base × min_scale``) only under
  *deadline pressure*: observed laxity headroom below
  ``pressure_periods × base``. Queue depth alone never shrinks a class —
  a saturated best-effort node keeps its exact base period, so every
  non-deadline simulation result stays bit-identical to the fixed-tick
  engine (the zero-cost-when-unused acceptance bar).
* **grow** (×2 per step, capped at the base) once the pressure clears
  *and* the observed ready-queue depth is zero — both signals of the
  ISSUE's "observed queue depth and laxity headroom" pair, with depth
  gating the relax direction so a backlogged class does not bounce.
* **bounded hysteresis**: a class only moves after ``shrink_after`` /
  ``grow_after`` consecutive observations agree, and each observation
  moves the scale at most one ×2 step, so the effective period is bounded
  in [base × min_scale, base] and cannot flap on alternating signals.
"""

from __future__ import annotations

from typing import Optional

#: defaults: shrink fast (one pressured observation), relax slowly (three
#: calm ones), floor at base/8 — a 3 ms SCHED_FAIR class bottoms out at
#: 375 µs, an order of magnitude below the fixed tick but still far above
#: timer-thread overhead territory
MIN_SCALE = 1.0 / 8.0
SHRINK_AFTER = 1
GROW_AFTER = 3
PRESSURE_PERIODS = 2.0


class _ClassState:
    __slots__ = ("scale", "shrink_streak", "grow_streak")

    def __init__(self) -> None:
        self.scale = 1.0
        self.shrink_streak = 0
        self.grow_streak = 0


class SliceController:
    """Deterministic per-interval-class tick-period adaptation."""

    __slots__ = ("min_scale", "shrink_after", "grow_after",
                 "pressure_periods", "_classes")

    def __init__(self, *, min_scale: float = MIN_SCALE,
                 shrink_after: int = SHRINK_AFTER,
                 grow_after: int = GROW_AFTER,
                 pressure_periods: float = PRESSURE_PERIODS):
        if not 0.0 < min_scale <= 1.0:
            raise ValueError(f"min_scale must be in (0, 1]: {min_scale}")
        self.min_scale = float(min_scale)
        self.shrink_after = max(1, int(shrink_after))
        self.grow_after = max(1, int(grow_after))
        self.pressure_periods = float(pressure_periods)
        #: base interval -> adaptation state; one entry per interval class
        self._classes: dict[float, _ClassState] = {}

    # -- reading -------------------------------------------------------- #
    def effective(self, base: float) -> float:
        """The class's current effective period (base × scale)."""
        st = self._classes.get(base)
        return base if st is None else base * st.scale

    def scale_of(self, base: float) -> float:
        st = self._classes.get(base)
        return 1.0 if st is None else st.scale

    def n_classes(self) -> int:
        return len(self._classes)

    # -- observing ------------------------------------------------------ #
    def observe(self, base: float, *, depth: int,
                laxity: Optional[float]) -> float:
        """Record one tick-time observation for the class of ``base`` and
        return the (possibly updated) effective period. ``depth`` is the
        arbiter-wide ready-queue depth, ``laxity`` the minimum deadline
        headroom (None = nothing deadline-bound pending)."""
        st = self._classes.get(base)
        if st is None:
            if laxity is None or laxity >= self.pressure_periods * base:
                return base  # calm and already at base: allocate nothing
            st = self._classes[base] = _ClassState()
        pressured = (laxity is not None
                     and laxity < self.pressure_periods * base)
        if pressured:
            st.grow_streak = 0
            st.shrink_streak += 1
            if st.shrink_streak >= self.shrink_after \
                    and st.scale > self.min_scale:
                st.scale = max(st.scale * 0.5, self.min_scale)
                st.shrink_streak = 0
        elif depth == 0:
            st.shrink_streak = 0
            st.grow_streak += 1
            if st.grow_streak >= self.grow_after and st.scale < 1.0:
                st.scale = min(st.scale * 2.0, 1.0)
                st.grow_streak = 0
        else:
            # backlogged but no deadline pressure: hold (no flapping)
            st.shrink_streak = 0
            st.grow_streak = 0
        if st.scale >= 1.0 and st.shrink_streak == 0 \
                and st.grow_streak == 0 and not pressured:
            del self._classes[base]  # settled back: state stays O(active)
            return base
        return base * st.scale

    def forget(self, base: float) -> None:
        """Drop a class's adaptation state (its last slot disarmed)."""
        self._classes.pop(base, None)
