"""Execution-resource topology.

Paper: cores grouped into NUMA domains (2 sockets x 56 cores).
TPU adaptation: *slots* (device partitions) grouped into ICI neighborhoods;
cross-domain = crossing the slow axis (other socket / other pod half / DCN).

The scheduler only ever needs a distance oracle:
    0 = same slot (perfect affinity: warm HBM/L2),
    1 = same domain (cheap migration),
    2 = remote domain (expensive migration).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Slot:
    """One execution resource: a core (paper) or a device partition (TPU)."""

    sid: int
    domain: int

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Slot({self.sid}@d{self.domain})"


class Topology:
    """A fixed set of slots partitioned into locality domains."""

    def __init__(self, n_slots: int, n_domains: int = 1, *, name: str = "node"):
        if n_slots <= 0:
            raise ValueError("need at least one slot")
        if n_domains <= 0 or n_slots % n_domains != 0:
            raise ValueError(f"{n_slots} slots not divisible into {n_domains} domains")
        self.name = name
        self.n_domains = n_domains
        per = n_slots // n_domains
        self.slots: list[Slot] = [Slot(i, i // per) for i in range(n_slots)]
        self._per_domain = per
        #: per-slot distance-ordered neighbor tuples, built lazily — the
        #: allocation-free fast path behind ``neighbors_first`` for hot
        #: per-pick placement searches (SCHED_COOP §4.1)
        self._neighbor_cache: list[Optional[tuple[Slot, ...]]] = [None] * n_slots

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def domain_slots(self, domain: int) -> Sequence[Slot]:
        lo = domain * self._per_domain
        return self.slots[lo : lo + self._per_domain]

    def domain_of(self, sid: int) -> int:
        return self.slots[sid].domain

    def distance(self, a: int, b: int) -> int:
        """0 same slot, 1 same domain, 2 cross domain."""
        if a == b:
            return 0
        return 1 if self.domain_of(a) == self.domain_of(b) else 2

    def neighbors_first(self, sid: int) -> tuple[Slot, ...]:
        """All slots ordered by distance from ``sid`` (affinity search order).

        This is the SCHED_COOP placement order of §4.1: preferred core, then
        same NUMA domain, then everything else. The tuple is computed once
        per slot and cached, so per-pick placement searches allocate nothing.
        """
        cached = self._neighbor_cache[sid]
        if cached is None:
            home = self.slots[sid]
            order = [home]
            order.extend(
                s for s in self.domain_slots(home.domain) if s.sid != sid
            )
            order.extend(s for s in self.slots if s.domain != home.domain)
            cached = self._neighbor_cache[sid] = tuple(order)
        return cached

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Topology({self.name}: {self.n_slots} slots / {self.n_domains} domains)"


def pod_topology(n_chips: int = 256, neighborhoods: int = 2) -> Topology:
    """A TPU pod viewed as a scheduling topology (ICI halves as domains)."""
    return Topology(n_chips, neighborhoods, name=f"pod{n_chips}")


def node_topology(cores: int = 112, sockets: int = 2) -> Topology:
    """The paper's evaluation node: 2 x 56-core Sapphire Rapids."""
    return Topology(cores, sockets, name=f"node{cores}")
