"""Deadline-aware job-level arbitration (SLO-native serving).

``DeadlineArbiter`` is the worked example of the ``SlotArbiter`` override
points (``_pick_multi`` / ``_recompute_quotas``): it makes the two-level
scheduler deadline-aware without touching the scheduler core or the
intra-job policies.

Deadline sources, both tracked per *job*:

* **task deadlines** — any READY task whose ``Task.deadline`` is set joins
  its job's deadline heap at the arbiter's ``on_ready`` hook (lazily
  invalidated: entries die when the task runs, finishes, or its deadline
  changes);
* **posted deadlines** — ``post_deadline(job, t)`` registers an
  engine-level obligation (e.g. an inference request sitting in a server's
  batch queue, not yet materialized as a task) and returns a token;
  ``retire_deadline(job, token)`` withdraws it when the request completes.

From these the arbiter derives each job's **laxity** — earliest deadline
minus now minus a cost estimate (the earliest pending task's ``cost_hint``)
— and changes three things:

1. **EDF grant order** (``_pick_multi``): within each I5 tier
   (spare-lease groups still strictly precede borrowers — non-deadline
   siblings keep their full I5 guarantee), deadline-holding groups are
   granted freed slots earliest-deadline-first, ahead of the tier's
   non-deadline groups; inside a chosen dedicated group the earliest
   pending deadline task is claimed directly, so intra-job order is EDF
   too. Ties and non-deadline groups keep the base largest-spare /
   least-over order.
2. **Urgency-boosted quotas** (``_recompute_quotas``): a job whose laxity
   is at or below ``urgency_threshold`` has its effective share multiplied
   by ``deadline_boost`` (bounded, restored after apportionment), so a
   rebalance under SLO pressure tilts integer quotas toward the pressed
   job. Quotas are re-evaluated at every rebalance and at every urgent
   grant.
3. **Urgent grants**: when a deadline job's laxity goes negative while no
   idle slot exists, the arbiter immediately flags need-resched on the
   lowest-value *borrowed* slot — a preemptive-policy slot running beyond
   its group's quota, preferring non-deadline victims and the most
   over-quota group — and stashes the pressed job's earliest deadline task
   as the slot's redispatch hint (``Scheduler.urgent_preempt``). The
   executor's ``on_urgent`` hook (a watchdog condition-variable kick under
   real threads) services the flag now instead of at the next periodic
   tick. In-lease slots are never victimized (that would break I5's
   spirit), cooperative-policy slots never either (I2).

Zero-cost-when-unused: with no posted deadline and no deadline task
pending, every override falls through to the ``SlotArbiter`` behaviour
after one empty-dict check, and the single-group fast path stays rebound
to the default policy's own methods.
"""

from __future__ import annotations

import itertools
from heapq import heappop, heappush
from typing import Optional

from repro.core.arbiter import ArbiterGroup, SlotArbiter
from repro.core.policies.base import Policy
from repro.core.task import Job, Task, TaskState


class DeadlineArbiter(SlotArbiter):
    """EDF / least-laxity slot arbitration over the ``SlotArbiter`` lease
    machinery (see module docstring for the full contract)."""

    def __init__(self, default_policy: Policy, *,
                 urgency_threshold: float = 0.0,
                 deadline_boost: float = 2.0):
        #: laxity at/below which a job counts as *urgent* (quota boost,
        #: urgent grants). 0.0 = only negative laxity (the ISSUE contract).
        self.urgency_threshold = float(urgency_threshold)
        #: bounded share multiplier applied to urgent jobs at quota
        #: recompute time
        self.deadline_boost = float(deadline_boost)
        #: jid -> heap of (deadline, token) posted obligations
        self._posted: dict[int, list[tuple[float, int]]] = {}
        self._retired: set[int] = set()
        self._token = itertools.count(1)
        #: jid -> heap of (deadline, seq, task) for READY deadline tasks
        #: (lazily invalidated: valid iff still READY with that deadline)
        self._ready_dl: dict[int, list[tuple[float, int, Task]]] = {}
        self._dlseq = itertools.count(1)
        #: urgent grants issued (introspection / benchmarks)
        self.urgent_grants = 0
        super().__init__(default_policy)  # binds entry points (see below)

    # ------------------------------------------------------------------ #
    # deadline bookkeeping
    # ------------------------------------------------------------------ #
    def post_deadline(self, job: Job, deadline: float) -> int:
        """Register an engine-level deadline obligation for ``job`` (e.g.
        a queued inference request); returns a token for ``retire``.
        Fires the urgent path immediately when the new obligation is
        already past its laxity budget."""
        token = next(self._token)
        heap = self._posted.get(job.jid)
        if heap is None:
            heap = self._posted[job.jid] = []
        heappush(heap, (float(deadline), token))
        rec = getattr(self.sched, "_rec", None)
        if rec is not None:
            from repro.core.scheduler import REC_DL_POST
            rec((self.sched.clock(), REC_DL_POST, job.jid, float(deadline)))
        self._maybe_urgent(job)
        return token

    def retire_deadline(self, job: Job, token: int) -> None:
        """Withdraw a posted obligation (request completed/cancelled)."""
        rec = getattr(self.sched, "_rec", None)
        if rec is not None:
            from repro.core.scheduler import REC_DL_RETIRE
            rec((self.sched.clock(), REC_DL_RETIRE, job.jid, token))
        heap = self._posted.get(job.jid)
        if not heap:
            return
        if heap[0][1] == token:
            heappop(heap)
            self._drain_retired(heap)
            if not heap:
                del self._posted[job.jid]
        else:
            self._retired.add(token)

    def _drain_retired(self, heap: list) -> None:
        retired = self._retired
        while heap and heap[0][1] in retired:
            retired.discard(heappop(heap)[1])

    def _active(self) -> bool:
        return bool(self._posted or self._ready_dl)

    def _job_deadline(self, jid: int) -> tuple[Optional[float], float]:
        """(earliest pending deadline, cost estimate) for one job — lazily
        compacting both heaps. The estimate is the earliest READY deadline
        task's ``cost_hint`` (0.0 for posted-only obligations)."""
        best: Optional[float] = None
        est = 0.0
        heap = self._posted.get(jid)
        if heap is not None:
            self._drain_retired(heap)
            if heap:
                best = heap[0][0]
            else:
                del self._posted[jid]
        rheap = self._ready_dl.get(jid)
        if rheap is not None:
            while rheap:
                dl, _, task = rheap[0]
                if task.state is TaskState.READY and task.deadline == dl:
                    if best is None or dl < best:
                        best = dl
                        est = task.cost_hint
                    break
                heappop(rheap)
            if not rheap:
                del self._ready_dl[jid]
        return best, est

    def _earliest_ready_task(self, jid: int) -> Optional[Task]:
        rheap = self._ready_dl.get(jid)
        while rheap:
            dl, _, task = rheap[0]
            if task.state is TaskState.READY and task.deadline == dl:
                return task
            heappop(rheap)
        return None

    def _group_deadline(self, group: ArbiterGroup) -> Optional[float]:
        best: Optional[float] = None
        for jid in group.jids:
            dl, _ = self._job_deadline(jid)
            if dl is not None and (best is None or dl < best):
                best = dl
        return best

    # -- the job-level laxity signal ------------------------------------ #
    def laxity(self, job: Job, now: float) -> Optional[float]:
        """``job``'s deadline headroom: earliest pending deadline − now −
        cost estimate, or None when nothing deadline-bound is pending."""
        dl, est = self._job_deadline(job.jid)
        return None if dl is None else dl - now - est

    def laxity_headroom(self, now: float) -> Optional[float]:
        """Minimum laxity across all jobs with pending deadlines (the
        adaptive slice controller's shrink signal)."""
        if not self._active():
            return None
        best: Optional[float] = None
        for jid in list(self._posted.keys() | self._ready_dl.keys()):
            dl, est = self._job_deadline(jid)
            if dl is None:
                continue
            lax = dl - now - est
            if best is None or lax < best:
                best = lax
        return best

    # ------------------------------------------------------------------ #
    # entry-point hooks (deadline tracking rides on_ready in both the
    # single-group and multi-group binding modes)
    # ------------------------------------------------------------------ #
    def _bind_single(self) -> None:
        super()._bind_single()
        self._inner_on_ready = self.on_ready
        self.on_ready = self._on_ready_deadline

    def _bind_multi(self) -> None:
        super()._bind_multi()
        self._inner_on_ready = self.on_ready
        self.on_ready = self._on_ready_deadline

    def _on_ready_deadline(self, task: Task) -> None:
        self._inner_on_ready(task)
        if task.deadline is None:
            return  # no SLO: exactly the base arbiter's on_ready path
        jid = task.job.jid
        heap = self._ready_dl.get(jid)
        if heap is None:
            heap = self._ready_dl[jid] = []
        heappush(heap, (task.deadline, next(self._dlseq), task))
        self._maybe_urgent(task.job)

    def detach_job(self, job: Job) -> None:
        super().detach_job(job)
        self._posted.pop(job.jid, None)
        self._ready_dl.pop(job.jid, None)

    # ------------------------------------------------------------------ #
    # override point 1: EDF grant order
    # ------------------------------------------------------------------ #
    def _pick_multi(self, slot_id: int) -> Optional[Task]:
        """I5-tiered EDF: spare-lease groups strictly before borrowers
        (the base tier boundary — non-deadline siblings with spare lease
        can never be starved by a borrowing deadline group), but *within*
        each tier deadline-holding groups go earliest-deadline-first,
        ahead of the tier's non-deadline groups, which keep the base
        largest-spare/least-over order among themselves."""
        if not self._active():
            return super()._pick_multi(slot_id)
        candidates = []
        for i, g in enumerate(self._groups):
            if g.policy.has_ready():
                dl = self._group_deadline(g)
                borrow = g.in_use - g.quota
                tier = 0 if borrow < 0 else 1
                if dl is None:
                    candidates.append(((tier, 1, 0.0, borrow, i), g))
                else:
                    candidates.append(((tier, 0, dl, borrow, i), g))
        if not candidates:
            return None
        candidates.sort(key=lambda c: c[0])
        for key, g in candidates:
            if not g.dedicated and len(g.jids) > 1:
                task = self._pick_shared_group(g, slot_id)
            elif key[1] == 0:
                task = self._pick_edf_in_group(g, slot_id)
            else:
                task = g.policy.pick(slot_id)
            if task is not None:
                return task
        return None

    def _pick_edf_in_group(self, g: ArbiterGroup, slot_id: int
                           ) -> Optional[Task]:
        """Intra-group EDF for a dedicated deadline-holding group: claim
        the earliest pending deadline *task* directly (the policy's
        ``remove`` keeps its incremental accounting exact); posted-only
        obligations or an unclaimable task fall back to the policy's own
        pick order."""
        for jid in g.jids:
            task = self._earliest_ready_task(jid)
            if task is not None:
                try:
                    g.policy.remove(task)
                except (KeyError, NotImplementedError):
                    break
                return task
        return g.policy.pick(slot_id)

    # ------------------------------------------------------------------ #
    # override point 2: urgency-boosted quotas
    # ------------------------------------------------------------------ #
    def _recompute_quotas(self) -> None:
        """Largest-remainder apportionment over *urgency-adjusted* shares:
        a job whose laxity is at/below ``urgency_threshold`` weighs
        ``deadline_boost`` times its configured share for this computation
        (shares are restored afterwards — the boost is bounded and
        re-evaluated at every rebalance / urgent grant)."""
        if not self._active() or self.sched is None:
            return super()._recompute_quotas()
        clock = getattr(self.sched, "clock", None)
        if clock is None:
            return super()._recompute_quotas()
        now = clock()
        boosted = []
        for lease in self._leases.values():
            lax = self.laxity(lease.job, now)
            if lax is not None and lax <= self.urgency_threshold:
                boosted.append((lease, lease.share))
                lease.share = lease.share * self.deadline_boost
        try:
            super()._recompute_quotas()
        finally:
            for lease, share in boosted:
                lease.share = share

    # ------------------------------------------------------------------ #
    # the urgent-grant path
    # ------------------------------------------------------------------ #
    def _maybe_urgent(self, job: Job) -> None:
        """Negative laxity + no idle capacity -> flag the lowest-value
        borrowed slot NOW (instead of at the next periodic tick), stash
        the pressed job's earliest deadline task as the redispatch hint,
        and re-tilt quotas under the urgency boost."""
        sched = self.sched
        if sched is None:
            return
        slots = getattr(sched, "_slots", None)
        if slots is None:  # bare stand-in scheduler (benchmarks/tests)
            return
        lease = self.lease_of(job)
        if lease is None:
            return
        now = sched.clock()
        lax = self.laxity(job, now)
        if lax is None or lax > self.urgency_threshold:
            return
        if sched._idle:
            return  # idle capacity exists: the normal fill admits the work
        victim = self._find_victim(lease.group, slots)
        if victim is None:
            return  # no borrowed preemptive slot: EDF order at the next
            #         natural scheduling point is the best I5 allows
        self._recompute_quotas()
        successor = self._earliest_ready_task(job.jid)
        if sched.urgent_preempt(victim, successor):
            self.urgent_grants += 1

    def _find_victim(self, pressed: ArbiterGroup, slots) -> Optional[int]:
        """The lowest-value borrowed slot: running a preemptive-policy
        task (I2) of a group beyond its quota (I5: in-lease grants are
        never revoked for a borrower), preferring victims with no pending
        deadline of their own, then the most over-quota group, then the
        lowest slot id. ``None`` when no slot qualifies."""
        best = None
        best_key = None
        leases = self._leases
        for sid, st in enumerate(slots):
            t = st.running
            if t is None or st.need_resched:
                continue
            vlease = leases.get(t.job.jid)
            vgroup = vlease.group if vlease is not None \
                else self._default_group
            if vgroup is pressed:
                continue
            if not vgroup.policy.preemptive:
                continue  # I2: cooperative slots are never victims
            over = vgroup.in_use - vgroup.quota
            if over <= 0:
                continue  # within lease: not a borrowed slot
            vdl, _ = self._job_deadline(t.job.jid)
            key = (0 if vdl is None else 1, -over, sid)
            if best_key is None or key < best_key:
                best, best_key = sid, key
        return best
