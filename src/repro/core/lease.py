"""Reusable lease/quota machinery — shared by the in-process ``SlotArbiter``
and the cross-process ``NodeBroker`` (repro.ipc).

Both arbitration layers answer the same question at different scopes: given
a capacity of slots and a set of share-weighted claimants, what integer
entitlement does each claimant hold (largest-remainder apportionment), and
in what order may claimants be *granted* capacity so that the grant rule —
invariant I5: *no claimant is granted capacity beyond its lease while a
sibling with spare lease has demand* — holds structurally?

The in-process arbiter apportions one ``Scheduler``'s slots across job
leases; the node broker apportions one *node*'s slots across registered
processes. Extracting the machinery here keeps the two layers
behaviour-identical (property-tested in tests/test_lease_table.py) and the
arbiter's single-group fast path untouched (the table is only consulted at
membership/share changes, never per pick).

Entries are caller-owned objects exposing three attributes the table reads
and writes: ``share`` (relative weight, read), ``quota`` (integer
entitlement, written by ``recompute``) and ``in_use`` (currently consumed
capacity, read by the borrow order). ``SlotLease`` (arbiter) and
``ProcLease`` (broker) both qualify.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence, TypeVar

E = TypeVar("E")


def apportion(capacity: int, shares: Sequence[float]) -> list[int]:
    """Largest-remainder apportionment of ``capacity`` integer slots over
    relative ``shares``. All-zero (or all-negative-clamped) share vectors
    fall back to equal entitlement. Quotas sum exactly to ``capacity``
    (for ``capacity >= 0``); an empty share vector yields ``[]``."""
    n = len(shares)
    if n == 0 or capacity <= 0:
        return [0] * n
    total = float(sum(shares))
    if total <= 0.0:
        exacts = [capacity / float(n)] * n
    else:
        exacts = [capacity * s / total for s in shares]
    quotas = [int(e) for e in exacts]
    granted = sum(quotas)
    remainders = sorted(
        (-(exact - q), i) for i, (exact, q) in enumerate(zip(exacts, quotas))
    )
    for k in range(capacity - granted):
        quotas[remainders[k][1]] += 1
    return quotas


def borrow_order(entries: Iterable[E]) -> list[E]:
    """The I5 grant order over lease entries: claimants holding spare lease
    first (largest spare wins), then — work-conserving borrowing — the
    claimants already at/over quota, least-over first; ties resolve by the
    given (attach) order. A borrowing grant is therefore only reachable
    after every spare-lease claimant declined, which is exactly the I5
    grant rule both arbitration layers enforce structurally."""
    return [e for _, _, e in
            sorted((e.in_use - e.quota, i, e) for i, e in enumerate(entries))]


class LeaseTable:
    """An insertion-ordered table of lease entries over one capacity pool.

    Owns no policy: it only maps shares to integer quotas (``recompute``)
    and exposes the I5 borrow order (``grant_order``). The arbiter keys
    entries by job id, the broker by worker id.
    """

    __slots__ = ("capacity", "entries")

    def __init__(self, capacity: int = 0):
        self.capacity = int(capacity)
        #: key -> entry, in attach order (dict preserves insertion order;
        #: the borrow order's tie-break and the largest-remainder scan
        #: order both follow it)
        self.entries: dict = {}

    # -- membership ----------------------------------------------------- #
    def add(self, key, entry) -> None:
        self.entries[key] = entry

    def pop(self, key):
        return self.entries.pop(key)

    def get(self, key, default=None):
        return self.entries.get(key, default)

    def values(self):
        return self.entries.values()

    def __contains__(self, key) -> bool:
        return key in self.entries

    def __len__(self) -> int:
        return len(self.entries)

    # -- apportionment & grant order ------------------------------------ #
    def recompute(self) -> None:
        """Write largest-remainder quotas into every entry (``entry.quota``)
        from the current shares and capacity."""
        entries = list(self.entries.values())
        quotas = apportion(self.capacity, [e.share for e in entries])
        for entry, q in zip(entries, quotas):
            entry.quota = q

    def grant_order(self, entries: Optional[Iterable] = None) -> list:
        """I5 borrow order over ``entries`` (default: every entry)."""
        return borrow_order(self.entries.values()
                            if entries is None else entries)

    def spare(self) -> int:
        """Capacity not consumed by current ``in_use`` (may go negative
        transiently while a reclaim is in flight)."""
        return self.capacity - sum(e.in_use for e in self.entries.values())

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"LeaseTable({len(self.entries)} leases / {self.capacity})"
