from repro.runtime.sharding import Sharder, DEFAULT_RULES, logical_to_spec

__all__ = ["Sharder", "DEFAULT_RULES", "logical_to_spec"]
