"""Distributed-optimization helpers: gradient compression + quantized
collectives (used across the DCN-ish ``pod`` axis where bandwidth is the
scarce resource).

* ``quantize_int8`` / ``dequantize_int8`` — symmetric per-tensor int8.
* ``compressed_psum`` — int8-quantized all-reduce inside ``shard_map``:
  ranks agree on a shared scale (pmax), sum int8 payloads in int32,
  dequantize. 4x less link traffic than fp32 psum, ~2x less than bf16.
* ``topk_compress`` — magnitude top-k sparsification with error feedback
  (the residual is carried to the next step, the classic Deep Gradient
  Compression recipe).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, scale: Optional[jax.Array] = None):
    if scale is None:
        scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantized psum — call inside shard_map/pmap over ``axis_name``.

    The scale is the global max (pmax) so every rank quantizes onto the
    same grid; int8 payloads are summed exactly in int32.
    """
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    s = jax.lax.psum(q, axis_name)
    return s.astype(jnp.float32) * scale


def topk_compress(g: jax.Array, error: jax.Array, *, frac: float = 0.01):
    """Top-k sparsification with error feedback.

    Returns (sparse_grad, new_error): ``sparse_grad`` keeps only the
    top-``frac`` magnitudes of (g + error); the rest accumulates into
    ``new_error`` for the next step.
    """
    acc = g + error
    flat = acc.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(acc) >= thresh
    sparse = jnp.where(mask, acc, 0.0)
    return sparse, acc - sparse
