"""Optional GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

Not used by the assigned cells (DESIGN.md §7: DP x FSDP x TP suffices at
256-512 chips), but 1000+-node deployments of the largest configs want a
``pipe`` axis; this module provides the schedule and is tested on fake
devices.

Implementation: ``shard_map`` over the pipe axis — each rank holds one
stage's parameters; activations rotate rank->rank+1 with
``lax.ppermute``. The loop runs ``n_micro + n_stages - 1`` ticks (the
GPipe fill/drain bubble); rank r computes on ticks r..r+n_micro-1.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe_forward(
    mesh: Mesh,
    stage_fn: Callable[[jax.Array, jax.Array], jax.Array],
    stage_params: jax.Array,   # [n_stages, ...] (stacked per-stage)
    microbatches: jax.Array,   # [n_micro, mb, ...]
    *,
    axis: str = "pipe",
) -> jax.Array:
    """Runs ``y = stage_{n-1}(...stage_0(x))`` for every microbatch with
    the GPipe rotation schedule. Returns [n_micro, mb, ...] outputs."""
    n_stages = mesh.shape[axis]
    n_micro = microbatches.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_rank(params, mb):  # params [1,...]; mb [n_micro, b, ...]
        p = jax.tree_util.tree_map(lambda a: a[0], params)
        rank = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(mb[0])          # activation in flight
        outs = jnp.zeros_like(mb)            # only the last rank's are real

        def tick(carry, t):
            buf, outs = carry
            # rank 0 injects microbatch t (when in range)
            inject = jnp.where(t < n_micro, t, 0)
            buf = jnp.where(rank == 0, mb[inject], buf)
            active = jnp.logical_and(t - rank >= 0, t - rank < n_micro)
            y = stage_fn(p, buf)
            y = jnp.where(active, y, buf)
            # the last rank records its completed microbatch
            done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            record = jnp.logical_and(rank == n_stages - 1, active)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(record, y, outs[done_idx]), done_idx, 0
            )
            # rotate activations to the next stage
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        return outs

    sm = shard_map(
        per_rank,
        mesh=mesh,
        in_specs=(P(axis), P(None)),
        out_specs=P(axis),  # each rank emits its view; stage n-1 is truth
        check_rep=False,
    )
    all_outs = sm(stage_params, microbatches)
    # out has a leading pipe dim folded into axis 0 of outs per rank:
    # [n_stages * n_micro, ...]; the final stage's block is the result
    return all_outs.reshape(n_stages, n_micro, *microbatches.shape[1:])[-1]
