"""Logical-axis sharding rules → GSPMD shardings (MaxText-style).

Every parameter and key activation in the model zoo carries *logical* axis
names ("embed", "heads", "vocab", "act_seq", ...). A rule table maps logical
axes to preferred mesh axes; ``logical_to_spec`` resolves them against a
concrete mesh, **auto-dropping** mesh axes that don't divide the dimension
or are already taken by another dimension of the same tensor.

This single mechanism is what makes all 40 (arch × shape) dry-run cells
lower cleanly: 8 KV heads on a 16-way model axis degrade to replication,
batch=1 long-context decode drops its batch sharding, 8 experts on a
16-way axis fall back to weight-dim sharding, etc., with no per-arch code.
"""

from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> ordered mesh-axis preference
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # activations
    "batch": ("pod", "data"),
    "act_batch": ("pod", "data"),
    "act_seq": ("model",),          # sequence parallelism (Megatron-SP style)
    "act_embed": (),                 # replicated within a row by default
    "act_heads": ("model",),        # tensor parallel attention activations
    "act_mlp": ("model",),
    "act_vocab": ("model",),        # sharded logits for the softmax/CE
    "act_experts": ("model",),
    # parameters
    "embed": ("data",),              # FSDP-style parameter sharding
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "ssm_heads": ("model",),
    "lru": ("model",),
    "head_dim": (),
    "state": (),
    "conv": (),
    "layers": (),                    # scan dim: never sharded
    # kv-cache
    "kv_batch": ("pod", "data"),
    "kv_seq": ("model",),           # flash-decode style split-KV
}


def logical_to_spec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Mapping[str, tuple[str, ...]]] = None,
) -> P:
    """Resolve logical axes to a PartitionSpec for ``mesh``.

    Drops (a) mesh axes not present in the mesh, (b) axes already used by
    another dim of this tensor, (c) axes whose size doesn't divide the dim.
    """
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} vs axes {axes} rank mismatch")
    rules = rules or DEFAULT_RULES
    used: set[str] = set()
    parts: list[Any] = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            parts.append(None)
            continue
        keep: list[str] = []
        prod = 1
        for m in rules.get(ax, ()):
            size = mesh.shape.get(m)
            if size is None or m in used:
                continue
            if dim % (prod * size) == 0:
                keep.append(m)
                prod *= size
        used.update(keep)
        if not keep:
            parts.append(None)
        elif len(keep) == 1:
            parts.append(keep[0])
        else:
            parts.append(tuple(keep))
    # trim trailing Nones (cosmetic)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


class Sharder:
    """Carries (mesh, rules) through model code; no-op when mesh is None.

    ``constrain(x, *axes)`` places with_sharding_constraint on key
    activations; ``param_shardings(specs)`` builds NamedShardings for a
    ParamSpec tree (see models/base.py).
    """

    def __init__(self, mesh: Optional[Mesh] = None,
                 rules: Optional[Mapping[str, tuple[str, ...]]] = None,
                 *, fsdp_gather: bool = False):
        self.mesh = mesh
        self.rules = dict(rules or DEFAULT_RULES)
        #: when True, ``gather()`` constrains layer weights to drop their
        #: FSDP ("embed") sharding at use time — explicit ZeRO-3-style
        #: per-layer all-gather, which keeps backward activation shardings
        #: on the model axis (see EXPERIMENTS.md §Perf iteration D).
        self.fsdp_gather = fsdp_gather
        #: when True, ``sp_boundary()`` emits explicit bf16 seq all-gathers
        #: at attention/MLP entries (Megatron-SP; §Perf iteration E).
        self.explicit_sp = False

    def with_rules(self, overrides: Mapping[str, tuple[str, ...]]) -> "Sharder":
        r = dict(self.rules)
        r.update(overrides)
        return Sharder(self.mesh, r, fsdp_gather=self.fsdp_gather)

    def spec(self, shape: Sequence[int], axes: Sequence[Optional[str]]) -> P:
        if self.mesh is None:
            return P()
        return logical_to_spec(shape, axes, self.mesh, self.rules)

    def sharding(self, shape: Sequence[int], axes: Sequence[Optional[str]]):
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec(shape, axes))

    def constrain(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        if self.mesh is None:
            return x
        spec = self.spec(x.shape, axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )

    def sp_boundary(self, x: jax.Array) -> jax.Array:
        """Explicit Megatron-SP boundary: all-gather the sequence dim (in
        the model's COMPUTE dtype, before any XLA-internal f32 upcast of
        dot operands) on entry to attention/MLP. The transpose of this
        constraint reduce-scatters the bf16 cotangent. No-op unless
        ``explicit_sp``. See EXPERIMENTS.md §Perf iteration E."""
        if self.mesh is None or not self.explicit_sp:
            return x
        axes = ("act_batch",) + (None,) * (x.ndim - 1)
        return self.constrain(x, *axes)

    def gather(self, w: jax.Array, *axes: Optional[str]) -> jax.Array:
        """FSDP use-time weight gather: same spec as ``constrain`` but with
        the "embed" (FSDP) axis replicated. No-op unless fsdp_gather."""
        if self.mesh is None or not self.fsdp_gather:
            return w
        rules = dict(self.rules)
        rules["embed"] = ()
        spec = logical_to_spec(w.shape, axes, self.mesh, rules)
        return jax.lax.with_sharding_constraint(
            w, NamedSharding(self.mesh, spec)
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Sharder(mesh={None if self.mesh is None else dict(self.mesh.shape)})"
