"""Sharded checkpointing: atomic, async, restartable, reshardable.

Layout: <dir>/step_<n>/
    manifest.json       — flattened key list, shapes, dtypes, step
    <key>.npy           — one array per leaf (host representation)

* Atomicity: written to ``step_<n>.tmp`` then renamed — a crash mid-save
  never corrupts the latest checkpoint.
* Async: ``AsyncCheckpointer`` snapshots to host (device_get) on the
  caller's thread, then writes on a background thread; training continues.
  The flush wait is a USF blocking point when a runtime is attached.
* Elastic restore: leaves are re-placed with whatever shardings the NEW
  mesh prescribes (``device_put`` against the target sharding) — the
  checkpoint is mesh-agnostic, which is what launch/elastic.py exercises.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(state: Any, directory: str, step: int,
                    *, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the final path."""
    base = pathlib.Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(state)
    manifest = {"step": step, "keys": []}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        entry = {"key": key, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
        if arr.dtype.kind not in "biufc":
            # exotic dtype (bfloat16, fp8, ...): store raw bytes
            np.save(tmp / fname,
                    np.ascontiguousarray(arr).reshape(-1).view(np.uint8))
            entry["raw"] = True
        else:
            np.save(tmp / fname, arr)
        manifest["keys"].append(entry)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _cleanup(base, keep)
    return str(final)


def _cleanup(base: pathlib.Path, keep: int) -> None:
    steps = sorted(
        (p for p in base.iterdir() if re.fullmatch(r"step_\d{8}", p.name)),
        key=lambda p: p.name,
    )
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    base = pathlib.Path(directory)
    if not base.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in base.iterdir()
        if re.fullmatch(r"step_\d{8}", p.name)
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target: Any,
                       *, shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (optional pytree) re-places leaves
    for a (possibly different) mesh — elastic rescale."""
    path = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    by_key = {e["key"]: e for e in manifest["keys"]}

    flat_t, treedef = jax.tree_util.tree_flatten_with_path(target)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]
    out = []
    for i, (p, leaf) in enumerate(flat_t):
        key = "/".join(_path_str(x) for x in p)
        e = by_key[key]
        arr = np.load(path / e["file"])
        if e.get("raw"):
            import jax.numpy as jnp

            dt = np.dtype(jnp.dtype(e["dtype"]))
            arr = arr.view(dt).reshape(e["shape"])
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {expect}")
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr, dtype=getattr(leaf, "dtype", None)))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Snapshot on caller thread, write on background thread."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, state: Any, step: int) -> None:
        self.wait()  # one in flight at a time
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state
        )

        def write():
            try:
                save_checkpoint(host_state, self.directory, step,
                                keep=self.keep)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
