from repro.ckpt.checkpoint import (
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    AsyncCheckpointer,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]
