"""Trace synthesis: arrival processes, workload generators, perturbations.

Everything here produces ``Workload`` objects (or plain arrival-time
lists) — no live engine objects — so a synthesized trace can be saved,
reloaded, perturbed and replayed under any ``ReplayConfig``. Pure
``random``/``math`` (no numpy in ``src/``); every generator is seeded and
deterministic.

Arrival processes:

* ``poisson_arrivals``  — homogeneous Poisson (exponential gaps)
* ``burst_arrivals``    — on/off modulated Poisson (MMPP-style bursts)
* ``diurnal_arrivals``  — sinusoid-modulated Poisson via thinning

Workloads:

* ``colocation_workload`` — the throughput trace: a latency job's request
  stream (n×chunks short computes) co-located with checkpoint-yielding
  batch ranks. Default shape is the benchmark's 10⁶-event trace.
* ``slo_workload``        — the open-arrival SLO cell of
  ``benchmarks/microservices.py`` rebuilt as a replayable workload
  (same node/shares/policies/service/classes), for the replayer-backed
  deadline-vs-share A/B at 10⁵+ requests per cell.

Perturbations (model straggler/churn studies from cluster traces):

* ``with_stragglers`` — scale a random task subset's compute times
* ``with_node_churn`` — timed width changes (slot parking) on the node
"""

from __future__ import annotations

import math
import random
from typing import Iterable, Optional

from repro.trace.replayer import JobSpec, TaskSpec, Workload

__all__ = [
    "poisson_arrivals",
    "burst_arrivals",
    "diurnal_arrivals",
    "colocation_workload",
    "slo_workload",
    "with_stragglers",
    "with_node_churn",
    "SLO_SLOTS",
    "SLO_SERVE_SHARE",
    "SLO_BATCH_SHARE",
    "SLO_SERVICE_S",
    "SLO_CHUNK_S",
    "SLO_BATCH_CHUNK_S",
    "SLO_CLASSES",
]


# --------------------------------------------------------------------- #
# arrival processes
# --------------------------------------------------------------------- #
def poisson_arrivals(rate: float, n: int, *, seed: int = 0,
                     start: float = 0.05) -> list[float]:
    """``n`` homogeneous-Poisson arrival times at ``rate``/s."""
    rng = random.Random(seed)
    expo = rng.expovariate
    t = start
    out = []
    for _ in range(n):
        t += expo(rate)
        out.append(t)
    return out


def burst_arrivals(rate: float, n: int, *, burst_factor: float = 8.0,
                   burst_frac: float = 0.1, period: float = 2.0,
                   seed: int = 0, start: float = 0.05) -> list[float]:
    """On/off modulated Poisson: within each ``period``, a ``burst_frac``
    window runs at ``burst_factor``× the base rate (the base rate is
    scaled down so the long-run mean stays ``rate``)."""
    if not 0.0 < burst_frac < 1.0:
        raise ValueError("burst_frac must be in (0, 1)")
    # mean = base * (1 - frac + frac * factor)  ==  rate
    base = rate / (1.0 - burst_frac + burst_frac * burst_factor)
    rng = random.Random(seed)
    expo = rng.expovariate
    t = start
    out = []
    for _ in range(n):
        phase = (t % period) / period
        r = base * burst_factor if phase < burst_frac else base
        t += expo(r)
        out.append(t)
    return out


def diurnal_arrivals(rate: float, n: int, *, period: float = 60.0,
                     depth: float = 0.8, seed: int = 0,
                     start: float = 0.05) -> list[float]:
    """Sinusoid-modulated Poisson (peak-to-trough swing ``depth``) via
    Lewis thinning: candidates at the peak rate, accepted with
    probability λ(t)/λ_peak. ``rate`` is the long-run mean."""
    if not 0.0 <= depth <= 1.0:
        raise ValueError("depth must be in [0, 1]")
    peak = rate * (1.0 + depth)
    rng = random.Random(seed)
    expo, unif = rng.expovariate, rng.random
    two_pi = 2.0 * math.pi / period
    t = start
    out = []
    while len(out) < n:
        t += expo(peak)
        lam = rate * (1.0 + depth * math.sin(two_pi * t))
        if unif() * peak <= lam:
            out.append(t)
    return out


# --------------------------------------------------------------------- #
# workload generators
# --------------------------------------------------------------------- #
def colocation_workload(*, n_requests: int = 30_000, chunks: int = 40,
                        chunk_s: float = 0.0005, rate: float = 250.0,
                        batch_tasks: int = 8, batch_segments: int = 12_000,
                        batch_chunk_s: float = 0.001,
                        yield_every: int = 100, seed: int = 0,
                        arrivals: Optional[list] = None) -> Workload:
    """The replay-throughput trace: a serve job's Poisson request stream
    (each request = ``chunks`` short computes) co-located with long
    checkpoint-yielding batch ranks. Defaults synthesize ≈1.36×10⁶
    engine events under the default SCHED_COOP config at ≈0.6 serve
    load on 8 slots (batch ranks borrow the rest — the node is full)."""
    if arrivals is None:
        arrivals = poisson_arrivals(rate, n_requests, seed=seed)
    serve, batch = JobSpec(0, "serve"), JobSpec(1, "batch")
    req_ops = ("compute", chunk_s, 0.0)
    request = tuple([req_ops] * chunks)
    seg = [("compute", batch_chunk_s, 0.0), ("checkpoint",)]
    batch_ops = []
    for i in range(batch_segments):
        batch_ops.extend(seg)
        if yield_every and (i + 1) % yield_every == 0:
            batch_ops.append(("yield",))
    batch_ops = tuple(batch_ops)

    tasks = [TaskSpec(0.0, i, 1, None, 0.0, batch_ops)
             for i in range(batch_tasks)]
    tasks.extend(
        TaskSpec(t, batch_tasks + i, 0, None, chunks * chunk_s, request)
        for i, t in enumerate(arrivals)
    )
    tasks.sort(key=lambda ts: ts.t)
    return Workload(
        jobs=[serve, batch], tasks=tasks,
        meta={"generator": "colocation", "n_requests": n_requests,
              "chunks": chunks, "chunk_s": chunk_s, "rate": rate,
              "batch_tasks": batch_tasks, "batch_segments": batch_segments,
              "seed": seed},
    )


# The open-arrival SLO cell (benchmarks/microservices.py), as data. Same
# node, shares, policies, service demand and request classes — only the
# arrival RNG differs (stdlib random here vs numpy there), which moves
# individual samples but not the distributions the A/B compares.
SLO_SLOTS = 8
SLO_SERVE_SHARE = 4.0
SLO_BATCH_SHARE = 4.0
SLO_SERVICE_S = 0.008
SLO_CHUNK_S = 0.001
SLO_BATCH_CHUNK_S = 0.005
SLO_CLASSES = [("tight", 0.030, 0.5), ("loose", 0.400, 0.5)]


def slo_workload(load: float, *, n_requests: int = 800,
                 seed: int = 0) -> Workload:
    """One offered-load cell of the SLO sweep as a replayable workload:
    Poisson arrivals at ``load × serve-share / service_s`` into a
    dedicated-policy serve job (every request carries an absolute
    deadline drawn from the tight/loose class mix) plus slot-hungry
    batch ranks running to the arrival horizon. Replay it under
    ``ReplayConfig(arbiter="deadline")`` vs ``"none"`` for the A/B."""
    rate = load * SLO_SERVE_SHARE / SLO_SERVICE_S
    arrivals = poisson_arrivals(rate, n_requests, seed=seed)
    rng = random.Random(seed + 1)
    horizon = arrivals[-1] + 2.0

    serve = JobSpec(0, "serve", share=SLO_SERVE_SHARE,
                    policy=("SCHED_FAIR", 0.003))
    batch = JobSpec(1, "batch", share=SLO_BATCH_SHARE,
                    policy=("SCHED_FAIR", 0.020))

    n_chunks = max(1, round(SLO_SERVICE_S / SLO_CHUNK_S))
    request = tuple([("compute", SLO_CHUNK_S, 0.0)] * n_chunks)

    # batch ranks: the live bench loops `while now < horizon`; the data
    # equivalent is a fixed segment count covering the horizon on a
    # dedicated slot (extra segments just keep borrowing idle slots)
    n_seg = int(math.ceil(horizon / SLO_BATCH_CHUNK_S))
    batch_ops = tuple([("compute", SLO_BATCH_CHUNK_S, 0.0),
                       ("checkpoint",)] * n_seg)
    tasks = [TaskSpec(0.0, i, 1, None, 0.0, batch_ops)
             for i in range(SLO_SLOTS)]

    weights = [w for _, _, w in SLO_CLASSES]
    classes = rng.choices(range(len(SLO_CLASSES)), weights=weights,
                          k=n_requests)
    for i, arr in enumerate(arrivals):
        cname, slo, _ = SLO_CLASSES[classes[i]]
        tasks.append(TaskSpec(arr, SLO_SLOTS + i, 0, arr + slo,
                              SLO_SERVICE_S, request))
    tasks.sort(key=lambda ts: ts.t)
    return Workload(
        jobs=[serve, batch], tasks=tasks,
        meta={"generator": "slo", "load": load, "rate_rps": round(rate, 2),
              "n_requests": n_requests, "seed": seed, "horizon": horizon,
              "classes": [{"name": n, "slo_s": s, "weight": w}
                          for n, s, w in SLO_CLASSES],
              "class_of": classes},
    )


# --------------------------------------------------------------------- #
# perturbations
# --------------------------------------------------------------------- #
def with_stragglers(workload: Workload, *, frac: float = 0.05,
                    factor: float = 4.0, seed: int = 0,
                    jid: Optional[int] = None) -> Workload:
    """A straggler study: scale every compute/stall duration of a random
    ``frac`` of tasks (optionally restricted to job ``jid``) by
    ``factor``. Returns a new Workload; the input is untouched."""
    rng = random.Random(seed)
    tasks = []
    slowed = 0
    for ts in workload.tasks:
        eligible = jid is None or ts.jid == jid
        if eligible and rng.random() < frac:
            ops = tuple(
                (op[0], op[1] * factor) + op[2:]
                if op[0] in ("compute", "stall") else op
                for op in ts.ops
            )
            hint = (ts.cost_hint * factor
                    if ts.cost_hint else ts.cost_hint)
            tasks.append(TaskSpec(ts.t, ts.tid, ts.jid, ts.deadline,
                                  hint, ops))
            slowed += 1
        else:
            tasks.append(ts)
    meta = dict(workload.meta)
    meta["stragglers"] = {"frac": frac, "factor": factor, "seed": seed,
                          "slowed": slowed}
    return Workload(jobs=list(workload.jobs), tasks=tasks,
                    control=list(workload.control), meta=meta)


def with_node_churn(workload: Workload,
                    events: Iterable[tuple]) -> Workload:
    """Overlay node-churn: ``events`` is ``(time, width)`` pairs — the
    node's effective slot count at each time (``None`` = full width).
    Replayed as elastic slot parking (``set_slot_target``), the
    engine-level analogue of nodes leaving/rejoining the cluster."""
    control = list(workload.control)
    churn = [(float(t), "target", w, None) for (t, w) in events]
    control.extend(churn)
    control.sort(key=lambda c: c[0])
    meta = dict(workload.meta)
    meta["node_churn"] = [[t, w] for (t, w) in events]
    return Workload(jobs=list(workload.jobs), tasks=list(workload.tasks),
                    control=control, meta=meta)
