"""Versioned JSONL trace schema (v1).

A trace file is one JSON header line followed by one JSON array per
record. The header pins schema name/version and the trace *kind*:

* ``decisions`` — the raw decision/event stream of a recorded run
  (what ``TraceRecorder`` writes).
* ``workload``  — a replayable workload: jobs, tasks with op lists,
  width/control events (what the synthesizers and the decision-stream
  reconstruction produce).

Decision records are ``[code, t, a, b]`` with two-letter codes; body ops
are compact arrays (``["c", dt, flops]`` for compute, …). Floats round-trip
exactly through JSON (``repr`` shortest-float), which the bit-identical
replay diff relies on.
"""

from __future__ import annotations

import json
from math import isfinite as _isfinite
from typing import Any, Iterable, Iterator, Optional, TextIO, Union

from repro.core.scheduler import (
    REC_ATTACH,
    REC_BLOCK,
    REC_DEMOTE,
    REC_DETACH,
    REC_DISPATCH,
    REC_DL_POST,
    REC_DL_RETIRE,
    REC_DONE,
    REC_JOB,
    REC_OP,
    REC_PREEMPT,
    REC_REQ_DONE,
    REC_REQUEST,
    REC_RESIZE,
    REC_SPAWN,
    REC_TARGET,
    REC_URGENT,
    REC_WAKE,
    REC_YIELD,
)

SCHEMA_NAME = "usf-trace"
SCHEMA_VERSION = 1

KIND_DECISIONS = "decisions"
KIND_WORKLOAD = "workload"


class TraceSchemaError(ValueError):
    pass


#: decision code <-> wire tag
CODE_TO_TAG = {
    REC_OP: "op",
    REC_SPAWN: "sp",
    REC_DISPATCH: "di",
    REC_BLOCK: "bl",
    REC_YIELD: "yi",
    REC_DONE: "dn",
    REC_PREEMPT: "pr",
    REC_WAKE: "wk",
    REC_JOB: "jb",
    REC_ATTACH: "at",
    REC_DEMOTE: "dm",
    REC_DETACH: "dt",
    REC_TARGET: "tg",
    REC_RESIZE: "rs",
    REC_DL_POST: "dp",
    REC_DL_RETIRE: "dr",
    REC_URGENT: "ur",
    REC_REQUEST: "rq",
    REC_REQ_DONE: "rd",
}
TAG_TO_CODE = {v: k for k, v in CODE_TO_TAG.items()}

#: body-op kind <-> wire tag (numeric-payload ops only; sync ops are never
#: recorded — the replayer reconstructs them from BLOCK/WAKE pairs)
_OP_TO_TAG = {
    "compute": "c",
    "stall": "st",
    "sleep": "s",
    "sleep_until": "su",
    "yield": "y",
    "checkpoint": "k",
}
_TAG_TO_OP = {v: k for k, v in _OP_TO_TAG.items()}


def encode_op(op: tuple) -> list:
    tag = _OP_TO_TAG.get(op[0])
    if tag is None:
        raise TraceSchemaError(f"unencodable op {op!r}")
    return [tag, *op[1:]]


def decode_op(arr: list) -> tuple:
    kind = _TAG_TO_OP.get(arr[0])
    if kind is None:
        raise TraceSchemaError(f"unknown op tag {arr[0]!r}")
    return (kind, *arr[1:])


def encode_record(rec: tuple) -> list:
    """(t, code, a, b) -> [tag, t, a, b]; op payloads are compacted."""
    t, code, a, b = rec
    tag = CODE_TO_TAG.get(code)
    if tag is None:
        raise TraceSchemaError(f"unknown decision code {code!r}")
    if code == REC_OP:
        b = encode_op(b)
    elif isinstance(b, tuple):
        b = list(b)
    return [tag, t, a, b]


def encode_record_json(rec: tuple) -> str:
    """One record straight to its JSONL line. Scalar-payload records —
    the hot dispatch/stop/wake stream, virtually all of a decisions-only
    trace — are formatted directly (several times cheaper than
    ``json.dumps``, which matters because the background writer encodes
    at the recording rate and competes with the traced run for the GIL);
    structured payloads fall back to ``encode_record`` + ``dumps``.
    ``repr`` of a float is its shortest exact form, which is also what
    ``json.dumps`` emits — decoded values are identical either way."""
    t, code, a, b = rec
    if type(a) is int and _isfinite(t):
        tb = type(b)
        if b is None or tb is int or (tb is float and _isfinite(b)):
            tag = CODE_TO_TAG.get(code)
            if tag is not None and code != REC_OP:
                return (f'["{tag}",{t!r},{a},'
                        f'{"null" if b is None else repr(b)}]')
    return json.dumps(encode_record(rec), separators=(",", ":"))


def decode_record(arr: list) -> tuple:
    if not isinstance(arr, list) or len(arr) != 4:
        raise TraceSchemaError(f"malformed record {arr!r}")
    tag, t, a, b = arr
    code = TAG_TO_CODE.get(tag)
    if code is None:
        raise TraceSchemaError(f"unknown record tag {tag!r}")
    if code == REC_OP:
        b = decode_op(b)
    elif isinstance(b, list):
        b = tuple(b)
    return (t, code, a, b)


def make_header(kind: str, meta: Optional[dict] = None) -> dict:
    return {
        "schema": SCHEMA_NAME,
        "version": SCHEMA_VERSION,
        "kind": kind,
        "meta": meta or {},
    }


def check_header(obj: Any) -> dict:
    if not isinstance(obj, dict):
        raise TraceSchemaError(f"trace header must be an object, got {obj!r}")
    if obj.get("schema") != SCHEMA_NAME:
        raise TraceSchemaError(
            f"not a {SCHEMA_NAME} trace (schema={obj.get('schema')!r})"
        )
    if obj.get("version") != SCHEMA_VERSION:
        raise TraceSchemaError(
            f"unsupported trace version {obj.get('version')!r} "
            f"(this reader speaks v{SCHEMA_VERSION})"
        )
    if obj.get("kind") not in (KIND_DECISIONS, KIND_WORKLOAD):
        raise TraceSchemaError(f"unknown trace kind {obj.get('kind')!r}")
    return obj


def write_trace(fh: TextIO, kind: str, lines: Iterable[list],
                meta: Optional[dict] = None) -> int:
    """Stream ``lines`` (already-encoded record arrays) to ``fh`` under a
    v1 header; returns the record count."""
    dump = json.dumps
    fh.write(dump(make_header(kind, meta), separators=(",", ":")) + "\n")
    n = 0
    for line in lines:
        fh.write(dump(line, separators=(",", ":")) + "\n")
        n += 1
    return n


def save_trace(path: str, kind: str, lines: Iterable[list],
               meta: Optional[dict] = None) -> int:
    with open(path, "w") as fh:
        return write_trace(fh, kind, lines, meta)


def iter_trace(source: Union[str, TextIO]) -> tuple[dict, Iterator[list]]:
    """Open a trace: returns (checked header, iterator of raw record
    arrays). Schema/version mismatches raise ``TraceSchemaError``."""
    fh = open(source) if isinstance(source, str) else source
    first = fh.readline()
    if not first.strip():
        raise TraceSchemaError("empty trace file")
    header = check_header(json.loads(first))

    def _lines():
        loads = json.loads
        with fh:
            for line in fh:
                if line.strip():
                    yield loads(line)

    return header, _lines()


def load_trace(source: Union[str, TextIO]) -> tuple[dict, list]:
    """Load a whole trace into memory: (header, decoded records) for a
    decisions trace, (header, raw arrays) for a workload trace."""
    header, lines = iter_trace(source)
    if header["kind"] == KIND_DECISIONS:
        return header, [decode_record(arr) for arr in lines]
    return header, list(lines)


def build_policy(desc):
    """(name, param) -> a fresh Policy instance (inverse of the recorder's
    ``_pol_desc``). ``None`` stays ``None`` (default group)."""
    if desc is None:
        return None
    name, param = desc
    from repro.core.policies import SchedCoop, SchedFair, SchedRR

    if name == "SCHED_COOP":
        return SchedCoop(**({} if param is None else {"quantum": param}))
    if name == "SCHED_FAIR":
        return SchedFair(**({} if param is None else {"slice_s": param}))
    if name == "SCHED_RR":
        return SchedRR(**({} if param is None else {"quantum": param}))
    raise TraceSchemaError(f"unknown policy {name!r}")
