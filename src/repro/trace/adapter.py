"""Cluster-trace adapter: Google/Alibaba-style task-event tables →
replayable ``Workload``.

Both public cluster traces describe tasks as *event rows* — a SUBMIT when
the task enters the cluster, a SCHEDULE when it is placed, a FINISH (or
FAIL/KILL/EVICT) when it leaves — keyed by (job id, task index). This
adapter folds such rows into per-task records and emits one ``TaskSpec``
per task: arrival = submit time, duration = finish − schedule (chunked
into compute ops at a scheduling granularity), job grouping preserved.

The reader is column-name driven (``columns`` maps logical fields to CSV
header names or 0-based indices for headerless files, as Google's
distribution ships), so the same code ingests either trace format or any
CSV shaped like them::

    wl = load_task_events("task_events.csv",
                          columns={"time": 0, "jid": 2, "tid": 3,
                                   "event": 5},
                          time_scale=1e-6)       # Google: microseconds

Tasks whose duration is unknown (no terminal event in the window, or a
truncated file) get ``default_duration``. Times are shifted so the first
submit lands at t=0.
"""

from __future__ import annotations

import csv
from typing import Iterable, Optional, Union

from repro.trace.replayer import JobSpec, TaskSpec, Workload

#: Google cluster-data v2 task_events column order (headerless CSV)
GOOGLE_COLUMNS = {"time": 0, "jid": 2, "tid": 3, "event": 5}
#: Alibaba cluster-trace-v2018 batch_task column order
ALIBABA_COLUMNS = {"tid": 0, "jid": 2, "event": 4,
                   "time": 5, "end_time": 6}

#: event-type spellings -> canonical phase
_SUBMIT = {"0", "submit", "waiting", "ready"}
_SCHEDULE = {"1", "schedule", "running"}
_FINISH = {"4", "finish", "finished", "terminated"}
_DEAD = {"2", "3", "5", "6", "evict", "fail", "failed", "kill",
         "killed", "lost", "cancelled"}


def _col(row, key):
    return row[key] if isinstance(key, int) else row.get(key)


def load_task_events(
    source: Union[str, Iterable],
    *,
    columns: Optional[dict] = None,
    time_scale: float = 1.0,
    chunk_s: float = 0.001,
    default_duration: float = 0.010,
    max_tasks: Optional[int] = None,
    has_header: Optional[bool] = None,
) -> Workload:
    """Fold a task-event CSV into a ``Workload``.

    Parameters
    ----------
    source:            path or an iterable of already-split rows.
    columns:           logical→physical column map; keys ``time``, ``jid``,
                       ``tid``, ``event`` required, ``end_time`` optional
                       (Alibaba-style one-row-per-task tables). Defaults to
                       ``GOOGLE_COLUMNS``.
    time_scale:        seconds per trace time unit (Google: 1e-6).
    chunk_s:           scheduling granularity a task's duration is chunked
                       into (each chunk is one compute op → one potential
                       scheduling point, like the serving benchmarks).
    default_duration:  seconds for tasks with no terminal event.
    max_tasks:         stop after this many distinct tasks (None = all).
    """
    cols = dict(GOOGLE_COLUMNS if columns is None else columns)
    for k in ("time", "jid", "tid", "event"):
        if k not in cols:
            raise ValueError(f"columns must map {k!r}")
    by_index = any(isinstance(v, int) for v in cols.values())

    if isinstance(source, str):
        fh = open(source, newline="")
        rows: Iterable = csv.reader(fh)
    else:
        fh = None
        rows = iter(source)

    # (jid, tid) -> [submit_t, schedule_t, end_t, dead]
    tasks: dict[tuple, list] = {}
    order: list[tuple] = []
    try:
        first = next(iter(rows), None)
        if first is None:
            raise ValueError("empty task-event table")
        header_row = None
        if has_header or (has_header is None and not by_index and
                          not isinstance(first, dict)):
            header_row = [str(c).strip() for c in first]
        rowiter = rows if header_row is not None else _chain_first(first,
                                                                   rows)
        for raw in rowiter:
            if header_row is not None and not isinstance(raw, dict):
                raw = dict(zip(header_row, raw))
            try:
                t = float(_col(raw, cols["time"])) * time_scale
                jid = str(_col(raw, cols["jid"]))
                tid = str(_col(raw, cols["tid"]))
                ev = str(_col(raw, cols["event"])).strip().lower()
            except (TypeError, ValueError, IndexError, KeyError):
                continue  # malformed row — cluster dumps have them
            key = (jid, tid)
            rec = tasks.get(key)
            if rec is None:
                if max_tasks is not None and len(tasks) >= max_tasks:
                    continue
                rec = tasks[key] = [None, None, None, False]
                order.append(key)
            if ev in _SUBMIT:
                if rec[0] is None:
                    rec[0] = t
            elif ev in _SCHEDULE:
                if rec[1] is None:
                    rec[1] = t
            elif ev in _FINISH:
                rec[2] = t
            elif ev in _DEAD:
                rec[3] = True
            end_key = cols.get("end_time")
            if end_key is not None:
                # one-row-per-task tables (Alibaba style): `time` is the
                # task's start regardless of the row's status spelling —
                # a lone "terminated" row must still yield a start time
                if rec[0] is None:
                    rec[0] = t
                try:
                    rec[2] = float(_col(raw, end_key)) * time_scale
                except (TypeError, ValueError, IndexError, KeyError):
                    pass
    finally:
        if fh is not None:
            fh.close()

    if not tasks:
        raise ValueError("no usable task events in table")

    starts = [r[0] if r[0] is not None else r[1] for r in tasks.values()]
    starts = [s for s in starts if s is not None]
    t0 = min(starts) if starts else 0.0
    jobs: dict[str, JobSpec] = {}
    specs = []
    defaulted = 0
    for i, key in enumerate(order):
        jid_s, _ = key
        submit, sched, end, dead = tasks[key]
        if dead and end is None:
            continue  # killed before running: nothing to replay
        arr = (submit if submit is not None else sched or t0) - t0
        started = sched if sched is not None else submit
        if end is not None and started is not None and end > started:
            dur = (end - started)
        else:
            dur = default_duration
            defaulted += 1
        job = jobs.get(jid_s)
        if job is None:
            job = jobs[jid_s] = JobSpec(len(jobs), f"job:{jid_s}")
        n = max(1, round(dur / chunk_s))
        ops = tuple([("compute", dur / n, 0.0)] * n)
        specs.append(TaskSpec(arr, i, job.jid, None, dur, ops))

    specs.sort(key=lambda ts: ts.t)
    return Workload(
        jobs=sorted(jobs.values(), key=lambda j: j.jid),
        tasks=specs,
        meta={"generator": "task_events", "time_scale": time_scale,
              "chunk_s": chunk_s, "n_tasks": len(specs),
              "n_jobs": len(jobs), "defaulted_durations": defaulted},
    )


def _chain_first(first, rest):
    yield first
    yield from rest
