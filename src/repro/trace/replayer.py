"""Trace replay: feed recorded or synthesized workloads through
``SimExecutor`` at hundreds-of-thousands of events per second.

Three layers:

* ``Workload`` — the replayable model: jobs, tasks (arrival time, ids,
  deadline, op list), control events (attach/demote/detach/resize/width).
* ``reconstruct`` — decision stream → Workload. Intrinsic ops (compute/
  stall/sleep/yield/checkpoint) are recorded verbatim; each *sync* block
  (lock/semaphore/barrier/cv/join/channel) appears in the stream as a
  BLOCK record not explained by a sleep op and is re-encoded as an
  absolute-time ``sleep_until`` at its recorded WAKE timestamp — replaying
  the *observed* blocking behaviour without the live sync objects.
* ``Replayer`` — builds a fresh executor and streams the workload through
  it: bodies are C-level tuple iterators over pre-decoded op tuples (no
  per-event allocation, no generator frames), job ids are pre-interned to
  ``Job`` objects, identical op lists are shared, and arrivals feed the
  heap one event at a time (``SimExecutor.feed``), keeping every heap pop
  shallow at million-task scale.

Determinism: the same workload under the same config is bit-identical —
``run(record=True)`` re-records the replay so ``decision_stream`` diffs
prove it (tids/jids are normalized back into trace id space first, since
live id counters are process-global).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Iterable, Optional

from repro.core.deadline import DeadlineArbiter
from repro.core.events import SimExecutor
from repro.core.scheduler import (
    REC_ATTACH,
    REC_BLOCK,
    REC_DEMOTE,
    REC_DETACH,
    REC_DISPATCH,
    REC_DONE,
    REC_JOB,
    REC_OP,
    REC_PREEMPT,
    REC_RESIZE,
    REC_SPAWN,
    REC_TARGET,
    REC_URGENT,
    REC_WAKE,
    REC_YIELD,
)
from repro.core.simtask import SimCosts
from repro.core.stats import SchedStats
from repro.core.task import Job, Task
from repro.core.topology import Topology
from repro.trace import schema
from repro.trace.recorder import TraceRecorder

#: codes whose (time, payload) sequence must be bit-identical between a
#: recording and its replay under the same config. OP is excluded: sync
#: ops are re-encoded as sleep_until on replay (documented approximation);
#: DL_POST/REQUEST are external-input records, re-derived only partially.
DECISION_CODES = frozenset((
    REC_SPAWN, REC_DISPATCH, REC_BLOCK, REC_YIELD, REC_DONE,
    REC_PREEMPT, REC_WAKE, REC_TARGET, REC_URGENT,
))

_SLEEP_OPS = ("sleep", "sleep_until")


@dataclasses.dataclass
class JobSpec:
    jid: int
    name: str = ""
    nice: int = 0
    share: Optional[float] = None
    policy: Optional[tuple] = None  # (name, param) or None = default group


@dataclasses.dataclass
class TaskSpec:
    t: float
    tid: int
    jid: int
    deadline: Optional[float]
    cost_hint: Optional[float]
    ops: tuple


@dataclasses.dataclass
class Workload:
    jobs: list
    tasks: list                              # sorted by arrival time
    control: list = dataclasses.field(default_factory=list)
    #                                        # (t, kind, jid_or_n, arg)
    meta: dict = dataclasses.field(default_factory=dict)

    def n_ops(self) -> int:
        return sum(len(t.ops) for t in self.tasks)

    # ------------------------------------------------------------------ #
    # (de)serialization — schema v1 "workload" kind
    # ------------------------------------------------------------------ #
    def to_lines(self) -> Iterable[list]:
        for j in self.jobs:
            yield ["J", j.jid, j.name, j.nice, j.share,
                   None if j.policy is None else list(j.policy)]
        for (t, kind, a, b) in self.control:
            yield ["C", t, kind, a, b]
        for ts in self.tasks:
            yield ["T", ts.t, ts.tid, ts.jid, ts.deadline, ts.cost_hint,
                   [schema.encode_op(op) for op in ts.ops]]

    def save(self, path: str) -> int:
        return schema.save_trace(path, schema.KIND_WORKLOAD,
                                 self.to_lines(), self.meta)

    @classmethod
    def from_lines(cls, lines: Iterable[list],
                   meta: Optional[dict] = None) -> "Workload":
        jobs, tasks, control = [], [], []
        for arr in lines:
            tag = arr[0]
            if tag == "T":
                _, t, tid, jid, dl, ch, ops = arr
                tasks.append(TaskSpec(t, tid, jid, dl, ch,
                                      tuple(schema.decode_op(o) for o in ops)))
            elif tag == "J":
                _, jid, name, nice, share, pol = arr
                jobs.append(JobSpec(jid, name, nice, share,
                                    None if pol is None else tuple(pol)))
            elif tag == "C":
                _, t, kind, a, b = arr
                control.append((t, kind, a,
                                tuple(b) if isinstance(b, list) else b))
            else:
                raise schema.TraceSchemaError(f"unknown workload tag {tag!r}")
        tasks.sort(key=lambda ts: ts.t)
        return cls(jobs=jobs, tasks=tasks, control=control,
                   meta=dict(meta or {}))

    @classmethod
    def load(cls, path: str) -> "Workload":
        header, lines = schema.iter_trace(path)
        if header["kind"] != schema.KIND_WORKLOAD:
            raise schema.TraceSchemaError(
                f"expected a workload trace, got {header['kind']!r}"
            )
        return cls.from_lines(lines, header.get("meta"))


# --------------------------------------------------------------------- #
# decision stream -> workload
# --------------------------------------------------------------------- #
def reconstruct(records: Iterable[tuple],
                meta: Optional[dict] = None) -> Workload:
    """Rebuild a replayable ``Workload`` from a recorded decision stream
    (op recording must have been armed — ``TraceRecorder.attach_sim``).

    Sync blocks become ``sleep_until`` at the recorded wake time; a block
    whose wake never came (run truncated) is dropped — the replayed task
    completes its recorded prefix. Dynamic spawns appear as top-level
    tasks at their recorded submit times.
    """
    jobs: dict[int, JobSpec] = {}
    tasks: dict[int, TaskSpec] = {}
    ops: dict[int, list] = {}
    #: per task: FIFO of outstanding blocks — True if owned by a sleep op
    pending_block: dict[int, list] = {}
    #: per task: index of the trailing sleep op already credited with a
    #: block — a sleep explains at most ONE block, so a sync block that
    #: lands right after a completed sleep (or after a re-encoded
    #: sleep_until) must not be attributed to it and silently dropped
    claimed: dict[int, int] = {}
    control: list = []

    for (t, code, a, b) in records:
        if code == REC_OP:
            lst = ops.get(a)
            if lst is not None:
                lst.append(b)
        elif code == REC_SPAWN:
            jid, deadline, cost_hint = b
            tasks[a] = TaskSpec(t, a, jid, deadline, cost_hint, ())
            ops[a] = []
            pending_block[a] = []
            if jid not in jobs:
                jobs[jid] = JobSpec(jid)
        elif code == REC_BLOCK:
            pb = pending_block.get(a)
            if pb is None:
                continue
            lst = ops[a]
            idx = len(lst) - 1
            owned_by_sleep = bool(lst) and lst[-1][0] in _SLEEP_OPS \
                and not pb and claimed.get(a) != idx
            if owned_by_sleep:
                claimed[a] = idx
            pb.append(owned_by_sleep)
        elif code == REC_WAKE:
            pb = pending_block.get(a)
            if not pb:
                continue
            if not pb.pop(0):
                # sync block: replay it as an absolute-time sleep ending
                # at this recorded wake (synthetic — it must not claim the
                # task's next block, its own already happened)
                lst = ops[a]
                lst.append(("sleep_until", t))
                claimed[a] = len(lst) - 1
        elif code == REC_JOB:
            name, nice, share = b
            spec = jobs.get(a)
            if spec is None:
                jobs[a] = JobSpec(a, name, nice, share)
            else:
                spec.name, spec.nice, spec.share = name, nice, share
        elif code == REC_ATTACH:
            share, pol = b
            jobs.setdefault(a, JobSpec(a))
            control.append((t, "attach", a, (share,
                                             None if pol is None
                                             else tuple(pol))))
        elif code == REC_DEMOTE:
            control.append((t, "demote", a, b))
        elif code == REC_DETACH:
            control.append((t, "detach", a, None))
        elif code == REC_RESIZE:
            control.append((t, "resize", a, b))
        elif code == REC_TARGET:
            control.append((t, "target", a, None))
        # DISPATCH/YIELD/DONE/PREEMPT/URGENT/DL_*/REQUEST*: decisions and
        # engine-level records — re-derived by the replay, not replayed.

    # attaches at-or-before the first arrival are initial configuration:
    # fold them into the JobSpec (the replayer attaches those eagerly).
    # Later attaches are live re-homes and stay control events — the job
    # must start in whatever group it had when the recording began.
    t0 = min((ts.t for ts in tasks.values()), default=0.0)
    kept = []
    for c in control:
        if c[1] == "attach" and c[0] <= t0:
            spec = jobs[c[2]]
            spec.share, spec.policy = c[3]
        else:
            kept.append(c)
    control = kept

    out = []
    for tid, spec in tasks.items():
        spec.ops = tuple(ops[tid])
        out.append(spec)
    out.sort(key=lambda ts: ts.t)
    return Workload(jobs=sorted(jobs.values(), key=lambda j: j.jid),
                    tasks=out, control=sorted(control, key=lambda c: c[0]),
                    meta=dict(meta or {}))


# --------------------------------------------------------------------- #
# replay
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class ReplayConfig:
    """Executor/policy configuration for one replay run (the A/B axis)."""
    slots: int = 8
    domains: int = 2
    default_policy: tuple = ("SCHED_COOP", None)
    #: "none" (share-based SlotArbiter) or "deadline" (EDF/least-laxity)
    arbiter: str = "none"
    #: jid -> (name, param) overrides on top of the workload's own attaches
    policies: dict = dataclasses.field(default_factory=dict)
    costs: Optional[SimCosts] = None
    max_time: float = 1e9
    max_events: int = 200_000_000

    def build_sim(self) -> SimExecutor:
        pol = schema.build_policy(self.default_policy)
        arb = None
        if self.arbiter == "deadline":
            arb = DeadlineArbiter(pol)
        elif self.arbiter != "none":
            raise ValueError(f"unknown arbiter {self.arbiter!r}")
        return SimExecutor(
            Topology(self.slots, self.domains),
            pol, costs=self.costs, max_time=self.max_time,
            max_events=self.max_events, arbiter=arb,
        )


@dataclasses.dataclass
class ReplayResult:
    stats: SchedStats
    events: int
    wall_s: float
    tasks: list                    # replayed Task objects (trace order)
    tid_of: dict                   # new tid -> trace tid
    jid_of: dict                   # new jid -> trace jid
    recorder: Optional[TraceRecorder]
    sim: SimExecutor

    @property
    def events_per_sec(self) -> float:
        return self.events / self.wall_s if self.wall_s > 0 else 0.0

    def normalized_records(self) -> list:
        """Re-recorded stream with tids/jids mapped into trace id space
        (for diffing against the source recording)."""
        if self.recorder is None:
            raise ValueError("replay ran without record=True")
        return normalize_stream(self.recorder.records(),
                                self.tid_of, self.jid_of)


class Replayer:
    """One replayable workload bound to one config; ``run()`` executes."""

    def __init__(self, workload: Workload,
                 config: Optional[ReplayConfig] = None):
        self.workload = workload
        self.config = config or ReplayConfig()

    def run(self, *, record: bool = False, until: Optional[float] = None,
            recorder: Optional[TraceRecorder] = None) -> ReplayResult:
        wl = self.workload
        cfg = self.config
        sim = cfg.build_sim()

        # arm before the eager attaches below: they happen at sim time 0,
        # and a re-recording must capture them so reconstructing the
        # replay folds them back into the JobSpecs (fixed point)
        rec = recorder
        if record and rec is None:
            rec = TraceRecorder()
        if rec is not None:
            rec.attach_sim(sim, ops=True)

        # pre-intern jobs (trace jid -> live Job) and attach leases
        jid_of: dict[int, int] = {}
        job_of: dict[int, Job] = {}
        for spec in wl.jobs:
            job = Job(spec.name or f"job{spec.jid}", nice=spec.nice,
                      share=spec.share)
            job_of[spec.jid] = job
            jid_of[job.jid] = spec.jid
            pol = cfg.policies.get(spec.jid, spec.policy)
            if pol is not None:
                # dedicated group; default-group jobs register lazily on
                # first submit (their share rides on the Job itself), the
                # same path the recorded run took
                sim.attach(job, policy=schema.build_policy(pol),
                           share=spec.share)

        # batch-decode tasks: shared op tuples -> C-level tuple-iterator
        # bodies, one Task per spec, arrivals streamed (not pre-posted)
        interned: dict = {}
        tasks = []
        tid_of: dict[int, int] = {}
        for ts in wl.tasks:
            body = interned.get(ts.ops)
            if body is None:
                body = interned[ts.ops] = functools.partial(iter, ts.ops)
            task = Task(job_of[ts.jid], body=body, deadline=ts.deadline,
                        cost_hint=ts.cost_hint or 0.0)
            tid_of[task.tid] = ts.tid
            tasks.append(task)

        for (t, kind, a, b) in wl.control:
            self._post_control(sim, job_of, t, kind, a, b)

        arrivals = iter([(ts.t, task)
                         for ts, task in zip(wl.tasks, tasks)])
        sim.feed(arrivals)
        t0 = time.perf_counter()
        stats = sim.run(until=until)
        wall = time.perf_counter() - t0
        if rec is not None:
            rec.detach_all()
        return ReplayResult(stats=stats, events=sim.events_processed,
                            wall_s=wall, tasks=tasks, tid_of=tid_of,
                            jid_of=jid_of, recorder=rec, sim=sim)

    @staticmethod
    def _post_control(sim: SimExecutor, job_of: dict, t: float,
                      kind: str, a, b) -> None:
        if kind == "attach":
            share, pol = b
            sim._post(t, lambda: sim.attach(
                job_of[a], policy=schema.build_policy(pol), share=share))
        elif kind == "demote":
            sim._post(t, lambda: sim.demote(job_of[a], share=b))
        elif kind == "detach":
            sim._post(t, lambda: sim.detach(job_of[a]))
        elif kind == "resize":
            sim._post(t, lambda: job_of[a].lease.resize(b))
        elif kind == "target":
            sim._post(t, lambda: sim.set_slot_target(a))
        else:
            raise schema.TraceSchemaError(f"unknown control {kind!r}")


# --------------------------------------------------------------------- #
# determinism diffing
# --------------------------------------------------------------------- #
def normalize_stream(records: Iterable[tuple], tid_of: dict,
                     jid_of: dict) -> list:
    """Map a re-recorded stream's process-global tids/jids back into the
    id space of the source trace so streams are directly comparable."""
    out = []
    for (t, code, a, b) in records:
        if code in (REC_OP, REC_DISPATCH, REC_BLOCK, REC_YIELD, REC_DONE,
                    REC_PREEMPT, REC_WAKE):
            a = tid_of.get(a, a)
        elif code == REC_SPAWN:
            a = tid_of.get(a, a)
            b = (jid_of.get(b[0], b[0]),) + tuple(b[1:])
        elif code in (REC_JOB, REC_ATTACH, REC_DEMOTE, REC_DETACH,
                      REC_RESIZE):
            a = jid_of.get(a, a)
        elif code == REC_URGENT:
            if b is not None:
                b = tid_of.get(b, b)
        out.append((t, code, a, b))
    return out


def decision_stream(records: Iterable[tuple]) -> list:
    """The bit-identity subset: scheduling decisions only."""
    return [r for r in records if r[1] in DECISION_CODES]


def diff_streams(a: Iterable[tuple], b: Iterable[tuple]) -> Optional[dict]:
    """First divergence between two decision streams (None = identical).
    Compares the DECISION_CODES subset, payloads and timestamps bit-for-
    bit (floats must round-trip exactly — they do through both memory
    and the JSONL encoding)."""
    da, db = decision_stream(a), decision_stream(b)
    for i, (ra, rb) in enumerate(zip(da, db)):
        if ra != rb:
            return {"index": i, "a": ra, "b": rb}
    if len(da) != len(db):
        n = min(len(da), len(db))
        return {"index": n,
                "a": da[n] if len(da) > n else None,
                "b": db[n] if len(db) > n else None}
    return None
