"""Cluster-scale trace record/replay (the simulation substrate).

Record every scheduling decision from a live ``SimExecutor`` /
``UsfRuntime`` run to a versioned JSONL trace; replay recorded or
synthesized traces through the discrete-event engine at
hundreds-of-thousands of events per second; A/B one trace under two
arbiter/policy configurations.

Layers:

* ``schema``   — versioned JSONL encode/decode (decision + workload records)
* ``recorder`` — arm points, ring buffer, background flush
* ``replayer`` — workload model, decision→workload reconstruction, replay
* ``synth``    — arrival generators (Poisson/burst/diurnal), perturbations
* ``adapter``  — Google/Alibaba-style task-event CSV → workload
* ``ab``       — policy A/B runner + replayed SLO sweep
"""

from repro.trace.recorder import TraceRecorder
from repro.trace.replayer import (
    ReplayConfig,
    Replayer,
    Workload,
    decision_stream,
    diff_streams,
    reconstruct,
)
from repro.trace.schema import (
    SCHEMA_NAME,
    SCHEMA_VERSION,
    TraceSchemaError,
    load_trace,
    save_trace,
)

__all__ = [
    "TraceRecorder",
    "ReplayConfig",
    "Replayer",
    "Workload",
    "decision_stream",
    "diff_streams",
    "reconstruct",
    "SCHEMA_NAME",
    "SCHEMA_VERSION",
    "TraceSchemaError",
    "load_trace",
    "save_trace",
]
