"""Decision/event recorder: near-zero overhead disarmed, ring-buffered +
background-flushed when armed.

Disarmed cost by design:

* ``Scheduler`` hot paths pay exactly one predicate check
  (``self._rec is None``) per decision.
* ``SimExecutor`` op recording costs *nothing* disarmed — arming swaps
  ``_advance`` for its recording twin, so the plain advance loop carries
  no check at all (benchmarks/trace_replay.py measures the interleaved
  A/B at ~1.0x).

Armed, ``emit`` takes the one pre-built ``(t, code, a, b)`` tuple the hot
path hands it and appends it to a deque — ``emit`` IS ``deque.append``
(a C call, no Python frame at all), so the armed hot-path cost is one
tuple allocation + one C-level append per record in BOTH modes. With a
``path``, a daemon writer thread polls the ring on a short interval and
drains it in batches behind the run, streaming schema-encoded JSONL —
the producer never pays a ring-occupancy check, and drained records are
freed promptly so the allocator recycles them. Records are never
dropped — determinism diffs need the exact stream — so a producer
outrunning the disk grows the ring until the next poll instead of
losing records.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Optional

from repro.trace import schema


class TraceRecorder:
    """Collects decision records from armed schedulers/executors.

    Parameters
    ----------
    path:       JSONL destination; ``None`` records in memory only.
    flush_at:   records per JSONL write batch in the background writer.
    poll_s:     background-writer drain interval (bounds ring occupancy
                at roughly ``producer rate x poll_s`` records).
    meta:       free-form dict stored in the trace header.
    """

    def __init__(self, path: Optional[str] = None, *,
                 flush_at: int = 8192, poll_s: float = 0.05,
                 meta: Optional[dict] = None):
        self.path = path
        self.meta = dict(meta or {})
        self._ring: deque = deque()
        self._flush_at = flush_at
        self._poll_s = poll_s
        self.emitted = 0
        self._armed: list = []  # (kind, target) pairs for detach_all
        self._fh = None
        self._writer: Optional[threading.Thread] = None
        self._wake = threading.Event()
        self._closing = False
        # `emit` takes ONE pre-built record tuple and IS the ring deque's
        # C-level append — no Python frame, no occupancy check, in either
        # mode. The file-mode writer drains by polling (`poll_s`), so the
        # producer's cost never depends on ring state.
        self.emit = self._ring.append
        if path is not None:
            self._fh = open(path, "w")
            self._fh.write(__import__("json").dumps(
                schema.make_header(schema.KIND_DECISIONS, self.meta),
                separators=(",", ":")) + "\n")
            self._writer = threading.Thread(target=self._drain_loop,
                                            name="trace-writer", daemon=True)
            self._writer.start()

    # ------------------------------------------------------------------ #
    # arm / disarm
    # ------------------------------------------------------------------ #
    def attach_sim(self, sim, *, ops: bool = True) -> "TraceRecorder":
        """Arm a ``SimExecutor``: decision hooks on its scheduler and —
        with ``ops`` — the intrinsic-op recording twin on the engine
        (required for a replayable recording; decisions-only is enough
        for monitoring). Arm before ``run``."""
        sim.sched._rec = self.emit
        if ops:
            sim._set_op_recorder(self.emit)
        self._armed.append(("sim", sim))
        return self

    def attach_runtime(self, rt) -> "TraceRecorder":
        """Arm a live ``UsfRuntime`` (decision records; real-thread bodies
        are opaque, so op recording does not apply)."""
        rt.set_recorder(self.emit)
        self._armed.append(("runtime", rt))
        return self

    def attach_sched(self, sched) -> "TraceRecorder":
        sched._rec = self.emit
        self._armed.append(("sched", sched))
        return self

    def detach_all(self) -> None:
        for kind, target in self._armed:
            if kind == "sim":
                target.sched._rec = None
                target._set_op_recorder(None)
            elif kind == "runtime":
                target.set_recorder(None)
            else:
                target._rec = None
        self._armed.clear()

    # ------------------------------------------------------------------ #
    # results
    # ------------------------------------------------------------------ #
    def records(self) -> list:
        """The in-memory stream (order preserved). With a ``path`` this is
        only the not-yet-flushed tail — use the file for the full trace."""
        return list(self._ring)

    def close(self) -> "TraceRecorder":
        """Detach everything and flush/close the file (if any)."""
        self.detach_all()
        if self._writer is not None:
            self._closing = True
            self._wake.set()
            self._writer.join()
            self._writer = None
        if self._fh is not None:
            self._flush_ring()
            self._fh.close()
            self._fh = None
        return self

    def __enter__(self) -> "TraceRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ #
    # background writer
    # ------------------------------------------------------------------ #
    def _drain_loop(self) -> None:
        while True:
            self._wake.wait(timeout=self._poll_s)
            self._wake.clear()
            self._flush_ring()
            if self._closing:
                return

    def _flush_ring(self) -> None:
        ring = self._ring
        fh = self._fh
        if fh is None:
            return
        encode = schema.encode_record_json
        popleft = ring.popleft
        out = []
        while ring:
            try:
                out.append(encode(popleft()))
            except IndexError:  # pragma: no cover - producer raced us
                break
            if len(out) >= self._flush_at:
                fh.write("\n".join(out) + "\n")
                self.emitted += len(out)
                out = []
        if out:
            fh.write("\n".join(out) + "\n")
            self.emitted += len(out)
