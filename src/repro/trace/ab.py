"""Policy A/B over one trace: replay the SAME workload under two
arbiter/policy configurations and diff the outcomes.

The workload fixes everything stochastic — arrivals, service demands,
deadlines, class mix — so the config under test is the ONLY independent
variable, the property the live benchmarks approximate with shared seeds
and the replayer gets by construction.

``run_ab`` returns raw per-side metrics (latency lists, miss/preempt
counts, makespan); percentile summarization/formatting lives in
``benchmarks/trace_replay.py`` (``src`` never imports ``benchmarks``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.trace.replayer import ReplayConfig, Replayer, ReplayResult, Workload


@dataclasses.dataclass
class SideMetrics:
    """One config's replay outcome, raw (no percentile math here)."""
    name: str
    config: ReplayConfig
    result: ReplayResult
    makespan: float
    latencies: list            # completed deadline-carrying tasks
    misses: int
    deadline_tasks: int
    completed: int
    preemptions: int
    urgent_grants: int
    events: int
    wall_s: float

    @property
    def miss_rate(self) -> float:
        return self.misses / self.deadline_tasks if self.deadline_tasks \
            else 0.0


def measure_side(name: str, workload: Workload, config: ReplayConfig,
                 *, until: Optional[float] = None) -> SideMetrics:
    """Replay ``workload`` under ``config`` and collect raw metrics.

    Latency of a deadline-carrying task = finish − arrival (the serving
    benchmarks' definition: the spec's arrival time is the request's
    arrival, the task's finish is the response)."""
    res = Replayer(workload, config).run(until=until)
    arrival_of = {}
    for spec, task in zip(workload.tasks, res.tasks):
        arrival_of[task.tid] = spec
    lats = []
    misses = 0
    deadline_tasks = 0
    completed = 0
    makespan = 0.0
    preemptions = 0
    for task in res.tasks:
        st = task.stats
        preemptions += st.preemptions
        fin = st.done_at
        if fin is None:
            continue
        completed += 1
        if fin > makespan:
            makespan = fin
        spec = arrival_of[task.tid]
        if spec.deadline is not None:
            deadline_tasks += 1
            lats.append(fin - spec.t)
            if fin > spec.deadline:
                misses += 1
    arb = res.sim.sched.arbiter
    return SideMetrics(
        name=name, config=config, result=res, makespan=makespan,
        latencies=lats, misses=misses, deadline_tasks=deadline_tasks,
        completed=completed, preemptions=preemptions,
        urgent_grants=getattr(arb, "urgent_grants", 0),
        events=res.events, wall_s=res.wall_s,
    )


def run_ab(workload: Workload, config_a: ReplayConfig,
           config_b: ReplayConfig, *, name_a: str = "a", name_b: str = "b",
           until: Optional[float] = None) -> dict:
    """Replay one workload under two configs; returns both sides plus the
    structural comparison (who won what, by how much)."""
    a = measure_side(name_a, workload, config_a, until=until)
    b = measure_side(name_b, workload, config_b, until=until)
    return {"a": a, "b": b, "comparison": compare_sides(a, b)}


def compare_sides(a: SideMetrics, b: SideMetrics) -> dict:
    def _ratio(x, y):
        return round(x / y, 4) if y else None

    return {
        "makespan_ratio": _ratio(a.makespan, b.makespan),
        "miss_rate": {a.name: round(a.miss_rate, 5),
                      b.name: round(b.miss_rate, 5)},
        "completed": {a.name: a.completed, b.name: b.completed},
        "preemptions": {a.name: a.preemptions, b.name: b.preemptions},
        "urgent_grants": {a.name: a.urgent_grants, b.name: b.urgent_grants},
        "events": {a.name: a.events, b.name: b.events},
    }


def slo_ab_configs(*, slots: int = 8, domains: int = 2) -> tuple:
    """The PR 7 SLO pair as replay configs: deadline-aware arbitration vs
    share-only, everything else identical (SCHED_FAIR 3 ms default)."""
    base = dict(slots=slots, domains=domains,
                default_policy=("SCHED_FAIR", 0.003), max_time=1e9)
    return (ReplayConfig(arbiter="deadline", **base),
            ReplayConfig(arbiter="none", **base))
