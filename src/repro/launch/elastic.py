"""Elastic rescale: continue a run on a different mesh (node failures or
reclaimed capacity) — the job-level generalization of cooperative yield.

The checkpoint format is mesh-agnostic (host arrays); rescaling =
restore with the NEW mesh's shardings + re-lower the step. The dry-run
demonstration compiles the same arch on (16,16) and on a degraded (8,16)
mesh (128 survivors) and proves both lower+compile with the same
checkpointed state tree.

Device reclaim and slot reclaim share one path: each mesh transition is
emitted as a ``repro.launch.rescale.MeshRescaleEvent``, and an optional
``ElasticCoordinator`` applies it to registered jobs' slot leases
(``SlotLease.resize``) — a job that loses half its devices surrenders the
matching fraction of its CPU-side slot share to co-located siblings.

Usage:
    REPRO_DRYRUN_DEVICES=512 PYTHONPATH=src \
        python -m repro.launch.elastic --arch smollm_360m
"""

import os

_DEV = os.environ.get("REPRO_DRYRUN_DEVICES", "512")
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={_DEV}"
).strip()

import argparse

import jax

from repro.configs.base import SHAPES, get_arch, list_archs
from repro.launch.dryrun import _compile, _memory
from repro.launch.mesh import make_mesh


def elastic_demo(arch_id: str, shape_name: str = "train_4k",
                 verbose: bool = True, coordinator=None) -> dict:
    """Compile on the full and degraded meshes; with a ``coordinator``
    (``repro.launch.rescale.ElasticCoordinator``) every mesh transition is
    also applied to the registered jobs' slot leases, so the scheduler-side
    share shrinks in step with the device-side capacity."""
    from repro.launch.rescale import MeshRescaleEvent

    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    results = {}
    prev_shape = None
    for name, mesh_shape in (("full_16x16", (16, 16)),
                             ("degraded_8x16", (8, 16))):
        mesh = make_mesh(mesh_shape, ("data", "model"))
        compiled, times = _compile(cfg, shape, mesh, microbatches=8)
        mem = _memory(compiled)
        results[name] = {"compile_s": times["compile_s"], "memory": mem}
        if verbose:
            print(f"[elastic] {arch_id} {shape_name} on {name}: "
                  f"compile {times['compile_s']}s, "
                  f"peak {mem['peak_bytes_est']/2**30:.2f} GiB/chip")
        if coordinator is not None and prev_shape is not None:
            event = MeshRescaleEvent(prev_shape, mesh_shape)
            shares = coordinator.on_rescale(event)
            results[name]["lease_shares"] = shares
            if verbose:
                print(f"[elastic] rescale {event.old_devices}->"
                      f"{event.new_devices} devices: slot leases resized "
                      f"to {shares}")
        prev_shape = mesh_shape
    return results


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m", choices=list_archs())
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    args = ap.parse_args()
    elastic_demo(args.arch, args.shape)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
