"""Mesh rescale → slot-lease resize: one elastic path for devices & slots.

``repro.launch.elastic`` demonstrates device-level elasticity: a run
continues on a degraded mesh after losing capacity. The job-level
``SlotArbiter`` exposes the same elastic primitive for *slots*
(``SlotLease.resize``). This module wires the two together so device
reclaim and slot reclaim share one path: a ``MeshRescaleEvent`` (devices
lost or regained) is applied proportionally to the job's slot lease — a
job that just lost half its mesh also surrenders half its CPU-side slot
share to its co-located siblings, and regains it when the mesh regrows.

Reclaim semantics are the lease's: grants fill idle slots immediately;
reclaims land at the borrower's next scheduling point, or within one
watchdog/sim tick period for preemptive intra-job policies (SCHED_COOP
jobs are never preempted for reclaim — I2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Iterable

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.arbiter import SlotLease


@dataclasses.dataclass(frozen=True)
class MeshRescaleEvent:
    """A mesh shape change (node failure, capacity reclaim, or regrowth)."""

    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]

    @property
    def old_devices(self) -> int:
        return math.prod(self.old_shape)

    @property
    def new_devices(self) -> int:
        return math.prod(self.new_shape)

    @property
    def scale(self) -> float:
        """Surviving-device fraction (may exceed 1.0 on regrowth)."""
        if self.old_devices <= 0:
            raise ValueError(f"empty source mesh {self.old_shape}")
        return self.new_devices / self.old_devices


def apply_rescale(lease: "SlotLease", event: MeshRescaleEvent) -> float:
    """Resize ``lease`` in proportion to the event's device change; returns
    the new share. The arbiter re-apportions quotas under its scheduler's
    lock, so this is safe to call from a rescale-monitoring thread."""
    new_share = lease.share * event.scale
    lease.resize(new_share)
    return new_share


class ElasticCoordinator:
    """Fans one mesh-rescale event out to every registered job lease.

    The launch layer (``repro.launch.elastic``) owns mesh transitions; the
    scheduling layer owns slot leases. The coordinator is the seam between
    them: ``register`` the leases of jobs whose slot share should track
    their device share, then call ``on_rescale`` whenever the mesh changes.

    ``runtime`` (a ``SimExecutor`` or ``UsfRuntime`` — anything exposing
    ``demote(job)``) enables ``demote_on_collapse`` registrations: a job
    whose mesh shrinks to zero devices is *live-demoted* into the shared
    default group instead of being left holding a dedicated zero-share
    lease — the rescale-driven policy swap without drain. The demoted
    job leaves elastic tracking (its dedicated lease is gone); re-promote
    it with a fresh ``attach`` + ``register`` once its mesh regrows.
    """

    def __init__(self, runtime=None) -> None:
        self._runtime = runtime
        self._leases: list["SlotLease"] = []
        #: opt-in keyed by LEASE identity, not jid: a stale registration's
        #: flag must die with it, never eclipsing (or erasing) the flag of
        #: a newer live registration for the same job
        self._demote_on_collapse: set["SlotLease"] = set()

    def register(self, lease: "SlotLease", *,
                 demote_on_collapse: bool = False) -> "SlotLease":
        if demote_on_collapse and self._runtime is None:
            raise ValueError(
                "demote_on_collapse needs a runtime exposing demote(job); "
                "pass it to ElasticCoordinator(runtime=...)"
            )
        if demote_on_collapse and not lease.group.dedicated:
            raise ValueError(
                f"demote_on_collapse needs a dedicated lease; {lease.job} "
                "already runs in the default group (nothing to demote)"
            )
        if lease not in self._leases:  # re-register only updates the flag:
            self._leases.append(lease)  # a duplicate would resize twice
        if demote_on_collapse:
            self._demote_on_collapse.add(lease)
        else:
            # re-registering the same lease without the flag revokes its
            # opt-in; a FRESH lease simply never carries the old one's
            self._demote_on_collapse.discard(lease)
        return lease

    def leases(self) -> Iterable["SlotLease"]:
        return tuple(self._leases)

    def on_rescale(self, event: MeshRescaleEvent) -> dict[str, float]:
        """Apply the event to every registered lease; returns the new
        shares keyed by job name (0.0 for a job demoted on collapse —
        its dedicated share is released wholesale)."""
        shares: dict[str, float] = {}
        survivors: list["SlotLease"] = []
        for lease in self._leases:
            if lease.job.lease is not lease:
                # superseded out-of-band (a live swap/demote/detach the
                # coordinator did not perform): the registration is dead —
                # drop it (and only ITS flag) rather than resize a lease
                # no quota reads; the job's new lease needs a fresh
                # register()
                self._demote_on_collapse.discard(lease)
                continue
            if (event.new_devices == 0
                    and lease in self._demote_on_collapse):
                self._runtime.demote(lease.job)
                self._demote_on_collapse.discard(lease)
                shares[lease.job.name] = 0.0
                continue  # the dedicated lease is dead: stop tracking it
            shares[lease.job.name] = apply_rescale(lease, event)
            survivors.append(lease)
        self._leases = survivors
        return shares
