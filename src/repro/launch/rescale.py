"""Mesh rescale → slot-lease resize: one elastic path for devices & slots.

``repro.launch.elastic`` demonstrates device-level elasticity: a run
continues on a degraded mesh after losing capacity. The job-level
``SlotArbiter`` exposes the same elastic primitive for *slots*
(``SlotLease.resize``). This module wires the two together so device
reclaim and slot reclaim share one path: a ``MeshRescaleEvent`` (devices
lost or regained) is applied proportionally to the job's slot lease — a
job that just lost half its mesh also surrenders half its CPU-side slot
share to its co-located siblings, and regains it when the mesh regrows.

Reclaim semantics are the lease's: grants fill idle slots immediately;
reclaims land at the borrower's next scheduling point, or within one
watchdog/sim tick period for preemptive intra-job policies (SCHED_COOP
jobs are never preempted for reclaim — I2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Iterable

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.arbiter import SlotLease


@dataclasses.dataclass(frozen=True)
class MeshRescaleEvent:
    """A mesh shape change (node failure, capacity reclaim, or regrowth)."""

    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]

    @property
    def old_devices(self) -> int:
        return math.prod(self.old_shape)

    @property
    def new_devices(self) -> int:
        return math.prod(self.new_shape)

    @property
    def scale(self) -> float:
        """Surviving-device fraction (may exceed 1.0 on regrowth)."""
        if self.old_devices <= 0:
            raise ValueError(f"empty source mesh {self.old_shape}")
        return self.new_devices / self.old_devices


def apply_rescale(lease: "SlotLease", event: MeshRescaleEvent) -> float:
    """Resize ``lease`` in proportion to the event's device change; returns
    the new share. The arbiter re-apportions quotas under its scheduler's
    lock, so this is safe to call from a rescale-monitoring thread."""
    new_share = lease.share * event.scale
    lease.resize(new_share)
    return new_share


class ElasticCoordinator:
    """Fans one mesh-rescale event out to every registered job lease.

    The launch layer (``repro.launch.elastic``) owns mesh transitions; the
    scheduling layer owns slot leases. The coordinator is the seam between
    them: ``register`` the leases of jobs whose slot share should track
    their device share, then call ``on_rescale`` whenever the mesh changes.
    """

    def __init__(self) -> None:
        self._leases: list["SlotLease"] = []

    def register(self, lease: "SlotLease") -> "SlotLease":
        self._leases.append(lease)
        return lease

    def leases(self) -> Iterable["SlotLease"]:
        return tuple(self._leases)

    def on_rescale(self, event: MeshRescaleEvent) -> dict[str, float]:
        """Apply the event to every registered lease; returns the new
        shares keyed by job name."""
        return {l.job.name: apply_rescale(l, event) for l in self._leases}
