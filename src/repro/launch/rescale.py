"""Mesh rescale → slot-lease resize: one elastic path for devices & slots.

``repro.launch.elastic`` demonstrates device-level elasticity: a run
continues on a degraded mesh after losing capacity. The job-level
``SlotArbiter`` exposes the same elastic primitive for *slots*
(``SlotLease.resize``). This module wires the two together so device
reclaim and slot reclaim share one path: a ``MeshRescaleEvent`` (devices
lost or regained) is applied proportionally to the job's slot lease — a
job that just lost half its mesh also surrenders half its CPU-side slot
share to its co-located siblings, and regains it when the mesh regrows.

Reclaim semantics are the lease's: grants fill idle slots immediately;
reclaims land at the borrower's next scheduling point, or within one
watchdog/sim tick period for preemptive intra-job policies (SCHED_COOP
jobs are never preempted for reclaim — I2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Iterable, Optional

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.arbiter import SlotLease


@dataclasses.dataclass(frozen=True)
class MeshRescaleEvent:
    """A mesh shape change (node failure, capacity reclaim, or regrowth)."""

    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]

    @property
    def old_devices(self) -> int:
        return math.prod(self.old_shape)

    @property
    def new_devices(self) -> int:
        return math.prod(self.new_shape)

    @property
    def scale(self) -> float:
        """Surviving-device fraction (may exceed 1.0 on regrowth)."""
        if self.old_devices <= 0:
            raise ValueError(f"empty source mesh {self.old_shape}")
        return self.new_devices / self.old_devices


def apply_rescale(lease: "SlotLease", event: MeshRescaleEvent) -> float:
    """Resize ``lease`` in proportion to the event's device change; returns
    the new share. The arbiter re-apportions quotas under its scheduler's
    lock, so this is safe to call from a rescale-monitoring thread."""
    new_share = lease.share * event.scale
    lease.resize(new_share)
    return new_share


class ElasticCoordinator:
    """Fans one mesh-rescale event out to every registered job lease.

    The launch layer (``repro.launch.elastic``) owns mesh transitions; the
    scheduling layer owns slot leases. The coordinator is the seam between
    them: ``register`` the leases of jobs whose slot share should track
    their device share, then call ``on_rescale`` whenever the mesh changes.

    ``runtime`` (a ``SimExecutor`` or ``UsfRuntime`` — anything exposing
    ``demote(job)``) enables ``demote_on_collapse`` registrations: a job
    whose mesh shrinks to zero devices is *live-demoted* into the shared
    default group instead of being left holding a dedicated zero-share
    lease — the rescale-driven policy swap without drain. With a
    ``policy_factory`` the round-trip closes automatically: the demoted
    job is **re-promoted** — a fresh dedicated lease under a fresh policy
    instance from the factory — on the first event that regrows its mesh
    to more than zero devices, at a share scaled by the regrown fraction.
    Without a factory the demoted job leaves elastic tracking (the PR 4
    behaviour); re-promote it manually with ``attach`` + ``register``.

    ``broker`` (a ``repro.ipc.BrokerClient``, or anything exposing
    ``rescale(scale)``) routes every event to the *node-level* lease too:
    the process that lost half its devices also surrenders half its node
    slot share to co-located sibling processes — cross-process reclaim
    riding the same event stream as the in-process leases.
    """

    def __init__(self, runtime=None, broker=None) -> None:
        self._runtime = runtime
        self._broker = broker
        self._leases: list["SlotLease"] = []
        #: opt-in keyed by LEASE identity, not jid: a stale registration's
        #: flag must die with it, never eclipsing (or erasing) the flag of
        #: a newer live registration for the same job
        self._demote_on_collapse: set["SlotLease"] = set()
        #: lease -> zero-arg Policy factory for auto re-promotion
        self._policy_factories: dict[int, object] = {}  # id(lease) -> factory
        #: jid -> (job, factory, share-at-collapse, devices-at-collapse):
        #: jobs demoted by a collapse, waiting for their mesh to regrow
        self._collapsed: dict[int, tuple] = {}
        #: (node share, devices) before a collapse zeroed the broker
        #: lease — a multiplicative rescale cannot recover from 0, so the
        #: regrow restores the share absolutely via ``broker.resize``
        self._broker_collapsed: Optional[tuple] = None

    def register(self, lease: "SlotLease", *,
                 demote_on_collapse: bool = False,
                 policy_factory=None) -> "SlotLease":
        if demote_on_collapse and self._runtime is None:
            raise ValueError(
                "demote_on_collapse needs a runtime exposing demote(job); "
                "pass it to ElasticCoordinator(runtime=...)"
            )
        if demote_on_collapse and not lease.group.dedicated:
            raise ValueError(
                f"demote_on_collapse needs a dedicated lease; {lease.job} "
                "already runs in the default group (nothing to demote)"
            )
        if policy_factory is not None and not demote_on_collapse:
            raise ValueError(
                "policy_factory only makes sense with demote_on_collapse "
                "(it rebuilds the dedicated policy at re-promotion)"
            )
        if lease not in self._leases:  # re-register only updates the flag:
            self._leases.append(lease)  # a duplicate would resize twice
        if demote_on_collapse:
            self._demote_on_collapse.add(lease)
            if policy_factory is not None:
                self._policy_factories[id(lease)] = policy_factory
            else:
                self._policy_factories.pop(id(lease), None)
        else:
            # re-registering the same lease without the flag revokes its
            # opt-in; a FRESH lease simply never carries the old one's
            self._demote_on_collapse.discard(lease)
            self._policy_factories.pop(id(lease), None)
        return lease

    def leases(self) -> Iterable["SlotLease"]:
        return tuple(self._leases)

    def on_rescale(self, event: MeshRescaleEvent) -> dict[str, float]:
        """Apply the event to every registered lease; returns the new
        shares keyed by job name (0.0 for a job demoted on collapse —
        its dedicated share is released wholesale). Regrowth events
        (new_devices > 0) first re-promote any collapse-demoted job that
        registered a ``policy_factory``; the event is also routed to the
        node-level broker lease when one is wired in."""
        shares: dict[str, float] = {}
        fresh: list["SlotLease"] = []
        if event.new_devices > 0 and self._collapsed:
            repromoted, fresh = self._repromote(event)
            shares.update(repromoted)
        survivors: list["SlotLease"] = []
        for lease in self._leases:
            if any(lease is f for f in fresh):
                # re-promoted by THIS event: its share already reflects the
                # regrown mesh — applying the event again would square it
                survivors.append(lease)
                continue
            if lease.job.lease is not lease:
                # superseded out-of-band (a live swap/demote/detach the
                # coordinator did not perform): the registration is dead —
                # drop it (and only ITS flag) rather than resize a lease
                # no quota reads; the job's new lease needs a fresh
                # register()
                self._demote_on_collapse.discard(lease)
                self._policy_factories.pop(id(lease), None)
                continue
            if (event.new_devices == 0
                    and lease in self._demote_on_collapse):
                factory = self._policy_factories.pop(id(lease), None)
                pre_share = lease.share
                self._runtime.demote(lease.job)
                self._demote_on_collapse.discard(lease)
                shares[lease.job.name] = 0.0
                if factory is not None:
                    # remember enough to re-promote when the mesh regrows:
                    # the share scales by regrown/pre-collapse devices
                    self._collapsed[lease.job.jid] = (
                        lease.job, factory, pre_share, event.old_devices)
                continue  # the dedicated lease is dead: stop tracking it
            if event.old_devices == 0:
                # a regrow-from-nothing defines no ratio for jobs that
                # were never collapsed: their shares are left untouched
                # (the event only feeds the re-promotion pass above)
                shares[lease.job.name] = lease.share
                survivors.append(lease)
                continue
            shares[lease.job.name] = apply_rescale(lease, event)
            survivors.append(lease)
        self._leases = survivors
        if self._broker is not None:
            # cross-process reclaim: the node-level share tracks the same
            # device fraction the in-process leases just applied
            if event.new_devices == 0 and event.old_devices > 0:
                # collapse: remember the pre-zero node share — 0 times
                # any later scale stays 0, so the regrow must restore
                # absolutely, not multiplicatively
                self._broker_collapsed = (self._broker.share,
                                          event.old_devices)
                self._broker.rescale(0.0)
            elif event.old_devices == 0:
                if self._broker_collapsed is not None:
                    share0, dev0 = self._broker_collapsed
                    self._broker_collapsed = None
                    self._broker.resize(
                        share0 * event.new_devices / dev0)
            else:
                self._broker.rescale(event.scale)
        return shares

    def _repromote(self, event: MeshRescaleEvent
                   ) -> tuple[dict[str, float], list]:
        """Close the collapse round-trip: re-attach every recorded
        collapse-demoted job under a fresh dedicated policy, at the
        pre-collapse share scaled by the regrown device fraction, and
        re-register it (flag and factory intact) so later events keep
        tracking it."""
        shares: dict[str, float] = {}
        fresh: list["SlotLease"] = []
        for jid, (job, factory, pre_share, pre_devices) in list(
                self._collapsed.items()):
            del self._collapsed[jid]
            cur = job.lease
            if cur is not None and cur.group.dedicated:
                # re-promoted out-of-band (a manual attach): leave the
                # manual registration — if any — in charge
                continue
            new_share = pre_share * (event.new_devices / pre_devices
                                     if pre_devices > 0 else 1.0)
            lease = self._runtime.attach(job, policy=factory(),
                                         share=new_share)
            self.register(lease, demote_on_collapse=True,
                          policy_factory=factory)
            shares[job.name] = new_share
            fresh.append(lease)
        return shares, fresh
