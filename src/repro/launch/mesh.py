"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state. The dry-run entry point sets
``--xla_force_host_platform_device_count=512`` before any jax import.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
pure data parallelism (gradient all-reduce only — DCN-tolerant).
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` exists from jax 0.5; on older jax (e.g. the 0.4.x
    pinned in CI images) meshes are Auto-typed by default, so omit it."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny meshes for fast local iteration (8/16 fake devices)."""
    if multi_pod:
        return make_mesh((2, 2, 4), ("pod", "data", "model"))
    return make_mesh((2, 4), ("data", "model"))
