"""Model-input construction: ShapeDtypeStruct stand-ins for the dry-run
(weak-type-correct, shardable, no device allocation) and real synthetic
arrays for smoke tests / examples.

Batch layout per shape kind:
  train:   {tokens|embeds, positions, labels}
  prefill: {tokens|embeds, positions}
  decode:  (cache_tree, tokens [B] | embeds [B,1,Fd], positions [B]|[3,B])
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import abstract_tree, init_tree
from repro.models.registry import build_model


def _pos_specs(cfg, B: int, S: int):
    if cfg.mrope_sections is not None:
        return jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    return jax.ShapeDtypeStruct((B, S), jnp.int32)


def batch_specs(cfg, B: int, S: int, *, with_labels: bool) -> dict:
    specs: dict[str, Any] = {}
    if cfg.frontend == "token":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        d_in = cfg.frontend_dim or cfg.d_model
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, d_in),
                                               jnp.dtype(cfg.compute_dtype))
    specs["positions"] = _pos_specs(cfg, B, S)
    if with_labels:
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return specs


def decode_specs(cfg, B: int, max_len: int) -> tuple[Any, Any, Any]:
    model = build_model(cfg)
    cache = abstract_tree(model.cache_specs(B, max_len), cfg.param_dtype)
    if cfg.frontend == "token":
        tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    else:
        d_in = cfg.frontend_dim or cfg.d_model
        tok = jax.ShapeDtypeStruct((B, 1, d_in), jnp.dtype(cfg.compute_dtype))
    if cfg.mrope_sections is not None:
        pos = jax.ShapeDtypeStruct((3, B), jnp.int32)
    else:
        pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    return cache, tok, pos


def input_specs(cfg, shape) -> dict:
    """ShapeDtypeStruct stand-ins for a (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, B, S, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, B, S, with_labels=False)}
    if shape.kind == "decode":
        cache, tok, pos = decode_specs(cfg, B, S)
        return {"cache": cache, "tokens": tok, "positions": pos}
    raise ValueError(shape.kind)


# --------------------------------------------------------------------------- #
# real synthetic data (smoke tests, examples, the 100M training driver)
# --------------------------------------------------------------------------- #
def make_batch(cfg, B: int, S: int, key, *, with_labels: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    batch: dict[str, Any] = {}
    if cfg.frontend == "token":
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab,
                                             dtype=jnp.int32)
    else:
        d_in = cfg.frontend_dim or cfg.d_model
        batch["embeds"] = jax.random.normal(
            ks[0], (B, S, d_in)
        ).astype(cfg.compute_dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.mrope_sections is not None:
        batch["positions"] = jnp.broadcast_to(pos, (3, B, S))
    else:
        batch["positions"] = pos
    if with_labels:
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab,
                                             dtype=jnp.int32)
    return batch


def make_decode_inputs(cfg, B: int, max_len: int, key, *, pos: int = 0):
    model = build_model(cfg)
    cache = init_tree(key, model.cache_specs(B, max_len), cfg.param_dtype)
    if cfg.frontend == "token":
        tok = jax.random.randint(key, (B,), 0, cfg.vocab, dtype=jnp.int32)
    else:
        d_in = cfg.frontend_dim or cfg.d_model
        tok = jax.random.normal(key, (B, 1, d_in)).astype(cfg.compute_dtype)
    p = jnp.full((B,), pos, jnp.int32)
    if cfg.mrope_sections is not None:
        p = jnp.broadcast_to(p, (3, B))
    return cache, tok, p
