"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1_5_110b \
        --shape train_4k [--multi-pod] [--debug-mesh] [--no-probes]
    PYTHONPATH=src python -m repro.launch.dryrun --all

For every (arch x shape x mesh) cell this:
  1. lowers + compiles the PRODUCTION step (scan-over-layers, remat,
     microbatching) on the 16x16 pod mesh / 2x16x16 multi-pod mesh,
     printing ``compiled.memory_analysis()`` (proves it fits) and
     ``compiled.cost_analysis()``;
  2. compiles two small UNROLLED probe models (1- and 2-layer variants) to
     derive exact per-layer FLOPs/bytes/collective-traffic — necessary
     because XLA cost analysis counts a scanned while-body once regardless
     of trip count (verified empirically; see EXPERIMENTS.md §Dry-run);
  3. emits the three roofline terms per DESIGN.md §9 into
     results/dryrun/<arch>.<shape>.<mesh>.json.
"""

# The VERY FIRST lines, before ANY other import (jax locks the device count
# on first init):
import os

_DEV = os.environ.get("REPRO_DRYRUN_DEVICES", "512")
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={_DEV}"
).strip()

import argparse
import dataclasses
import json
import pathlib
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_bytes_per_device
from repro.analysis.roofline import HW, model_flops_analytic, roofline_terms
from repro.configs.base import SHAPES, cell_supported, get_arch, list_archs
from repro.launch import inputs as inp
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models.base import abstract_tree, is_spec, shardings_tree
from repro.models.registry import build_model
from repro.optim import make_optimizer
from repro.optim.optimizers import _factored
from repro.runtime.sharding import Sharder
from repro.train.step import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
)


# --------------------------------------------------------------------------- #
# sharding trees for every argument
# --------------------------------------------------------------------------- #
def _repl(mesh):
    return NamedSharding(mesh, P())


def _batch_shardings(cfg, shape, sharder) -> dict:
    mesh = sharder.mesh
    out: dict[str, Any] = {}
    B, S = shape.global_batch, shape.seq_len

    def sh(shp, axes):
        return NamedSharding(mesh, sharder.spec(shp, axes))

    if cfg.frontend == "token":
        out["tokens"] = sh((B, S), ("batch", None))
    else:
        d_in = cfg.frontend_dim or cfg.d_model
        out["embeds"] = sh((B, S, d_in), ("batch", None, None))
    if cfg.mrope_sections is not None:
        out["positions"] = sh((3, B, S), (None, "batch", None))
    else:
        out["positions"] = sh((B, S), ("batch", None))
    if shape.kind == "train":
        out["labels"] = sh((B, S), ("batch", None))
    return out


def _opt_shardings(opt_name: str, specs, sharder):
    mesh = sharder.mesh

    def param_sh(s):
        return NamedSharding(mesh, sharder.spec(s.shape, s.axes))

    if opt_name == "adamw":
        tree = jax.tree_util.tree_map(param_sh, specs, is_leaf=is_spec)
        return {"m": tree, "v": tree, "count": _repl(mesh)}
    if opt_name == "adafactor":
        def fac(s):
            if _factored(s.shape):
                return {
                    "vr": NamedSharding(
                        mesh, sharder.spec(s.shape[:-1], s.axes[:-1])
                    ),
                    "vc": NamedSharding(
                        mesh,
                        sharder.spec(
                            s.shape[:-2] + s.shape[-1:],
                            s.axes[:-2] + s.axes[-1:],
                        ),
                    ),
                }
            return {"v": param_sh(s)}

        return {
            "f": jax.tree_util.tree_map(fac, specs, is_leaf=is_spec),
            "count": _repl(mesh),
        }
    raise ValueError(opt_name)


# --------------------------------------------------------------------------- #
# cell construction
# --------------------------------------------------------------------------- #
def build_cell(cfg, shape, mesh, *, microbatches: int = 1,
               rules: Optional[dict] = None, fsdp_gather: bool = False,
               explicit_sp: bool = False, accum_dtype: str = "float32"):
    """Returns (fn, abstract_args, in_shardings, out_shardings)."""
    sharder = Sharder(mesh, rules, fsdp_gather=fsdp_gather)
    sharder.explicit_sp = explicit_sp
    model = build_model(cfg)
    specs = model.param_specs()
    params_abs = abstract_tree(specs, cfg.param_dtype)
    params_sh = shardings_tree(specs, sharder, cfg.param_dtype)

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        state_abs = {"step": jax.ShapeDtypeStruct((), jnp.int32),
                     "params": params_abs, "opt": opt_abs}
        state_sh = {"step": _repl(mesh), "params": params_sh,
                    "opt": _opt_shardings(cfg.optimizer, specs, sharder)}
        batch_abs = inp.batch_specs(cfg, shape.global_batch, shape.seq_len,
                                    with_labels=True)
        batch_sh = _batch_shardings(cfg, shape, sharder)
        fn = make_train_step(model, sharder, microbatches=microbatches,
                             accum_dtype=accum_dtype)
        return fn, (state_abs, batch_abs), (state_sh, batch_sh), (state_sh, None)

    if shape.kind == "prefill":
        batch_abs = inp.batch_specs(cfg, shape.global_batch, shape.seq_len,
                                    with_labels=False)
        batch_sh = _batch_shardings(cfg, shape, sharder)
        fn = make_prefill_step(model, sharder)
        return fn, (params_abs, batch_abs), (params_sh, batch_sh), None

    if shape.kind == "decode":
        cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_abs = abstract_tree(cache_specs, cfg.param_dtype)
        cache_sh = shardings_tree(cache_specs, sharder, cfg.param_dtype)
        B = shape.global_batch
        if cfg.frontend == "token":
            tok_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
            tok_sh = NamedSharding(mesh, sharder.spec((B,), ("batch",)))
        else:
            d_in = cfg.frontend_dim or cfg.d_model
            tok_abs = jax.ShapeDtypeStruct((B, 1, d_in),
                                           jnp.dtype(cfg.compute_dtype))
            tok_sh = NamedSharding(
                mesh, sharder.spec((B, 1, d_in), ("batch", None, None))
            )
        if cfg.mrope_sections is not None:
            pos_abs = jax.ShapeDtypeStruct((3, B), jnp.int32)
            pos_sh = NamedSharding(mesh, sharder.spec((3, B), (None, "batch")))
        else:
            pos_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
            pos_sh = NamedSharding(mesh, sharder.spec((B,), ("batch",)))
        fn = make_serve_step(model, sharder)
        return (
            fn,
            (params_abs, cache_abs, tok_abs, pos_abs),
            (params_sh, cache_sh, tok_sh, pos_sh),
            (None, cache_sh),
        )

    raise ValueError(shape.kind)


def _compile(cfg, shape, mesh, *, microbatches=1, rules=None,
             fsdp_gather=False, explicit_sp=False, accum_dtype="float32"):
    fn, args, in_sh, out_sh = build_cell(
        cfg, shape, mesh, microbatches=microbatches, rules=rules,
        fsdp_gather=fsdp_gather, explicit_sp=explicit_sp,
        accum_dtype=accum_dtype,
    )
    # donate the mutable aggregate (train state / KV cache): the output
    # aliases the input buffer, halving the step's resident footprint
    donate = (0,) if shape.kind == "train" else (
        (1,) if shape.kind == "decode" else ()
    )
    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            fn, in_shardings=in_sh, out_shardings=out_sh,
            donate_argnums=donate,
        ).lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
    return compiled, {"lower_s": round(t_lower, 2),
                      "compile_s": round(t_compile, 2)}


def _cost(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax<=0.4.x: one dict per computation
        ca = ca[0] if ca else {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def _memory(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes_est": int(
            ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes
        ),
        "hbm_capacity": int(HW["hbm_bytes"]),
    }


# --------------------------------------------------------------------------- #
# probe decomposition (per-layer exact costs)
# --------------------------------------------------------------------------- #
def probe_pair(cfg):
    """(cfgA, cfgB, multiplier): total = costA + multiplier x (costB - costA)."""
    if cfg.family == "moe":
        fk = cfg.first_k_dense
        a = dataclasses.replace(cfg, n_layers=fk + 1, scan_layers=False)
        b = dataclasses.replace(cfg, n_layers=fk + 2, scan_layers=False)
        return a, b, cfg.n_layers - fk - 1
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // 3
        tail = cfg.n_layers - 3 * n_super
        a = dataclasses.replace(cfg, n_layers=3 + tail, scan_layers=False)
        b = dataclasses.replace(cfg, n_layers=6 + tail, scan_layers=False)
        return a, b, n_super - 1
    a = dataclasses.replace(cfg, n_layers=1, scan_layers=False)
    b = dataclasses.replace(cfg, n_layers=2, scan_layers=False)
    return a, b, cfg.n_layers - 1


def _probe_costs(cfg, shape, mesh, *, rules=None,
                 fsdp_gather=False, explicit_sp=False) -> dict:
    """Probes always run microbatches=1: the microbatch accumulation loop is
    itself a scan, whose body XLA cost analysis counts once — total step
    cost is independent of the microbatch count, so mb=1 probes are exact."""
    cfg_a, cfg_b, mult = probe_pair(cfg)
    out = {}
    for tag, c in (("A", cfg_a), ("B", cfg_b)):
        compiled, times = _compile(c, shape, mesh, microbatches=1,
                                   rules=rules, fsdp_gather=fsdp_gather,
                                   explicit_sp=explicit_sp)
        cost = _cost(compiled)
        coll = collective_bytes_per_device(compiled.as_text())
        out[tag] = {
            "layers": c.n_layers,
            **cost,
            "coll_traffic": coll["total_traffic_bytes"],
            "coll_traffic_tpu": coll["total_traffic_bytes_tpu"],
            "coll_by_kind": coll["by_kind"],
            **times,
        }
    a, b = out["A"], out["B"]
    out["multiplier"] = mult
    out["derived"] = {
        "flops": a["flops"] + mult * (b["flops"] - a["flops"]),
        "bytes": a["bytes"] + mult * (b["bytes"] - a["bytes"]),
        "coll_traffic": a["coll_traffic"]
        + mult * (b["coll_traffic"] - a["coll_traffic"]),
        "coll_traffic_tpu": a["coll_traffic_tpu"]
        + mult * (b["coll_traffic_tpu"] - a["coll_traffic_tpu"]),
        "per_layer_flops": b["flops"] - a["flops"],
        "per_layer_bytes": b["bytes"] - a["bytes"],
        "per_layer_coll": b["coll_traffic"] - a["coll_traffic"],
    }
    return out


# --------------------------------------------------------------------------- #
# cell runner
# --------------------------------------------------------------------------- #
#: production microbatch counts for the train shape (memory-fit choice;
#: the full-model compile proves it via memory_analysis). Cost terms are
#: microbatch-independent (see _probe_costs).
TRAIN_MICROBATCHES = {
    "qwen1_5_110b": 8,
    "smollm_360m": 8,
    "command_r_plus_104b": 8,
    "h2o_danube_3_4b": 8,
    "mamba2_2_7b": 8,
    "deepseek_moe_16b": 8,
    "grok_1_314b": 8,
    "recurrentgemma_9b": 8,
    "qwen2_vl_7b": 8,
    "hubert_xlarge": 8,
}


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             probes: bool = True, debug_mesh: bool = False,
             microbatches: Optional[int] = None, rules: Optional[dict] = None,
             fsdp_gather: bool = False, remat: Optional[str] = None,
             explicit_sp: bool = False, param_dtype: Optional[str] = None,
             capacity_factor: Optional[float] = None,
             verbose: bool = True) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_arch(arch_id)
    if microbatches is None:
        microbatches = (TRAIN_MICROBATCHES.get(arch_id, 8)
                        if shape.kind == "train" else 1)
    if shape.kind in ("prefill", "decode"):
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")  # serving dtype
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if param_dtype:
        cfg = dataclasses.replace(cfg, param_dtype=param_dtype)
    if capacity_factor:
        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)

    mesh_name = "debug" if debug_mesh else ("2x16x16" if multi_pod else "16x16")
    result: dict[str, Any] = {
        "arch": arch_id,
        "arch_name": cfg.name,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "microbatches": microbatches,
        "fsdp_gather": fsdp_gather,
        "explicit_sp": explicit_sp,
        "remat": cfg.remat,
        "param_dtype": cfg.param_dtype,
    }

    ok, reason = cell_supported(cfg, shape)
    if not ok:
        result["status"] = "skip"
        result["reason"] = reason
        if verbose:
            print(f"[dryrun] SKIP {arch_id} x {shape_name}: {reason}")
        return result

    mesh = (make_debug_mesh(multi_pod=multi_pod) if debug_mesh
            else make_production_mesh(multi_pod=multi_pod))
    chips = mesh.size

    try:
        compiled, times = _compile(cfg, shape, mesh,
                                   microbatches=microbatches, rules=rules,
                                   fsdp_gather=fsdp_gather,
                                   explicit_sp=explicit_sp)
        mem = _memory(compiled)
        cost_full = _cost(compiled)
        coll_full = collective_bytes_per_device(compiled.as_text())
        result["full"] = {**times, "memory": mem, "cost_raw": cost_full,
                          "collectives_raw": coll_full,
                          "note": "scan bodies counted once by XLA cost "
                                  "analysis; roofline uses probe-derived "
                                  "totals"}
        if verbose:
            print(f"[dryrun] {arch_id} x {shape_name} @ {mesh_name}: "
                  f"compile {times['compile_s']}s")
            print(f"  memory_analysis: {mem}")
            print(f"  cost_analysis(raw): {cost_full}")

        if probes:
            pr = _probe_costs(cfg, shape, mesh, rules=rules,
                              fsdp_gather=fsdp_gather,
                              explicit_sp=explicit_sp)
            result["probes"] = pr
            d = pr["derived"]
            terms = roofline_terms(
                flops_per_device=d["flops"],
                bytes_per_device=d["bytes"],
                coll_traffic_per_device=d["coll_traffic"],
                chips=chips,
                model_flops=model_flops_analytic(cfg, shape),
            )
            result["roofline"] = terms.as_dict()
            terms_tpu = roofline_terms(
                flops_per_device=d["flops"],
                bytes_per_device=d["bytes"],
                coll_traffic_per_device=d["coll_traffic_tpu"],
                chips=chips,
                model_flops=model_flops_analytic(cfg, shape),
            )
            result["roofline_tpu_corrected"] = terms_tpu.as_dict()
            if verbose:
                print(f"  roofline: compute={terms.compute_s:.4f}s "
                      f"memory={terms.memory_s:.4f}s "
                      f"collective={terms.collective_s:.4f}s "
                      f"dominant={terms.dominant} "
                      f"useful={terms.useful_flops_ratio:.2f} "
                      f"mfu_bound={terms.mfu_bound:.3f}")
        result["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] ERROR {arch_id} x {shape_name}: {result['error']}")
    return result


def _out_path(outdir: str, r: dict) -> pathlib.Path:
    p = pathlib.Path(outdir)
    p.mkdir(parents=True, exist_ok=True)
    return p / f"{r['arch']}.{r['shape']}.{r['mesh']}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--debug-mesh", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--fsdp-gather", action="store_true",
                    help="use-time FSDP weight gathering (perf iteration D)")
    ap.add_argument("--explicit-sp", action="store_true",
                    help="explicit bf16 SP boundaries (perf iterations E/I)")
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--remat", choices=["none", "full", "dots"], default=None)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                for mp in meshes:
                    cells.append((a, s, mp))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    failures = 0
    for a, s, mp in cells:
        mesh_name = "debug" if args.debug_mesh else ("2x16x16" if mp else "16x16")
        path = pathlib.Path(args.out) / f"{a}.{s}.{mesh_name}.json"
        if args.skip_existing and path.exists():
            prev = json.loads(path.read_text())
            if prev.get("status") in ("ok", "skip"):
                print(f"[dryrun] cached {a} x {s} @ {mesh_name}")
                continue
        r = run_cell(a, s, multi_pod=mp, probes=not args.no_probes,
                     debug_mesh=args.debug_mesh,
                     microbatches=args.microbatches,
                     fsdp_gather=args.fsdp_gather, remat=args.remat,
                     explicit_sp=args.explicit_sp,
                     param_dtype=args.param_dtype,
                     capacity_factor=args.capacity_factor)
        _out_path(args.out, r).write_text(json.dumps(r, indent=2, default=str))
        if r["status"] == "error":
            failures += 1
    print(f"[dryrun] done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
