"""Data pipeline: deterministic synthetic LM stream + prefetching loader.

* Determinism: batch(step) depends only on (seed, step, shard) — restart
  from a checkpoint replays the exact stream (fault-tolerance tests rely
  on this).
* The loader's host->device wait is an *intercepted blocking point*: when
  running under a USF runtime, a stalled input pipeline releases the
  job's slots to co-located jobs (the paper's "fill the gaps" §5.6)
  instead of spinning.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autockpt import maybe_checkpoint


class SyntheticLMDataset:
    """Markov-ish synthetic token stream with learnable structure (so smoke
    training runs show decreasing loss, not noise-floor flailing)."""

    def __init__(self, cfg, *, global_batch: int, seq_len: int,
                 seed: int = 0, n_shards: int = 1, shard: int = 0):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.n_shards = n_shards
        self.shard = shard
        assert global_batch % n_shards == 0
        self.local_batch = global_batch // n_shards

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * self.n_shards + self.shard
        )
        B, S, V = self.local_batch, self.seq_len, cfg.vocab
        # structured stream: a global bigram rule t_{i+1} = (t_i + 31) mod V
        # with 2% noise — compressible, so CE falls quickly below ln(V)
        start = rng.integers(0, V, size=(B, 1))
        idx = np.arange(S + 1)[None, :]
        toks = (start + 31 * idx) % V
        noise = rng.random((B, S + 1)) < 0.02
        toks = np.where(noise, rng.integers(0, V, size=(B, S + 1)), toks)
        batch: dict[str, Any] = {}
        if cfg.frontend == "token":
            batch["tokens"] = toks[:, :-1].astype(np.int32)
        else:
            d_in = cfg.frontend_dim or cfg.d_model
            batch["embeds"] = rng.standard_normal(
                (B, S, d_in), dtype=np.float32
            )
        batch["labels"] = toks[:, 1:].astype(np.int32)
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
        if cfg.mrope_sections is not None:
            pos = np.broadcast_to(pos, (3, B, S))
        batch["positions"] = pos
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Background-thread prefetch with a bounded queue.

    ``usf`` (optional): a UsfRuntime — ``get()`` then blocks cooperatively
    (CoopEvent) so a data stall yields the slot instead of busy-waiting.
    """

    def __init__(self, dataset: SyntheticLMDataset, *, depth: int = 2,
                 start_step: int = 0, usf=None):
        self.dataset = dataset
        self._q: "queue.Queue[dict]" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = False
        self._usf = usf
        # the generation-counter checkpoint tier (non-JAX hot loop): the
        # fill thread is a plain thread today, so the tick no-ops — but
        # the instrumentation is unconditional, so if the loader is ever
        # hosted on a gated task it is already revocable at batch
        # granularity (docs/PREEMPTION.md tier 3)
        self._tick = (maybe_checkpoint(usf, every=4) if usf is not None
                      else None)
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        step = self._step
        while not self._stop:
            if self._tick is not None:
                self._tick()
            batch = self.dataset.batch_at(step)
            while not self._stop:
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> dict:
        if self._usf is not None and self._usf.current_task() is not None:
            # cooperative wait: poll + nosv_waitfor-style timed block (§4.3.4)
            while True:
                try:
                    return self._q.get_nowait()
                except queue.Empty:
                    self._usf.sleep(0.002)
        return self._q.get()

    def stop(self) -> None:
        self._stop = True
