from repro.data.pipeline import SyntheticLMDataset, PrefetchLoader

__all__ = ["SyntheticLMDataset", "PrefetchLoader"]
