"""HLO-text analysis: collective ops and their traffic.

``cost_analysis()`` does not report collective bytes, so we parse the
compiled module text: every ``all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute`` instruction, its result shape, and its
replica group size.

Per-device *link traffic* model (ring algorithms, n = group size):
    all-reduce:         2 (n-1)/n x elem_bytes      (reduce-scatter+all-gather)
    all-gather:           (n-1)/n x out_bytes        (out = gathered)
    reduce-scatter:       (n-1)   x out_bytes        (in = n x out moves)
    all-to-all:           (n-1)/n x out_bytes
    collective-permute:             out_bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# e.g.  %x = f32[32,128]{1,0} all-reduce(  OR  (f32[..], f32[..]) all-gather-start(
_INSTR_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|\S+)\s+(?P<kind>"
    + "|".join(_KINDS)
    + r")(?P<variant>-start|-done)?\("
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    out_bytes: int
    group_size: int
    dtype: str = ""

    @property
    def traffic_bytes(self) -> float:
        n = max(self.group_size, 1)
        if n == 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * (n - 1) / n * self.out_bytes
        if self.kind == "all-gather":
            return (n - 1) / n * self.out_bytes
        if self.kind == "reduce-scatter":
            return float(n - 1) * self.out_bytes
        if self.kind == "all-to-all":
            return (n - 1) / n * self.out_bytes
        if self.kind == "collective-permute":
            return float(self.out_bytes)
        raise ValueError(self.kind)

    @property
    def traffic_bytes_tpu(self) -> float:
        """TPU-pipeline-corrected estimate (documented in EXPERIMENTS.md
        §Perf iteration F): the XLA *CPU* SPMD pipeline (the compile host)
        (a) upcasts bf16 dot operands to f32 BEFORE placing the collective
        and (b) lacks the TPU pipeline's all-reduce→reduce-scatter rewrite
        for sliced consumers. Correction for large activation collectives:
        f32 ⇒ ×0.5 (bf16 on TPU); activation all-reduce ⇒ ×0.5 (RS)."""
        t = self.traffic_bytes
        if self.out_bytes < 4 * 1024 * 1024:
            return t  # small tensors: keep as compiled
        if self.dtype == "f32":
            t *= 0.5
        if self.kind == "all-reduce":
            t *= 0.5
        return t


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if m.group("variant") == "-done":
            continue  # counted at -start
        out_bytes = _shape_bytes(m.group("shapes"))
        gsize = 0
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            gsize = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                gsize = len([t for t in gl.group(1).split(",") if t.strip()])
        dm = _SHAPE_RE.search(m.group("shapes"))
        dtype = dm.group(1) if dm else ""
        ops.append(CollectiveOp(m.group("kind"), out_bytes, gsize or 1, dtype))
    return ops


def collective_bytes_per_device(hlo_text: str) -> dict:
    ops = parse_collectives(hlo_text)
    by_kind: dict[str, dict] = {}
    total = 0.0
    total_tpu = 0.0
    for op in ops:
        d = by_kind.setdefault(op.kind, {"count": 0, "traffic_bytes": 0.0,
                                         "payload_bytes": 0})
        d["count"] += 1
        d["traffic_bytes"] += op.traffic_bytes
        d["payload_bytes"] += op.out_bytes
        total += op.traffic_bytes
        total_tpu += op.traffic_bytes_tpu
    return {"total_traffic_bytes": total,
            "total_traffic_bytes_tpu": total_tpu,
            "by_kind": by_kind, "n_ops": len(ops)}
