from repro.analysis.hlo import parse_collectives, collective_bytes_per_device
from repro.analysis.roofline import HW, roofline_terms

__all__ = ["parse_collectives", "collective_bytes_per_device", "HW",
           "roofline_terms"]
