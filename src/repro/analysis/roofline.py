"""Roofline terms (TPU v5e targets; this container is the compile host).

    compute    = HLO_FLOPs_global    / (chips x 197e12 FLOP/s)
    memory     = HLO_bytes_global    / (chips x 819e9  B/s)
    collective = coll_bytes_global   / (chips x 50e9   B/s per link)

cost_analysis() reports per-*device* program cost; x chips = global.
"""

from __future__ import annotations

import dataclasses

HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_gbps": 819e9,           # per chip
    "ici_gbps": 50e9,            # per link
    "hbm_bytes": 16 * 1024**3,   # v5e HBM capacity
}


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    chips: int
    flops_global: float
    bytes_global: float
    coll_bytes_global: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming no overlap of the dominant term."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.flops_global if self.flops_global else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-flops utilization at the roofline step time."""
        cap = self.step_time_s * self.chips * HW["peak_flops_bf16"]
        return self.model_flops / cap if cap else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            dominant=self.dominant,
            step_time_s=self.step_time_s,
            useful_flops_ratio=self.useful_flops_ratio,
            mfu_bound=self.mfu_bound,
        )
        return d


def roofline_terms(
    *,
    flops_per_device: float,
    bytes_per_device: float,
    coll_traffic_per_device: float,
    chips: int,
    model_flops: float = 0.0,
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_device / HW["peak_flops_bf16"],
        memory_s=bytes_per_device / HW["hbm_gbps"],
        collective_s=coll_traffic_per_device / HW["ici_gbps"],
        chips=chips,
        flops_global=flops_per_device * chips,
        bytes_global=bytes_per_device * chips,
        coll_bytes_global=coll_traffic_per_device * chips,
        model_flops=model_flops,
    )


def model_flops_analytic(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N_active D (inference fwd), plus KV
    reads are a memory, not FLOP, term. N counts active params for MoE."""
    from repro.models.registry import build_param_specs
    from repro.models.base import param_count, is_spec
    import jax

    specs = build_param_specs(cfg)
    n_total = param_count(specs)
    if cfg.family == "moe":
        # active = total - inactive routed experts
        leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
        # routed expert weight specs have a leading (layers, experts) pair
        routed = 0
        import math

        def walk(tree):
            nonlocal routed
            if is_spec(tree):
                if "experts" in tree.axes:
                    routed += math.prod(tree.shape)
                return
            if isinstance(tree, dict):
                for k, v in tree.items():
                    if k == "router":
                        continue
                    walk(v)

        walk(specs)
        n_active = n_total - routed + routed * (cfg.top_k / max(cfg.n_experts, 1))
    else:
        n_active = n_total

    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per row
    return 2.0 * n_active * shape.global_batch
