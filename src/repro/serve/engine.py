"""Oversubscribed serving engine — the paper's §5.5 scenario, real JAX.

Each ``InferenceServer`` is a USF *job* with worker tasks that run
continuous-batching decode loops over a slot-based KV cache. Every wait —
request-queue get, batch formation, device-step completion — is an
intercepted USF blocking point, so SCHED_COOP multiplexes the servers
(and the gateway) over slots at *application* boundaries, never preempting
a decode burst mid-flight (the HBM-residency analogue of cache affinity).

The gateway fans a request to several model servers and joins the
responses (the paper's agentic benchmark: LLaMA + GPT-2 + RoBERTa).

Two-level scheduling: the gateway and every server attach as their own
arbiter group (a dedicated SCHED_COOP instance each) with a slot ``share``
derived from ``nice`` unless given explicitly — the paper's
gateway-nice-0 / servers-nice-20 priority story expressed as slot leases,
with work-conserving borrowing when the gateway is idle.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autockpt import wrap_jit
from repro.core.policies import Policy, SchedCoop
from repro.core.scheduler import REC_REQ_DONE, REC_REQUEST
from repro.core.sync import CoopChannel, CoopEvent
from repro.core.task import Job
from repro.core.threads import UsfRuntime, UsfTaskError
from repro.launch.inputs import make_decode_inputs
from repro.models.base import init_tree
from repro.models.registry import build_model
from repro.runtime.sharding import Sharder
from repro.train.step import make_serve_step

_RID = itertools.count()


@dataclasses.dataclass
class Request:
    tokens: list[int]
    max_new: int = 8
    rid: int = dataclasses.field(default_factory=lambda: next(_RID))
    arrival: float = 0.0
    started: float = 0.0
    finished: float = 0.0
    #: absolute SLO deadline (``time.monotonic`` domain); None = best-effort
    deadline: Optional[float] = None
    output: list[int] = dataclasses.field(default_factory=list)
    done: Optional[CoopEvent] = None
    #: arbiter deadline token while posted (set by ``submit``)
    _dl_token: Optional[int] = None

    @property
    def latency(self) -> float:
        return self.finished - self.arrival

    @property
    def missed(self) -> bool:
        """True iff the request had an SLO and finished past it."""
        return (self.deadline is not None and self.finished > 0.0
                and self.finished > self.deadline)


class InferenceServer:
    """One model server (a Job): continuous batching over `max_batch` KV
    slots; requests are prefilled teacher-forced through the decode path
    and then generated greedily."""

    def __init__(self, name: str, cfg, usf: UsfRuntime, *,
                 max_batch: int = 2, max_len: int = 64, seed: int = 0,
                 nice: int = 0, share: Optional[float] = None,
                 policy: Optional[Policy] = None, auto_ckpt: bool = True):
        self.name = name
        self.cfg = cfg
        self.usf = usf
        self.job = Job(name, nice=nice, share=share)
        self._policy = policy
        self.lease = None  # set on start()
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue = CoopChannel(usf)
        self.model = build_model(cfg)
        self.sharder = Sharder(None)
        self.params = init_tree(jax.random.PRNGKey(seed),
                                self.model.param_specs(), cfg.param_dtype)
        self._step = jax.jit(make_serve_step(self.model, self.sharder),
                             donate_argnums=(1,))
        if auto_ckpt:
            # every decode dispatch is a preemption point: a broker revoke
            # or elastic shrink parks this worker within ~one engine step
            # even when the batch never drains (docs/PREEMPTION.md tier 3)
            self._step = wrap_jit(self._step, runtime=usf)
        self._task = None
        self._stop = False
        self.served = 0

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> Request:
        req.done = req.done or CoopEvent(self.usf)
        req.arrival = req.arrival or time.monotonic()
        if req.deadline is not None:
            # surface the SLO to the job-level arbiter: a DeadlineArbiter
            # folds it into its EDF/least-laxity grant order (and may fire
            # an urgent grant if laxity is already negative); the base
            # SlotArbiter has no post_deadline and the request degrades to
            # best-effort ordering.
            post = getattr(self.usf.sched.arbiter, "post_deadline", None)
            if post is not None:
                req._dl_token = post(self.job, req.deadline)
        rec = self.usf.sched._rec
        if rec is not None:
            rec((self.usf.sched.clock(), REC_REQUEST, req.rid,
                 (self.job.jid, req.deadline)))
        self.queue.put(req)
        return req

    def _retire(self, req: Request) -> None:
        rec = self.usf.sched._rec
        if rec is not None:
            rec((self.usf.sched.clock(), REC_REQ_DONE, req.rid, req.latency))
        if req._dl_token is not None:
            retire = getattr(self.usf.sched.arbiter, "retire_deadline", None)
            if retire is not None:
                retire(self.job, req._dl_token)
            req._dl_token = None

    def start(self) -> None:
        # the worker starts through the shared default group (a warm
        # server: its loop may already be building batches) and is then
        # re-homed LIVE into its own arbiter group — a dedicated intra-job
        # policy under a nice-weighted (or explicit) slot lease. attach
        # migrates the queued/running worker without draining it.
        self._task = self.usf.create(self._serve_loop, job=self.job,
                                     name=f"{self.name}-worker")
        if self.job.lease is None or not self.job.lease.group.dedicated:
            self.lease = self.usf.attach(
                self.job, policy=self._policy or SchedCoop(),
                share=self.job.share,
            )

    def set_policy(self, policy: Optional[Policy], *,
                   share: Optional[float] = None):
        """Live re-home the server without draining its decode loop (the
        rescale-driven policy change): a fresh dedicated intra-job policy
        swaps in place, or ``policy=None`` demotes the server into the
        shared default group (e.g. after its mesh collapsed and a
        dedicated slot claim no longer makes sense). Queued requests keep
        their place — the worker task migrates exactly once, mid-batch if
        it is running."""
        if policy is None:
            self.lease = self.usf.demote(self.job, share=share)
        else:
            self.lease = self.usf.attach(
                self.job, policy=policy,
                share=share if share is not None else self.job.share,
            )
        return self.lease

    def stop(self) -> None:
        self._stop = True
        self.queue.put(None)  # wake the worker

    # ------------------------------------------------------------------ #
    def _serve_loop(self) -> None:
        cfg = self.cfg
        B = self.max_batch
        cache, _, _ = make_decode_inputs(cfg, B, self.max_len,
                                         jax.random.PRNGKey(1))
        active: list[Optional[Request]] = [None] * B
        pos = np.zeros(B, np.int64)
        remaining = np.zeros(B, np.int64)
        pending_tokens: list[list[int]] = [[] for _ in range(B)]
        cur = np.zeros(B, np.int64)

        while not self._stop:
            # admit requests into free slots (continuous batching)
            for i in range(B):
                if active[i] is None:
                    req = self.queue.try_get() if any(
                        a is not None for a in active
                    ) else self.queue.get()  # block only when fully idle
                    if req is None:
                        if self._stop:
                            return
                        continue
                    req.started = time.monotonic()
                    active[i] = req
                    pos[i] = 0
                    remaining[i] = req.max_new
                    pending_tokens[i] = list(req.tokens)
                    cur[i] = pending_tokens[i].pop(0)
            if all(a is None for a in active):
                continue

            # one engine step: each active slot advances one token
            toks = jnp.asarray(cur, jnp.int32)
            p = jnp.asarray(pos, jnp.int32)
            if cfg.mrope_sections is not None:
                p = jnp.broadcast_to(p, (3, B))
            logits, cache = self._step(self.params, cache, toks, p)
            logits.block_until_ready()  # the device wait: a blocking point
            nxt = np.asarray(jnp.argmax(logits, axis=-1))

            for i in range(B):
                req = active[i]
                if req is None:
                    continue
                pos[i] += 1
                if pending_tokens[i]:
                    cur[i] = pending_tokens[i].pop(0)  # still prefilling
                    continue
                req.output.append(int(nxt[i]))
                cur[i] = int(nxt[i])
                remaining[i] -= 1
                if remaining[i] <= 0 or pos[i] >= self.max_len - 1:
                    req.finished = time.monotonic()
                    self.served += 1
                    self._retire(req)
                    req.done.set()
                    active[i] = None


class Gateway:
    """Fans each request out to all servers; joins all responses (§5.5)."""

    def __init__(self, usf: UsfRuntime, servers: list[InferenceServer],
                 *, nice: int = 0, share: Optional[float] = None,
                 policy: Optional[Policy] = None):
        self.usf = usf
        self.servers = servers
        self.job = Job("gateway", nice=nice, share=share)
        # the gateway gets its own lease too (nice 0 -> heaviest share by
        # default, mirroring the paper's microservices priority setup)
        self.lease = usf.attach(self.job, policy=policy or SchedCoop(),
                                share=share)
        self.responses: list[dict] = []

    def _check_servers(self) -> None:
        """A dead server worker would leave fanned-out requests pending
        forever: surface its task exception to the caller instead."""
        for s in self.servers:
            t = s._task
            if t is not None and getattr(t, "_exc", None) is not None:
                raise UsfTaskError(t, t._exc)

    def handle(self, tokens: list[int], max_new: int = 4,
               timeout: Optional[float] = None,
               slo: Optional[float] = None) -> dict:
        """Runs on the caller's USF task: submit to every server, wait all.

        Polls the response events so a crashed server worker raises
        ``UsfTaskError`` here rather than hanging the request; ``timeout``
        (wall seconds, whole fan-out) raises ``TimeoutError``. ``slo``
        (relative seconds) stamps every fanned request with an absolute
        deadline that a deadline-aware arbiter folds into its grant order;
        misses are recorded, never enforced."""
        t0 = time.monotonic()
        deadline = None if timeout is None else t0 + timeout
        dl = None if slo is None else t0 + slo
        reqs = []
        for s in self.servers:
            r = Request(tokens=list(tokens), max_new=max_new, arrival=t0,
                        deadline=dl)
            s.submit(r)
            reqs.append(r)
        for r in reqs:
            while True:
                poll = 0.5
                if deadline is not None:
                    poll = min(poll, max(deadline - time.monotonic(), 0.0))
                if r.done.wait(timeout=poll):
                    break
                self._check_servers()
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"gateway fan-out exceeded {timeout}s "
                        f"(request {r.rid})"
                    )
        rec = {
            "latency": time.monotonic() - t0,
            "per_server": {s.name: r.latency for s, r in zip(self.servers, reqs)},
        }
        if slo is not None:
            rec["slo"] = slo
            rec["missed"] = any(r.missed for r in reqs)
        self.responses.append(rec)
        return rec
