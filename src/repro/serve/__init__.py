from repro.serve.engine import InferenceServer, Gateway, Request

__all__ = ["InferenceServer", "Gateway", "Request"]
