"""Multi-process serving — N server *processes* behind one gateway.

The single-process engine (``repro.serve.engine``) co-locates servers as
jobs inside one ``UsfRuntime``; this module is the paper's full
*multi-process* story: each model server runs in its own OS process with
its own runtime, and the processes share the node's cores through the
node-level lease broker (``repro.ipc``) instead of blind OS-level
oversubscription:

    gateway process: MultiProcessGateway ── NodeBroker (thread)
        ├── ServerProcess A: UsfRuntime + BrokerClient + InferenceServer
        ├── ServerProcess B: …
        └── ServerProcess C: …

Request fan-out/fan-in crosses process boundaries over multiprocessing
queues; *slot* coordination crosses them over the broker's Unix socket.
Each server registers a nice-derived (or explicit) node share, so the
paper's gateway-nice-0 / servers-nice-20 priority story scales from jobs
to processes unchanged.

Failure/recovery (``supervise=True``, the default): the gateway
*supervises* its server processes — a dead ``ServerProcess`` is
restarted with capped exponential backoff, a crash loop (more than
``max_restarts`` deaths inside ``restart_window`` seconds) opens a
circuit breaker that marks the slot failed (surfaced in ``snapshot()``)
while requests keep routing to the survivors, and a request in flight on
a dying server is retried once on a survivor before a
``ServerProcessError`` surfaces. ``supervise=False`` is the unsupervised
PR 5 behavior: a dead server raises at the caller and stays dead. Either
way a dead server's node lease is reclaimed by the broker (its slots
flow to the survivors) and a dead broker degrades every server to
free-running — then heals: the server-side ``BrokerClient`` reconnects
with backoff once a broker is back on the rendezvous path.
"""

from __future__ import annotations

import multiprocessing as mp
import os
import queue as queue_mod
import threading
import time
from typing import Any, Optional

from repro.ipc import BrokerClient, NodeBroker

#: spawn, not fork: server children initialize their own JAX runtime (a
#: forked interpreter would inherit locked XLA state and watchdog threads)
_CTX = mp.get_context("spawn")


class ServerProcessError(RuntimeError):
    pass


def _server_main(spec: dict, req_q, resp_q) -> None:
    """Child entry: one InferenceServer on its own broker-bound runtime."""
    try:
        from repro.configs.base import get_arch, get_smoke
        from repro.core.policies import SchedCoop
        from repro.core.threads import UsfRuntime
        from repro.core.topology import Topology
        from repro.serve.engine import InferenceServer, Request

        usf = UsfRuntime(Topology(int(spec["slots"]), 1), SchedCoop())
        client = None
        if spec.get("broker_path"):
            share = spec.get("share")

            def _backlog() -> int:
                # real demand, not topology width: the runtime's runnable
                # tasks plus the gateway requests still queued toward this
                # server — an idle server reports 0 and its node slots
                # flow to a saturated sibling process
                try:
                    queued = req_q.qsize()
                except (NotImplementedError, OSError):
                    queued = 0  # qsize is unsupported on some platforms
                return usf.runnable_backlog() + queued

            client = BrokerClient(
                spec["broker_path"],
                name=spec["name"],
                # explicit 0.0 is a valid (best-effort) share: only an
                # unset share defaults to 1.0
                share=1.0 if share is None else share,
                heartbeat_interval=spec.get("heartbeat_interval", 0.2),
                backlog_probe=_backlog,
            ).bind(usf).start()
            client.wait_grant(5.0)  # coordinated before the first decode
        cfg = (get_smoke(spec["arch"]) if spec.get("smoke", True)
               else get_arch(spec["arch"]))
        server = InferenceServer(
            spec["name"], cfg, usf,
            max_batch=int(spec.get("max_batch", 2)),
            max_len=int(spec.get("max_len", 32)),
            nice=int(spec.get("nice", 0)),
            share=spec.get("job_share"),
            # auto-checkpointed decode (default): a broker regrant parks
            # this server's surplus slots within ~one engine step even
            # while it is decode-saturated, instead of waiting for the
            # batch to drain to a blocking point
            auto_ckpt=bool(spec.get("auto_ckpt", True)),
        )
        server.start()
        resp_q.put({"ready": True, "pid": os.getpid()})
        while True:
            item = req_q.get()
            if item is None:
                break
            rid, tokens, max_new = item
            req = server.submit(Request(tokens=list(tokens),
                                        max_new=int(max_new)))
            # the pump is a plain-thread waiter on the CoopEvent (mixed
            # waiters are supported); the decode loop runs gated
            req.done.wait()
            resp_q.put({
                "rid": rid,
                "output": list(req.output),
                "latency": req.latency,
                "granted": None if client is None else client.granted,
            })
        server.stop()
        if client is not None:
            client.stop()
        usf.shutdown(timeout=5.0)
    except Exception:  # noqa: BLE001 - surface to the parent, then die
        import traceback

        resp_q.put({"fatal": traceback.format_exc()})
        raise


class ServerProcess:
    """Parent-side handle of one model-server process.

    Restartable: ``restart()`` respawns a dead child on *fresh* queues
    (in-flight items on the old queues die with the old process) and
    bumps ``generation`` so a caller blocked on the old response stream
    surfaces a ``ServerProcessError`` instead of waiting on a queue
    nobody will ever fill. ``failed`` is the crash-loop circuit breaker
    flag (set by the supervising gateway, surfaced in snapshots)."""

    def __init__(self, name: str, arch: str, *,
                 broker_path: Optional[str] = None,
                 slots: int = 2, share: Optional[float] = None,
                 nice: int = 0, max_batch: int = 2, max_len: int = 32,
                 smoke: bool = True, heartbeat_interval: float = 0.2,
                 auto_ckpt: bool = True):
        self.name = name
        self.spec = {
            "name": name,
            "arch": arch,
            "broker_path": broker_path,
            "slots": slots,
            "share": share,
            "job_share": None,
            "nice": nice,
            "max_batch": max_batch,
            "max_len": max_len,
            "smoke": smoke,
            "heartbeat_interval": heartbeat_interval,
            "auto_ckpt": auto_ckpt,
        }
        self._req_q = _CTX.Queue()
        self._resp_q = _CTX.Queue()
        self._proc: Optional[Any] = None
        self._rid = 0
        self.served = 0
        #: bumped on every (re)spawn; result() fences on it
        self.generation = 0
        #: lifetime restarts performed on this slot
        self.restarts = 0
        #: circuit breaker: True once the slot crash-looped and was
        #: permanently benched (requests route to survivors only)
        self.failed = False
        #: monotonic stamps of observed deaths (the breaker's window)
        self.fail_times: list = []

    def start(self, *, ready_timeout: float = 180.0) -> "ServerProcess":
        self._proc = _CTX.Process(
            target=_server_main,
            args=(self.spec, self._req_q, self._resp_q),
            name=f"usf-server-{self.name}", daemon=True)
        self._proc.start()
        msg = self._next_resp(ready_timeout)
        if not msg.get("ready"):
            raise ServerProcessError(f"{self.name} failed to start: {msg}")
        return self

    def restart(self, *, ready_timeout: float = 180.0) -> "ServerProcess":
        """Respawn a dead server on fresh queues (supervision path)."""
        old = self._proc
        if old is not None and old.is_alive():
            raise ServerProcessError(f"{self.name} is alive; not restarting")
        if old is not None:
            old.join(0.0)
        self._req_q = _CTX.Queue()
        self._resp_q = _CTX.Queue()
        self.generation += 1
        self.restarts += 1
        return self.start(ready_timeout=ready_timeout)

    @property
    def pid(self) -> Optional[int]:
        return None if self._proc is None else self._proc.pid

    def alive(self) -> bool:
        return self._proc is not None and self._proc.is_alive()

    def submit(self, tokens, max_new: int = 4) -> int:
        """Queue one request; returns its rid (responses arrive FIFO)."""
        self._rid += 1
        self._req_q.put((self._rid, list(tokens), max_new))
        return self._rid

    def result(self, timeout: Optional[float] = None) -> dict:
        """Next response (FIFO — the server pump is serial)."""
        msg = self._next_resp(timeout)
        self.served += 1
        return msg

    def _next_resp(self, timeout: Optional[float]) -> dict:
        deadline = None if timeout is None else time.monotonic() + timeout
        gen = self.generation
        resp_q = self._resp_q
        while True:
            step = 0.5 if deadline is None else max(
                0.0, min(0.5, deadline - time.monotonic()))
            try:
                msg = resp_q.get(timeout=step)
            except queue_mod.Empty:
                if self.generation != gen:
                    # the supervisor restarted the child under us: the
                    # old response stream is dead, surface it
                    raise ServerProcessError(
                        f"server process {self.name} restarted mid-request")
                if not self.alive():
                    raise ServerProcessError(
                        f"server process {self.name} (pid={self.pid}) died")
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"no response from {self.name} within {timeout}s")
                continue
            if "fatal" in msg:
                raise ServerProcessError(
                    f"{self.name} crashed:\n{msg['fatal']}")
            return msg

    def stop(self, timeout: float = 10.0) -> None:
        if self._proc is None:
            return
        try:
            self._req_q.put(None)
        except (OSError, ValueError):
            pass
        self._proc.join(timeout)
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(5.0)


class MultiProcessGateway:
    """Fans each request out to every live server process and joins the
    responses (the cross-process twin of ``serve.engine.Gateway``).

    With ``coordinate=True`` (default) the gateway hosts the designated
    ``NodeBroker`` thread and every server process registers with it —
    the co-located servers split the node by share instead of
    oversubscribing it. ``coordinate=False`` is the free-running Linux
    baseline: same processes, no slot coordination.

    With ``supervise=True`` (default) the gateway is *self-healing*: a
    supervisor thread restarts dead servers with capped exponential
    backoff (``restart_backoff``), opens a crash-loop circuit breaker
    after ``max_restarts`` deaths within ``restart_window`` seconds
    (slot marked ``failed``, surfaced by ``snapshot()``, routed around),
    and ``handle`` retries a request lost to a dying server once on a
    survivor. ``supervise=False`` restores the PR 5 fail-fast behavior.
    """

    def __init__(self, archs: dict[str, str], *, coordinate: bool = True,
                 node_capacity: Optional[int] = None,
                 slots_per_server: int = 2, shares: Optional[dict] = None,
                 max_batch: int = 2, max_len: int = 32, smoke: bool = True,
                 heartbeat_timeout: float = 1.0,
                 supervise: bool = True, max_restarts: int = 3,
                 restart_window: float = 30.0,
                 restart_backoff: tuple = (0.5, 8.0),
                 poll_interval: float = 0.2):
        self.broker: Optional[NodeBroker] = None
        broker_path = None
        if coordinate:
            self.broker = NodeBroker(capacity=node_capacity,
                                     heartbeat_timeout=heartbeat_timeout)
            broker_path = self.broker.start()
        shares = shares or {}
        self.servers = [
            ServerProcess(name, arch, broker_path=broker_path,
                          slots=slots_per_server, share=shares.get(name),
                          max_batch=max_batch, max_len=max_len, smoke=smoke)
            for name, arch in archs.items()
        ]
        self.supervise = bool(supervise)
        self.max_restarts = int(max_restarts)
        self.restart_window = float(restart_window)
        self.restart_backoff = restart_backoff
        self._poll_interval = float(poll_interval)
        self._ready_timeout = 180.0
        self._stop_evt = threading.Event()
        self._supervisor: Optional[threading.Thread] = None
        self.responses: list[dict] = []

    def start(self, *, ready_timeout: float = 180.0) -> "MultiProcessGateway":
        self._ready_timeout = float(ready_timeout)
        for s in self.servers:
            s.start(ready_timeout=ready_timeout)
        if self.supervise:
            self._supervisor = threading.Thread(
                target=self._supervise_main, name="usf-gateway-supervisor",
                daemon=True)
            self._supervisor.start()
        return self

    # ------------------------------------------------------------------ #
    # supervision (restart + crash-loop circuit breaker)
    # ------------------------------------------------------------------ #
    def _supervise_main(self) -> None:
        while not self._stop_evt.wait(self._poll_interval):
            for s in self.servers:
                if s.failed or s._proc is None or s.alive():
                    continue
                now = time.monotonic()
                s.fail_times.append(now)
                s.fail_times[:] = [t for t in s.fail_times
                                   if now - t <= self.restart_window]
                if len(s.fail_times) > self.max_restarts:
                    # crash loop: open the breaker — stop burning the
                    # node respawning it, keep routing to survivors
                    s.failed = True
                    continue
                base, cap = self.restart_backoff
                delay = min(cap, base * (2 ** (len(s.fail_times) - 1)))
                if self._stop_evt.wait(delay):
                    return
                try:
                    s.restart(ready_timeout=self._ready_timeout)
                except Exception:  # noqa: BLE001
                    # the respawn itself crashed (e.g. still-broken
                    # config): the dead child is counted at the next
                    # poll, converging on the breaker
                    pass

    def _targets(self) -> list:
        if not self.supervise:
            return list(self.servers)
        return [s for s in self.servers if not s.failed and s.alive()]

    def handle(self, tokens, max_new: int = 4,
               timeout: Optional[float] = None) -> dict:
        """Submit to every live server process, wait for all responses.

        Under supervision, a request lost to a dying server is retried
        once on a surviving server before ``ServerProcessError``
        surfaces; the stand-in's answer is recorded under the dead
        server's key with a ``retried_on`` marker."""
        t0 = time.monotonic()
        targets = self._targets()
        if not targets:
            raise ServerProcessError("no live server processes")

        def left() -> Optional[float]:
            return None if timeout is None else max(
                0.0, timeout - (time.monotonic() - t0))

        for s in targets:
            s.submit(tokens, max_new)
        per_server = {}
        dead = []
        for s in targets:
            try:
                per_server[s.name] = s.result(timeout=left())
            except ServerProcessError:
                if not self.supervise:
                    raise
                dead.append(s)
        for s in dead:
            survivors = [t for t in targets
                         if t is not s and t.name in per_server and t.alive()]
            if not survivors:
                raise ServerProcessError(
                    f"{s.name} died mid-request and no survivor could "
                    "retry it")
            stand_in = survivors[0]
            stand_in.submit(tokens, max_new)
            retried = dict(stand_in.result(timeout=left()))
            retried["retried_on"] = stand_in.name
            per_server[s.name] = retried
        rec = {
            "latency": time.monotonic() - t0,
            "per_server": {n: r["latency"] for n, r in per_server.items()},
            "outputs": {n: r["output"] for n, r in per_server.items()},
            "retried": {n: r["retried_on"] for n, r in per_server.items()
                        if "retried_on" in r},
        }
        self.responses.append(rec)
        return rec

    def snapshot(self) -> dict:
        """Supervision + coordination state: per-server liveness,
        restart counts, breaker flags — and the broker's lease table."""
        out = {
            "supervise": self.supervise,
            "servers": {
                s.name: {
                    "alive": s.alive(),
                    "pid": s.pid,
                    "restarts": s.restarts,
                    "failed": s.failed,
                    "served": s.served,
                } for s in self.servers
            },
        }
        if self.broker is not None:
            out["broker"] = self.broker.snapshot()
        return out

    def stop(self) -> None:
        self._stop_evt.set()
        if self._supervisor is not None:
            self._supervisor.join(10.0)
        for s in self.servers:
            s.stop()
        if self.broker is not None:
            self.broker.stop()

    def __enter__(self) -> "MultiProcessGateway":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
