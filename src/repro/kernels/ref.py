"""Pure-jnp oracles for every kernel (the ground truth for allclose tests).

Deliberately naive: materialized score matrices, O(S) step-by-step
recurrences — slow, obvious, and independent of the kernel algebra.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1.0e30


def flash_attention_ref(q, k, v, *, causal=True, window: Optional[int] = None):
    """q [B,H,S,D]; k,v [B,KV,T,D] -> [B,H,S,D]."""
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, S, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bkgsd,bktd->bkgst", qr, k.astype(jnp.float32))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


def flash_decode_ref(q, k_cache, v_cache, cache_pos, q_pos, *,
                     window: Optional[int] = None):
    """q [B,H,D]; caches [B,KV,W,D]; cache_pos [B,W]; q_pos [B]."""
    B, H, D = q.shape
    KV, W = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bkgd,bkwd->bkgw", qr, k_cache.astype(jnp.float32))
    valid = (cache_pos >= 0) & (cache_pos <= q_pos[:, None])
    if window is not None:
        valid &= q_pos[:, None] - cache_pos < window
    s = jnp.where(valid[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    o = jnp.einsum("bkgw,bkwd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm):
    """Exact O(S) recurrence. x [B,S,H,P]; dt [B,S,H]; A [H];
    Bm, Cm [B,S,N]. Returns (y [B,S,H,P], h_final [B,H,P,N])."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, t):
        xt, dtt, Bt, Ct = t
        a = jnp.exp(dtt.astype(jnp.float32) * A)           # [B,H]
        h = h * a[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt.astype(jnp.float32),
            Bt.astype(jnp.float32), xt.astype(jnp.float32)
        )
        y = jnp.einsum("bn,bhpn->bhp", Ct.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def rglru_ref(a, b, h0):
    """Exact step recurrence. a,b [B,S,W]; h0 [B,W]."""
    def step(h, t):
        at, bt = t
        h = at.astype(jnp.float32) * h + bt.astype(jnp.float32)
        return h, h

    h, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)),
    )
    return jnp.moveaxis(ys, 0, 1).astype(a.dtype), h


def moe_gmm_ref(x, w):
    """x [E,C,D]; w [E,D,F]."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
