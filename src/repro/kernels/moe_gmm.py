"""Grouped (per-expert) matmul kernel: out[e] = x[e] @ w[e].

The MoE hot loop: x_e [E, C, D] x w [E, D, F] -> [E, C, F]. Grid
(expert, C_blocks, F_blocks, D_blocks) with a [blk_c, blk_f] fp32 VMEM
accumulator across the sequential D axis — a classic MXU matmul pipeline
with an extra expert dimension, so each expert's weights stream through
VMEM exactly once per (C, F) tile pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _kernel(x_ref, w_ref, o_ref, acc_scr):
    di = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]   # [blk_c, blk_d]
    w = w_ref[0]   # [blk_d, blk_f]
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(di == nd - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def moe_gmm(
    x: jax.Array,  # [E, C, D]
    w: jax.Array,  # [E, D, F]
    *,
    blk_c: int = 128,
    blk_f: int = 128,
    blk_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    E, C, D = x.shape
    F = w.shape[-1]
    blk_c = min(blk_c, C)
    blk_f = min(blk_f, F)
    blk_d = min(blk_d, D)
    pc, pf, pd = (-C) % blk_c, (-F) % blk_f, (-D) % blk_d
    if pc or pd:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        w = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    Cp, Dp, Fp = x.shape[1], x.shape[2], w.shape[2]

    out = pl.pallas_call(
        _kernel,
        grid=(E, Cp // blk_c, Fp // blk_f, Dp // blk_d),
        in_specs=[
            pl.BlockSpec((1, blk_c, blk_d), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, blk_d, blk_f), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, blk_c, blk_f),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, Fp), x.dtype),
        scratch_shapes=[_vmem((blk_c, blk_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :C, :F]
