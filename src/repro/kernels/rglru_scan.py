"""RG-LRU linear-recurrence kernel: h_t = a_t * h_{t-1} + b_t.

Memory-bound elementwise scan. Grid (batch, width_blocks, seq_blocks) with
the seq axis sequential-minor; the [blk_w] hidden state lives in VMEM
scratch across seq iterations, and each iteration runs a short fori_loop
over its seq tile. Gates (a, b) are computed outside in JAX (they're
matmuls that XLA already fuses well); the kernel is the part XLA does
badly — a length-S sequential dependence that would otherwise lower to S
tiny HLO ops or an O(S log S) associative scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _kernel(a_ref, b_ref, h0_ref, y_ref, hout_ref, h_scr, *, blk_s: int):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)   # [blk_s, blk_w]
    b = b_ref[0].astype(jnp.float32)

    def step(t, carry):
        h = carry
        h = a[t] * h + b[t]
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, blk_s, step, h_scr[...])
    h_scr[...] = h

    @pl.when(si == ns - 1)
    def _fin():
        hout_ref[0] = h.astype(hout_ref.dtype)


def rglru_scan_kernel(
    a: jax.Array,    # [B, S, W] decay in (0,1)
    b: jax.Array,    # [B, S, W] gated input
    h0: jax.Array,   # [B, W]
    *,
    blk_w: int = 128,
    blk_s: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, S, W = a.shape
    blk_w = min(blk_w, W)
    blk_s = min(blk_s, S)
    assert W % blk_w == 0 and S % blk_s == 0, (W, blk_w, S, blk_s)

    y, hN = pl.pallas_call(
        functools.partial(_kernel, blk_s=blk_s),
        grid=(B, W // blk_w, S // blk_s),
        in_specs=[
            pl.BlockSpec((1, blk_s, blk_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, blk_s, blk_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, blk_w), lambda bi, wi, si: (bi, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_s, blk_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, blk_w), lambda bi, wi, si: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[_vmem((blk_w,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y, hN
