"""Flash attention forward kernel (causal / sliding-window / bidirectional,
GQA-aware).

TPU mapping: grid (batch, q_head, q_blocks, kv_blocks); the kv dimension is
the minor (sequential) grid axis, so the running-softmax state (m, l, acc)
lives in VMEM scratch that persists across kv iterations. Fully-masked
blocks (above the causal diagonal / below the sliding window) are skipped
with ``pl.when`` — on hardware they cost nothing, which is the 2x causal
FLOP saving the pure-JAX chunked backend cannot express.

Block sizes default to (128, 128): MXU-aligned on the (8,128)/(16,128)
tiling grid of VMEM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            blk_q: int, blk_k: int, seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q
    k_start = ki * blk_k

    # block-level skip: entirely above the causal diagonal, or entirely
    # outside the sliding window
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + blk_q - 1)
    if window is not None:
        live = jnp.logical_and(
            live, k_start + blk_k - 1 >= q_start - (window - 1)
        )

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [blk_q, D]
        k = k_ref[0, 0].astype(jnp.float32)                # [blk_k, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                   # [blk_q, blk_k]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)                # [blk_k, D]
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,   # [B, H, Sq, D]
    k: jax.Array,   # [B, KV, Sk, D]
    v: jax.Array,   # [B, KV, Sk, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0
    G = H // KV
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    # pad seq dims to block multiples (masked out by seq_k bound)
    pq = (-Sq) % blk_q
    pk = (-Sk) % blk_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    nq = qp.shape[2] // blk_q
    nk = kp.shape[2] // blk_k

    grid = (B, H, nq, nk)
    kern = functools.partial(
        _kernel, scale=D ** -0.5, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, seq_q=Sq, seq_k=Sk,
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            _vmem((blk_q,), jnp.float32),       # running max m
            _vmem((blk_q,), jnp.float32),       # running sum l
            _vmem((blk_q, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    if pq:
        out = out[:, :, :Sq]
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
