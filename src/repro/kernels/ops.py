"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto-detection: True off-TPU (this container),
False on real TPU hardware. Model code calls these through
``cfg.attn_backend="pallas"`` etc.; layouts are adapted here
([B,S,H,D] model convention -> [B,H,S,D] kernel convention).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssd_scan as _ssd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """Model layout: q [B,S,H,D]; k,v [B,T,KV,D] -> [B,S,H,D]."""
    it = _interpret_default() if interpret is None else interpret
    qT = jnp.swapaxes(q, 1, 2)
    kT = jnp.swapaxes(k, 1, 2)
    vT = jnp.swapaxes(v, 1, 2)
    o = _fa.flash_attention_fwd(qT, kT, vT, causal=causal, window=window,
                                interpret=it)
    return jnp.swapaxes(o, 1, 2)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_decode(q, k_cache, v_cache, cache_pos, q_pos, *,
                 window: Optional[int] = None,
                 interpret: Optional[bool] = None):
    """q [B,H,D]; caches [B,W,KV,D] (model layout) -> [B,H,D]."""
    it = _interpret_default() if interpret is None else interpret
    kT = jnp.swapaxes(k_cache, 1, 2)
    vT = jnp.swapaxes(v_cache, 1, 2)
    return _dec.flash_decode(q, kT, vT, cache_pos, q_pos, window=window,
                             interpret=it)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256,
             interpret: Optional[bool] = None):
    it = _interpret_default() if interpret is None else interpret
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=it)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru(a, b, h0, *, interpret: Optional[bool] = None):
    it = _interpret_default() if interpret is None else interpret
    return _rg.rglru_scan_kernel(a, b, h0, interpret=it)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_gmm(x, w, *, interpret: Optional[bool] = None):
    it = _interpret_default() if interpret is None else interpret
    return _gmm.moe_gmm(x, w, interpret=it)
