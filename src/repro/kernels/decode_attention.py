"""Split-KV flash-decode kernel: one new token against a long KV cache.

Grid (batch, kv_head, kv_blocks): each kv block folds its partial softmax
into VMEM scratch (running m/l/acc per q-head-group) — flash-decoding
adapted to the TPU's sequential minor grid axis instead of GPU thread-block
reductions. Validity is positional (cache slots carry absolute positions:
ring buffers for SWA/local attention come for free).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1.0e30


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _kernel(q_ref, k_ref, v_ref, cpos_ref, qpos_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float,
            window: Optional[int], blk_k: int, G: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)                # [blk_k, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, blk_k]
    cpos = cpos_ref[0]                                  # [blk_k] int32
    qpos = qpos_ref[0]                                  # [] int32
    valid = jnp.logical_and(cpos >= 0, cpos <= qpos)
    if window is not None:
        valid = jnp.logical_and(valid, qpos - cpos < window)
    s = jnp.where(valid[None, :], s, NEG)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,          # [B, H, D] one token per row
    k_cache: jax.Array,    # [B, KV, W, D]
    v_cache: jax.Array,    # [B, KV, W, D]
    cache_pos: jax.Array,  # [B, W] absolute positions (-1 empty)
    q_pos: jax.Array,      # [B] absolute position of the new token
    *,
    window: Optional[int] = None,
    blk_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    KV, W = k_cache.shape[1], k_cache.shape[2]
    assert H % KV == 0
    G = H // KV
    blk_k = min(blk_k, W)
    pk = (-W) % blk_k
    if pk:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pk), (0, 0)))
        cache_pos = jnp.pad(cache_pos, ((0, 0), (0, pk)),
                            constant_values=-1)
    Wp = k_cache.shape[2]
    nk = Wp // blk_k
    qg = q.reshape(B, KV, G, D)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=D ** -0.5, window=window,
                          blk_k=blk_k, G=G),
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, blk_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, blk_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, blk_k), lambda b, h, j: (b, j)),
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            _vmem((G,), jnp.float32),
            _vmem((G,), jnp.float32),
            _vmem((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, cache_pos, q_pos)
    return out.reshape(B, H, D)
