"""Mamba-2 SSD chunked-scan kernel.

Grid (batch, head, chunk): the chunk axis is the sequential minor grid
dimension, carrying the [P, N] recurrent state in VMEM scratch. Each chunk
iteration does three MXU matmuls (C.B^T scores, score @ x, outer-product
state update) plus elementwise decay math — the same algebra as
models/mamba2.ssd_chunked (the ref oracle uses the O(S) recurrence).

VMEM per iteration: x,y [Q,P] + B,C [Q,N] + state [P,N] — a few hundred KB
at (Q=256, P=64, N=128); the MXU dims (Q, P, N) are all 128-aligned or
padded by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr,
            *, Q: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)            # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)          # [Q]
    A = a_ref[0].astype(jnp.float32)               # [] scalar (per head)
    Bm = b_ref[0].astype(jnp.float32)              # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)              # [Q, N]

    dA = dt * A                                    # [Q], negative
    cum = jnp.cumsum(dA)                           # [Q]
    # intra-chunk: scores_ij = (C_i . B_j) exp(cum_i - cum_j) dt_j, i >= j
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.exp(jnp.clip(cum[:, None] - cum[None, :], -60.0, 0.0))
    scores = jnp.where(ii >= jj, CB * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, P]
    # cross-chunk: y_i += exp(cum_i) C_i . h
    h = h_scr[...]                                  # [P, N]
    Ch = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, P]
    y = y + Ch * jnp.exp(jnp.clip(cum, -60.0, 0.0))[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: h' = exp(cum_Q) h + sum_j exp(cum_Q - cum_j) dt_j x_j B_j^T
    last = cum[Q - 1]
    w = jnp.exp(jnp.clip(last - cum, -60.0, 0.0)) * dt   # [Q]
    h_new = jnp.exp(jnp.clip(last, -60.0, 0.0)) * h + jax.lax.dot_general(
        x * w[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # [P, N]
    h_scr[...] = h_new

    @pl.when(ci == nc - 1)
    def _fin():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan(
    x: jax.Array,    # [B, S, H, P]
    dt: jax.Array,   # [B, S, H] (>0)
    A: jax.Array,    # [H] (<0)
    Bm: jax.Array,   # [B, S, N]
    Cm: jax.Array,   # [B, S, N]
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    # kernel layouts: x [B,H,S,P], dt [B,H,S], B/C [B,S,N] shared over heads
    xk = jnp.moveaxis(x, 2, 1)
    dtk = jnp.moveaxis(dt, 2, 1)

    y, h = pl.pallas_call(
        functools.partial(_kernel, Q=Q),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[_vmem((P, N), jnp.float32)],
        interpret=interpret,
    )(xk, dtk, A, Bm, Cm)
    return jnp.moveaxis(y, 1, 2), h
