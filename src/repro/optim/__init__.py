from repro.optim.optimizers import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    make_optimizer,
)
from repro.optim.schedules import warmup_cosine

__all__ = [
    "adamw_init",
    "adamw_update",
    "adafactor_init",
    "adafactor_update",
    "make_optimizer",
    "warmup_cosine",
]
