"""Optimizers, pure JAX, param-tree generic.

* AdamW — fp32 moments; state mirrors the param tree so it inherits the
  params' shardings (FSDP-sharded optimizer state for free).
* Adafactor — factored second moments for >=2D params (rank-1 outer
  approximation), no first moment; the memory footprint that lets
  grok-1-314B train on a single 256-chip pod (see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------- #
# AdamW
# --------------------------------------------------------------------------- #
def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads: Any,
    state: dict,
    params: Any,
    *,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[Any, dict]:
    count = state["count"] + 1
    c = count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m2 / (1 - b1 ** c)
        vhat = v2 / (1 - b2 ** c)
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        p2, m2, v2 = upd(g, m, v, p)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return (
        jax.tree_util.tree_unflatten(tdef, new_p),
        {
            "m": jax.tree_util.tree_unflatten(tdef, new_m),
            "v": jax.tree_util.tree_unflatten(tdef, new_v),
            "count": count,
        },
    )


# --------------------------------------------------------------------------- #
# Adafactor (factored, momentum-free)
# --------------------------------------------------------------------------- #
def _factored(shape: tuple[int, ...]) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params: Any) -> dict:
    def per_param(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "f": jax.tree_util.tree_map(per_param, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adafactor_update(
    grads: Any,
    state: dict,
    params: Any,
    *,
    lr: float | jax.Array,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    chunk_stacked: int = 8,
) -> tuple[Any, dict]:
    """``chunk_stacked``: scan the update over the leading (stacked-layers)
    dim of big params — the fp32 temporaries (g², vhat, u) of an update on
    a [L, ...] stacked tensor otherwise dominate peak memory (§Perf
    iteration I5: grok-314B, 64-layer expert stacks)."""
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    beta2 = 1.0 - c ** (-decay)

    def upd(g, f, p):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if _factored(p.shape):
            vr = beta2 * f["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * f["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
            vhat = (vr / denom)[..., None] * vc[..., None, :]
            newf = {"vr": vr, "vc": vc}
        else:
            v = beta2 * f["v"] + (1 - beta2) * g2
            vhat = v
            newf = {"v": v}
        u = g32 / jnp.sqrt(vhat + eps)
        # update clipping (RMS <= clip_threshold); under the chunked path
        # this clips per layer slice — the per-tensor semantics of
        # unstacked frameworks
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        step = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), newf

    def upd_maybe_chunked(g, f, p):
        if chunk_stacked and p.ndim >= 3 and p.shape[0] >= chunk_stacked:
            return jax.lax.map(lambda t: upd(*t), (g, f, p))
        return upd(g, f, p)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_f = tdef.flatten_up_to(state["f"])
    new_p, new_f = [], []
    for g, f, p in zip(flat_g, flat_f, flat_p):
        p2, f2 = upd_maybe_chunked(g, f, p)
        new_p.append(p2)
        new_f.append(f2)
    return (
        jax.tree_util.tree_unflatten(tdef, new_p),
        {"f": jax.tree_util.tree_unflatten(tdef, new_f), "count": count},
    )


# --------------------------------------------------------------------------- #
# factory
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], dict]
    update: Callable[..., tuple[Any, dict]]


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return Optimizer(
            "adamw",
            adamw_init,
            lambda g, s, p, lr: adamw_update(g, s, p, lr=lr, **kw),
        )
    if name == "adafactor":
        return Optimizer(
            "adafactor",
            adafactor_init,
            lambda g, s, p, lr: adafactor_update(g, s, p, lr=lr, **kw),
        )
    raise ValueError(f"unknown optimizer {name}")
