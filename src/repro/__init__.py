"""repro: USF/SCHED_COOP — a user-space cooperative scheduling framework for
oversubscribed multi-runtime / multi-job JAX workloads on TPU pods.

Reproduction of: Roca & Beltran, "Rethinking Thread Scheduling under
Oversubscription: A User-Space Framework for Coordinating Multi-runtime and
Multi-process Workloads" (PPoPP '26), adapted TPU-natively per DESIGN.md.
"""

__version__ = "1.0.0"
