"""hubert-xlarge [audio] — 48L d_model=1280 16H (MHA kv=16) d_ff=5120
vocab=504 (cluster units), encoder-only (wav2vec2 architecture).
[arXiv:2106.07447; unverified]

Encoder-only: bidirectional attention, masked-unit-prediction training,
NO autoregressive decode — decode_32k / long_500k cells are skipped (see
DESIGN.md §Arch-applicability). The waveform conv frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings [B, S, 512]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    head_dim=80,
    encoder_only=True,
    mlp_act="gelu",
    frontend="frame",
    frontend_dim=512,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="hubert-xlarge-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        vocab=64,
        head_dim=16,
        frontend_dim=32,
        attn_chunk=32,
        compute_dtype="float32",
    )
