"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) expert d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]

Trains with Adafactor (factored second moments, no first moment) so the
optimizer state fits a single 256-chip v5e pod at 16 GB/chip — see
DESIGN.md §5 and EXPERIMENTS.md §Dry-run."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    head_dim=128,
    rope_theta=10_000.0,
    n_experts=8,
    n_shared_experts=0,
    top_k=2,
    expert_d_ff=32768,
    first_k_dense=0,
    capacity_factor=1.25,
    optimizer="adafactor",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="grok-1-314b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        head_dim=16,
        n_experts=4,
        top_k=2,
        expert_d_ff=96,
        attn_chunk=32,
        compute_dtype="float32",
    )
