"""Architecture & shape configuration.

One ``ArchConfig`` per assigned architecture lives in
``src/repro/configs/<id>.py`` with the exact published hyperparameters, plus
a ``smoke()`` reduced config of the same family for CPU tests.

The four assigned input shapes are global (every LM arch pairs with all
four, modulo documented skips — see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

_ARCH_IDS = [
    "qwen1_5_110b",
    "smollm_360m",
    "command_r_plus_104b",
    "h2o_danube_3_4b",
    "mamba2_2_7b",
    "deepseek_moe_16b",
    "grok_1_314b",
    "recurrentgemma_9b",
    "qwen2_vl_7b",
    "hubert_xlarge",
]

# public ids use dashes (CLI-friendly); module names use underscores
def _norm(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    encoder_only: bool = False
    swa_window: Optional[int] = None  # sliding-window attention (danube)
    rope_theta: float = 1e4
    mrope_sections: Optional[tuple[int, ...]] = None  # qwen2-vl M-RoPE
    frontend: str = "token"           # token | patch | frame (stubs for vlm/audio)
    frontend_dim: int = 0             # embedding dim provided by the stub
    norm_eps: float = 1e-6
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    moe_aux_loss: float = 0.01
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- hybrid (recurrentgemma / Griffin) ---
    lru_width: int = 0
    local_window: int = 2048
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    # --- numerics & execution ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    attn_backend: str = "chunked"     # reference | chunked | pallas
    attn_chunk: int = 1024
    remat: str = "full"               # none | full | dots
    #: scan over stacked layers (constant-size HLO). The dry-run's roofline
    #: probes set False on 1-2 layer variants: cost_analysis() counts a scan
    #: body ONCE regardless of trip count, so per-layer costs are derived
    #: from unrolled probes (see launch/dryrun.py).
    scan_layers: bool = True
    #: nested remat around attention: recompute attention internals during
    #: the block's backward instead of saving per-chunk softmax residuals —
    #: the pure-JAX stand-in for the Pallas flash kernel's recompute-bwd
    #: (§Perf iteration I8). Costs one extra attention forward.
    remat_attention: bool = False
    mlp_act: str = "silu"             # silu (swiglu) | gelu (classic 2-mat)
    z_loss: float = 0.0
    # --- optimizer selection (grok needs adafactor to fit one pod) ---
    optimizer: str = "adamw"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k decode? (bounded state/window)"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.swa_window is not None

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    def param_count_analytic(self) -> int:
        """Approximate N for MODEL_FLOPS=6ND (embeddings included once)."""
        from repro.models.registry import build_param_specs
        from repro.models.base import param_count

        return param_count(build_param_specs(self))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_supported(arch: "ArchConfig", shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch, shape) a runnable dry-run cell? Returns (ok, reason)."""
    if shape.kind == "decode" and not arch.supports_decode:
        return False, "encoder-only arch has no autoregressive decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "pure full-attention arch: no sub-quadratic path for 500k"
    return True, ""


def get_arch(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch_id)}")
    return mod.CONFIG


def get_smoke(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch_id)}")
    return mod.smoke()


def list_archs() -> list[str]:
    return list(_ARCH_IDS)
