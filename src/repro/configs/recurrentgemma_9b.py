"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, RG-LRU + local attention, pattern (rec, rec, attn).
[arXiv:2402.19427; unverified]

38 layers = 12 scanned superblocks of (rec, rec, local-attn) + 2 unrolled
trailing recurrent blocks. Sub-quadratic: long_500k runs (O(1) LRU state +
O(window) local-attention ring cache)."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    rope_theta=10_000.0,
    lru_width=4096,
    local_window=2048,
    block_pattern=("rec", "rec", "attn"),
    ssm_conv=4,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="recurrentgemma-9b-smoke",
        n_layers=5,            # 1 superblock + 2 tail rec blocks
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab=256,
        head_dim=16,
        lru_width=64,
        local_window=16,
        attn_chunk=16,
        compute_dtype="float32",
    )
