"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA kv=16) expert
d_ff=1408 vocab=102400, MoE 64 routed top-6 + 2 shared, fine-grained;
first layer dense (d_ff=10944). [arXiv:2401.06066; hf]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,           # the single dense layer's FFN
    vocab=102400,
    head_dim=128,
    rope_theta=10_000.0,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1408,
    first_k_dense=1,
    capacity_factor=1.25,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="deepseek-moe-16b-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        vocab=256,
        head_dim=16,
        n_experts=8,
        n_shared_experts=1,
        top_k=2,
        expert_d_ff=32,
        first_k_dense=1,
        attn_chunk=32,
        compute_dtype="float32",
    )
