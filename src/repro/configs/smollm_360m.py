"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152, llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    head_dim=64,
    rope_theta=10_000.0,
    mlp_act="silu",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="smollm-360m-smoke",
        n_layers=2,
        d_model=60,
        n_heads=3,
        n_kv_heads=1,
        d_ff=128,
        vocab=256,
        head_dim=20,
        attn_chunk=32,
        compute_dtype="float32",
    )
