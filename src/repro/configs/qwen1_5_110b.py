"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_act="silu",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen1.5-110b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=256,
        head_dim=16,
        attn_chunk=32,
        compute_dtype="float32",
    )
