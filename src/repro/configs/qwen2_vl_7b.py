"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, M-RoPE (temporal/h/w sections), dynamic resolution.
[arXiv:2409.12191; hf]

The vision frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings [B, S, d_model] plus the 3-stream
M-RoPE position ids [3, B, S]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # pairs: sums to head_dim/2 = 64
    frontend="patch",
    frontend_dim=3584,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen2-vl-7b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=256,
        head_dim=16,
        mrope_sections=(2, 3, 3),
        frontend_dim=64,
        attn_chunk=32,
        compute_dtype="float32",
    )
