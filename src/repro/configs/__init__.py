from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, get_arch, list_archs

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "get_arch", "list_archs"]
