"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000, GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01;
unverified]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    head_dim=128,
    qkv_bias=False,
    rope_theta=75_000_000.0,
    mlp_act="silu",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="command-r-plus-104b-smoke",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        d_ff=192,
        vocab=256,
        head_dim=16,
        attn_chunk=32,
        compute_dtype="float32",
    )
