"""mamba2-2.7b [ssm] — 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,        # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="mamba2-2.7b-smoke",
        n_layers=2,
        d_model=64,
        vocab=256,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=16,
        compute_dtype="float32",
    )
