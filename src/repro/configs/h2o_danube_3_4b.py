"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000, llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]

SWA makes this the one *dense* arch that supports long_500k decode
(O(window) ring-buffer KV cache)."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    head_dim=120,
    swa_window=4096,
    rope_theta=10_000.0,
    mlp_act="silu",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="h2o-danube-3-4b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=256,
        head_dim=16,
        swa_window=16,
        attn_chunk=16,
        compute_dtype="float32",
    )
