"""Train / eval steps: microbatch gradient accumulation, remat, optimizer.

``make_train_step`` builds the function handed to ``jax.jit`` in both the
real trainer and the dry-run. Gradient reduction across data/pod axes is
GSPMD's job (params are sharded/replicated by the in_shardings; XLA emits
the reduce-scatter/all-reduce and overlaps it with the backward when the
latency-hiding scheduler allows); microbatching bounds activation memory
with a scan whose carry is the fp32 grad accumulator.

Preemption: nothing in these factories checkpoints, deliberately — a
``usf.checkpoint()`` cannot run inside a traced function (it would
execute once at trace time, then never again). The preemption point for
a jitted step is its *call site*: the trainer and the serving engine
wrap the jitted function with ``repro.core.autockpt`` so every dispatch
boundary checkpoints (docs/PREEMPTION.md tier 3).
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.train.loss import lm_loss


def init_train_state(model, params) -> dict:
    opt = make_optimizer(model.cfg.optimizer)
    return {"step": jnp.zeros((), jnp.int32), "params": params,
            "opt": opt.init(params)}


def _loss_fn(model, sharder, params, batch):
    logits, aux = model.forward(params, batch, sharder)
    loss, metrics = lm_loss(logits, batch["labels"], z_loss=model.cfg.z_loss)
    if model.cfg.family == "moe":
        loss = loss + aux["moe_aux"] + aux["moe_z"]
        metrics["moe_aux"] = aux["moe_aux"]
    metrics["loss"] = loss
    return loss, metrics


def _split_microbatches(batch: dict, k: int) -> dict:
    def rs(x):
        if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] % k == 0:
            return x.reshape((k, x.shape[0] // k) + x.shape[1:])
        if hasattr(x, "ndim") and x.ndim >= 2:  # [3,B,S] positions (vlm)
            return x.reshape(
                (x.shape[0], k, x.shape[1] // k) + x.shape[2:]
            ).swapaxes(0, 1)
        raise ValueError(f"cannot split microbatch on {getattr(x, 'shape', x)}")

    return jax.tree_util.tree_map(rs, batch)


def make_train_step(
    model,
    sharder,
    *,
    microbatches: int = 1,
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    accum_dtype: str = "float32",
) -> Callable[[dict, dict], tuple[dict, dict]]:
    opt = make_optimizer(model.cfg.optimizer)
    adt = jnp.dtype(accum_dtype)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: _loss_fn(model, sharder, p, batch), has_aux=True
            )(params)
        else:
            mb = _split_microbatches(batch, microbatches)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, adt), params
            )

            def acc(carry, mbatch):
                gsum = carry
                (l, m), g = jax.value_and_grad(
                    lambda p: _loss_fn(model, sharder, p, mbatch), has_aux=True
                )(params)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(adt), gsum, g
                )
                return gsum, (l, m)

            grads, (losses, mlist) = jax.lax.scan(acc, g0, mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda x: x.mean(0), mlist)
            loss = losses.mean()

        lr = warmup_cosine(state["step"], peak_lr=peak_lr, warmup=warmup,
                           total=total_steps)
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads))
        )
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return (
            {"step": state["step"] + 1, "params": new_params, "opt": new_opt},
            metrics,
        )

    return train_step


def make_eval_step(model, sharder) -> Callable[[dict, dict], dict]:
    def eval_step(params: dict, batch: dict) -> dict:
        _, metrics = _loss_fn(model, sharder, params, batch)
        return metrics

    return eval_step


def make_prefill_step(model, sharder) -> Callable[[dict, dict], jax.Array]:
    """Full-sequence forward (inference prefill): logits only."""

    def prefill_step(params: dict, batch: dict) -> jax.Array:
        logits, _ = model.forward(params, batch, sharder)
        return logits

    return prefill_step


def make_serve_step(model, sharder) -> Callable[..., tuple[jax.Array, dict]]:
    """One decode token against a KV cache."""

    def serve_step(params: dict, cache: dict, tokens: jax.Array,
                   positions: jax.Array) -> tuple[jax.Array, dict]:
        return model.decode_step(params, cache, tokens, positions, sharder)

    return serve_step
