from repro.train.loss import lm_loss
from repro.train.step import make_train_step, make_eval_step, init_train_state

__all__ = ["lm_loss", "make_train_step", "make_eval_step", "init_train_state"]
