"""Production training loop: checkpoint/restart, async saves, straggler
mitigation hooks, co-execution awareness.

Fault-tolerance model (1000+-node design, exercised at container scale):
  * deterministic data stream keyed by step — restart replays exactly;
  * atomic async checkpoints every ``ckpt_every`` steps;
  * ``Trainer.run`` resumes from the latest checkpoint automatically;
  * straggler mitigation: per-step wall times feed an EWMA detector; a
    slot flagged as slow gets its affinity demoted in the USF scheduler
    (cooperative analogue of backup tasks — see core/straggler.py);
  * under a UsfRuntime, the step dispatch/ready waits are USF blocking
    points, so a co-located job can fill this job's stalls (§5.6).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.ckpt import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core.autockpt import preemptible
from repro.data.pipeline import PrefetchLoader, SyntheticLMDataset
from repro.models.base import init_tree
from repro.models.registry import build_model
from repro.runtime.sharding import Sharder
from repro.train.step import init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    keep: int = 3
    microbatches: int = 1
    peak_lr: float = 3e-4
    warmup: int = 10
    log_every: int = 10
    seed: int = 0


class StragglerDetector:
    """EWMA per-step wall-time watchdog; flags steps >= factor x EWMA."""

    def __init__(self, factor: float = 2.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.factor * self.ewma
        if slow:
            self.flagged.append(step)
        # EWMA excludes flagged outliers so one straggler doesn't mask the next
        if not slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class Trainer:
    def __init__(self, cfg, tcfg: TrainerConfig, *, sharder: Optional[Sharder] = None,
                 usf=None, on_step: Optional[Callable[[int, dict], None]] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.sharder = sharder or Sharder(None)
        self.usf = usf
        self.on_step = on_step
        self.model = build_model(cfg)
        self.straggler = StragglerDetector()
        self.metrics_log: list[dict] = []
        self._step_fn = jax.jit(
            make_train_step(
                self.model, self.sharder, microbatches=tcfg.microbatches,
                peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
                total_steps=tcfg.steps,
            ),
            donate_argnums=(0,),
        )
        if usf is not None:
            # auto-checkpoint at the step-dispatch boundary: revokes land
            # between steps even before the end-of-step yield below, and
            # the same instrumented path no-ops when run outside a task
            self._step_fn = preemptible(self._step_fn, runtime=usf)

    # ------------------------------------------------------------------ #
    def init_state(self) -> dict:
        params = init_tree(jax.random.PRNGKey(self.tcfg.seed),
                           self.model.param_specs(), self.cfg.param_dtype)
        return init_train_state(self.model, params)

    def run(self, *, resume: bool = True,
            stop_at: Optional[int] = None) -> dict:
        """``stop_at`` simulates a crash: stop early without touching the
        LR schedule (which stays keyed to cfg.steps)."""
        tcfg = self.tcfg
        ckpt = AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.keep) if tcfg.ckpt_dir else None
        state = self.init_state()
        start = 0
        if resume and tcfg.ckpt_dir:
            last = latest_step(tcfg.ckpt_dir)
            if last is not None:
                state = restore_checkpoint(tcfg.ckpt_dir, last, state)
                start = int(np.asarray(state["step"]))
        ds = SyntheticLMDataset(self.cfg, global_batch=tcfg.global_batch,
                                seq_len=tcfg.seq_len, seed=tcfg.seed)
        loader = PrefetchLoader(ds, start_step=start, usf=self.usf)
        try:
            for step in range(start, min(stop_at or tcfg.steps, tcfg.steps)):
                batch = loader.get()
                t0 = time.monotonic()
                state, metrics = self._step_fn(state, batch)
                loss = float(metrics["loss"])  # sync point
                dt = time.monotonic() - t0
                slow = self.straggler.observe(step, dt)
                rec = {"step": step + 1, "loss": loss, "wall_s": dt,
                       "straggler": slow}
                self.metrics_log.append(rec)
                if self.on_step:
                    self.on_step(step + 1, rec)
                if ckpt and (step + 1) % tcfg.ckpt_every == 0:
                    ckpt.save(state, step + 1)
                if self.usf is not None and self.usf.current_task() is not None:
                    # scheduling point between steps: lets SCHED_COOP rotate
                    # jobs at quantum boundaries (§4.1)
                    self.usf.yield_now()
        finally:
            loader.stop()
            if ckpt:
                ckpt.wait()
        return state
