"""Losses. Vocab-sharded-safe: logsumexp/gather over the sharded vocab dim
lower to local reductions + small all-reduces under GSPMD (never a [T, V]
one-hot)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(logits: jax.Array, labels: jax.Array, *, z_loss: float = 0.0,
            ignore_id: int = -1) -> tuple[jax.Array, dict]:
    """Token-mean cross entropy. logits [B,S,V]; labels [B,S] int32."""
    l32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(l32, axis=-1)                       # [B,S]
    safe_labels = jnp.maximum(labels, 0)
    ll = jnp.take_along_axis(l32, safe_labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels != ignore_id).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    metrics = {
        "ce_loss": loss,
        "tokens": denom,
        "accuracy": ((l32.argmax(-1) == labels) * mask).sum() / denom,
    }
    if z_loss:
        zl = z_loss * ((lse ** 2) * mask).sum() / denom
        loss = loss + zl
        metrics["z_loss"] = zl
    return loss, metrics
