"""Cross-process USF: the node-level coordination layer.

The paper's headline results are *multi-process*: independent processes
(nested BLAS, multi-process LLaMA inference, MD) co-located on one node,
coordinated purely in user space. This package is that layer:

* ``NodeBroker`` (broker.py) — one per node: apportions the node's slots
  across registered processes with the same lease machinery
  (``repro.core.lease``) the in-process ``SlotArbiter`` uses for jobs;
  heartbeat-based liveness reclaims a dead worker's lease.
* ``BrokerClient`` (client.py) — one per worker process: registers a
  share, receives grants, and lands them on the runtime's elastic slot
  parking (``UsfRuntime.set_slot_target``). A dead broker degrades the
  worker to free-running — never a deadlock.
* ``protocol`` — the tiny length-prefixed JSON framing over Unix sockets.

Scheduling is thus three-level: NodeBroker (processes) → SlotArbiter
(jobs) → intra-job policies (tasks), every level speaking leases.
"""

from repro.ipc.broker import BrokerError, NodeBroker, ProcLease
from repro.ipc.client import BrokerClient
from repro.ipc.protocol import default_socket_path

__all__ = [
    "NodeBroker",
    "BrokerClient",
    "BrokerError",
    "ProcLease",
    "default_socket_path",
]
